//! Quickstart: compile a zoo model for the 2-TOPS Neutron, run the cycle
//! simulator, and print the headline numbers.
//!
//!     cargo run --release --example quickstart [-- --model yolov8n-det]

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::sim::{simulate, SimOptions};
use eiq_neutron::util::cli::Args;
use eiq_neutron::zoo::ModelId;

fn main() {
    let args = Args::from_env();
    let name = args.opt("model", "mobilenet-v2");
    let id = ModelId::parse(&name).expect("unknown model — see `neutron list`");

    // 1. Build the model graph (what the LiteRT frontend would hand over).
    let graph = id.build();
    println!(
        "{}: {} ops, {:.2} GMACs, {:.1} M params",
        id.display_name(),
        graph.ops.len(),
        graph.total_macs() as f64 / 1e9,
        graph.total_params() as f64 / 1e6
    );

    // 2. Compile: format selection → tiling+fusion CP → scheduling CP →
    //    allocation CP (all Sec. IV of the paper).
    let cfg = NeutronConfig::flagship_2tops();
    let compiled = compile(&graph, &cfg, &CompileOptions::default_partitioned());
    println!(
        "compiled in {} ms: {} tiles, {} ticks, {} CP subproblems",
        compiled.compile_ms,
        compiled.program.tiles.len(),
        compiled.schedule.ticks.len(),
        compiled.schedule.subproblems
    );

    // 3. Simulate the decoupled access-execute execution.
    let report = simulate(&compiled, &cfg, &SimOptions::default());
    println!(
        "latency {:.2} ms | effective {:.2} TOPS (peak {:.2}) | DDR {:.1} MB | DM hidden {:.0}%",
        report.latency_ms,
        report.effective_tops(graph.total_macs()),
        cfg.peak_tops(),
        report.ddr_bytes as f64 / 1e6,
        report.hiding_ratio() * 100.0
    );
}
