//! Table III demo: latency + LTP of all 12 Table-IV models on Ours /
//! eNPU-A / eNPU-B / iNPU (the paper's headline comparison).
//!
//!     cargo run --release --example compare_npus

fn main() {
    eiq_neutron::report::table3();
    eiq_neutron::report::table1();
}
