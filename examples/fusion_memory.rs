//! Fig. 6 demo: memory usage over time during the first layers of
//! MobileNetV2, with and without the fusion+tiling optimization.
//!
//!     cargo run --release --example fusion_memory

fn main() {
    eiq_neutron::report::fig6();
}
