//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled quickstart CNN (JAX/Pallas → HLO text, built by
//! `make artifacts`), serves a batch of inference requests through the L3
//! coordinator — simulated NPU timing from the compiled job program, REAL
//! numerics from the PJRT executable — and checks the first request's
//! logits against the manifest's expected vector (proving the artifact,
//! the runtime, and the build-time oracle all agree).
//!
//!     make artifacts && cargo run --release --example e2e_inference

use anyhow::Result;
use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::coordinator::{emit, Executor};
use eiq_neutron::report::quickstart_graph;
use eiq_neutron::runtime::{literal_i8, literal_to_i32s, Manifest, Runtime};
use eiq_neutron::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let requests: usize = args.opt_parse("requests", 16);

    // --- Load artifacts (Python ran once at build time; never again). ---
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo_text(manifest.artifact_path("model.path")?)?;
    println!("PJRT platform: {}", rt.platform());

    let shape: Vec<usize> = manifest
        .get("model.input_shape")?
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();

    // --- Self-check: replay the manifest's pinned input seed through the
    // executable and compare with the expected logits (computed at build
    // time by BOTH the traced jax fn and the pure-jnp oracle). ---
    // numpy's PCG64 stream cannot be reproduced here, so aot.py pinned the
    // expected logits for its own input; we verify determinism instead:
    // same input ⇒ same logits across repeated runs.
    let n: usize = shape.iter().product();
    let probe = eiq_neutron::runtime::deterministic_i8(0xE2E, n);
    let lit = literal_i8(&probe, &shape)?;
    let a = literal_to_i32s(&exe.run(&[lit.clone()])?[0])?;
    let b = literal_to_i32s(&exe.run(&[lit])?[0])?;
    assert_eq!(a, b, "PJRT execution must be deterministic");
    let expected = manifest.get_i32s("model.expected_logits")?;
    println!(
        "artifact self-check: deterministic ✓ ({} classes; manifest expects {} classes)",
        a.len(),
        expected.len()
    );
    assert_eq!(a.len(), expected.len());

    // --- Compile the equivalent IR graph for timing and build the job
    // program the coordinator drives. ---
    let cfg = NeutronConfig::flagship_2tops();
    let g = quickstart_graph(shape[0], shape[2]);
    let compiled = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let program = emit(&compiled, "quickstart");
    let (cj, dj) = program.job_counts();
    println!(
        "job program: {} compute jobs, {} DMA jobs, {} ticks",
        cj,
        dj,
        program.tick_count()
    );
    let mut executor = Executor::new(cfg.clone(), program);

    // --- Serve the batch. ---
    let mut class_histogram = vec![0usize; a.len()];
    for req in 0..requests {
        let payload = eiq_neutron::runtime::deterministic_i8(req as u64, n);
        let lit = literal_i8(&payload, &shape)?;
        let run = || -> Result<Vec<i32>> { literal_to_i32s(&exe.run(&[lit.clone()])?[0]) };
        let result = executor.run_request(Some(&run))?;
        let logits = result.logits.unwrap();
        let top = logits
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        class_histogram[top] += 1;
        if req < 3 {
            println!(
                "req {req}: class={top} sim={:.3} ms host={} µs",
                result.sim_ms, result.host_us
            );
        }
    }
    println!("class histogram over {requests} requests: {class_histogram:?}");
    println!("{}", executor.metrics.summary(cfg.freq_ghz));
    println!("e2e OK");
    Ok(())
}
