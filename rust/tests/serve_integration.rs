//! Integration + property tests for the multi-tenant serving layer:
//! conservation (every admitted request completes exactly once), scaling
//! monotonicity (more instances never increase makespan), cache coherence
//! (a hit is bit-identical to a cold compile), and virtual-clock
//! determinism (same seed → identical `ServeReport`).

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::compile;
use eiq_neutron::coordinator::emit;
use eiq_neutron::serve::{
    deterministic_compile_options, run_trace, serve, serve_with_cache, synthetic_trace,
    Completion, CompileCache, ServeOptions,
};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Cheap zoo subset for property cases (each model compiles once per
/// cache, so shared caches keep the suite fast).
const POOL: [ModelId; 4] = [
    ModelId::MobileNetV1,
    ModelId::MobileNetV2,
    ModelId::MobileNetV3Min,
    ModelId::EfficientNetLite0,
];

/// A random non-empty, duplicate-free subset of the pool.
fn random_models(rng: &mut Rng) -> Vec<ModelId> {
    let k = rng.usize(1, POOL.len());
    let start = rng.usize(0, POOL.len() - 1);
    (0..k).map(|i| POOL[(start + i) % POOL.len()]).collect()
}

fn makespan(completions: &[Completion]) -> u64 {
    completions.iter().map(|c| c.finish_cycles).max().unwrap_or(0)
}

#[test]
fn prop_conservation_every_admitted_request_completes_once() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(16, 0x5E41, |rng| {
        let models = random_models(rng);
        let n = rng.usize(1, 40);
        let instances = rng.usize(1, 5);
        let gap = rng.int(0, 2_000_000) as u64;
        let trace = synthetic_trace(&models, n, gap, rng.next_u64());
        let (completions, busy) = run_trace(&cfg, &trace, instances, &mut cache);

        assert_eq!(completions.len(), n, "every admitted request completes");
        let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "no request completes twice");
        assert_eq!(busy.len(), instances);
        for c in &completions {
            let req = trace[c.id as usize];
            assert_eq!(req.model, c.model);
            assert_eq!(req.arrival_cycles, c.arrival_cycles);
            assert!(c.start_cycles >= c.arrival_cycles, "no request starts before arrival");
            assert!(c.finish_cycles > c.start_cycles, "service time must be positive");
            assert!(c.instance < instances);
            assert_eq!(
                c.latency_cycles(),
                c.queue_cycles() + c.service_cycles(),
                "latency decomposes into queueing delay + service time"
            );
        }
    });
}

#[test]
fn prop_more_instances_never_increase_makespan() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(15, 0x9A7E, |rng| {
        let models = random_models(rng);
        let n = rng.usize(1, 30);
        let gap = rng.int(0, 1_500_000) as u64;
        let trace = synthetic_trace(&models, n, gap, rng.next_u64());
        let k = rng.usize(1, 4);
        let extra = rng.usize(1, 4);
        let (small, _) = run_trace(&cfg, &trace, k, &mut cache);
        let (big, _) = run_trace(&cfg, &trace, k + extra, &mut cache);
        assert!(
            makespan(&big) <= makespan(&small),
            "{} instances (makespan {}) vs {} instances (makespan {})",
            k + extra,
            makespan(&big),
            k,
            makespan(&small)
        );
        // Pointwise: with FIFO earliest-idle dispatch, extra instances can
        // only move every request earlier, never later.
        for (a, b) in small.iter().zip(big.iter()) {
            assert_eq!(a.id, b.id);
            assert!(
                b.finish_cycles <= a.finish_cycles,
                "request {} finished later with more instances",
                a.id
            );
        }
    });
}

#[test]
fn prop_cache_hit_is_bit_identical_to_cold_compile() {
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(15, 0xCAC4E, |rng| {
        // Cheapest three models — each case compiles twice (cache + cold).
        let model = *rng.choose(&POOL[..3]);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let miss = cache.get(model);
        let hit = cache.get(model);
        assert!(Arc::ptr_eq(&miss, &hit), "hit must return the cached entry");
        assert_eq!((cache.hits, cache.misses), (1, 1));

        // Bit-identical to an independent cold compile under the same
        // (deterministic, node-limited) options.
        let graph = model.build();
        let cold = compile(&graph, &cfg, &deterministic_compile_options());
        let cold_program = emit(&cold, &graph.name);
        assert_eq!(
            hit.program, cold_program,
            "{model:?}: cached program differs from cold compile"
        );
        // Re-emission from the cached mid-end artifact is also stable.
        assert_eq!(emit(&hit.compiled, &graph.name), hit.program);
    });
}

#[test]
fn prop_same_seed_produces_identical_reports() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    // Pre-warm so both runs of a pair observe identical cache deltas.
    for model in POOL {
        cache.get(model);
    }
    for_each_case(15, 0xD37, |rng| {
        let opts = ServeOptions {
            models: random_models(rng),
            requests: rng.usize(1, 30),
            instances: rng.usize(1, 4),
            mean_gap_cycles: rng.int(0, 1_000_000) as u64,
            seed: rng.next_u64(),
        };
        let a = serve_with_cache(&cfg, &opts, &mut cache);
        let b = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(a, b, "same seed + same trace must give identical ServeReport");
    });
}

/// The acceptance scenario from the issue: a 200-request mixed trace over
/// 3 zoo models and 2 virtual NPU instances, ≥50% cache hit rate, sane
/// percentiles, and cold-cache rerun reproducibility.
#[test]
fn acceptance_200_request_mixed_trace() {
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions::default();
    assert!(opts.models.len() >= 3);
    assert!(opts.instances >= 2);
    assert_eq!(opts.requests, 200);

    let r1 = serve(&cfg, &opts);
    assert_eq!(r1.requests, 200);
    assert_eq!(r1.cache_misses, opts.models.len() as u64);
    assert!(
        r1.cache_hit_rate() >= 0.5,
        "cache hit rate {:.2} below the 50% floor",
        r1.cache_hit_rate()
    );
    assert!(r1.p50_ms > 0.0);
    assert!(r1.p50_ms <= r1.p95_ms && r1.p95_ms <= r1.p99_ms);
    assert!(r1.throughput_inf_s > 0.0);
    assert!(r1.utilization() > 0.0 && r1.utilization() <= 1.0);
    assert_eq!(r1.per_model.iter().map(|m| m.requests).sum::<u64>(), 200);

    // Second cold-cache run: the whole report must reproduce bit-for-bit.
    let r2 = serve(&cfg, &opts);
    assert_eq!(r1, r2);

    let s = r1.summary();
    assert!(s.contains("p50") && s.contains("hit rate"));
}
