//! Integration + property tests for the multi-tenant serving layer:
//! conservation under shedding (completed + shed == offered, exactly
//! once each), scaling monotonicity (more instances never increase
//! makespan for the FIFO configuration), strict class ordering (absent
//! aging, lower-class work never dispatches while higher-class work
//! waits), batching neutrality (batching re-times requests, never changes
//! which requests complete), cache coherence (a hit is bit-identical to a
//! cold compile), and virtual-clock determinism (same seed + same options
//! → identical `ServeReport`, including shed sets and batch composition).

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::compile;
use eiq_neutron::coordinator::{emit, Executor};
use eiq_neutron::serve::{
    deterministic_compile_options, marginal_service_cycles, run_trace, serve, serve_with_cache,
    synthetic_trace, synthetic_trace_with_mix, AdmissionPolicy, Completion, CompileCache,
    PriorityMix, SchedulerOptions, ServeOptions, TraceOutcome,
};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Cheap zoo subset for property cases (each model compiles once per
/// cache, so shared caches keep the suite fast).
const POOL: [ModelId; 4] = [
    ModelId::MobileNetV1,
    ModelId::MobileNetV2,
    ModelId::MobileNetV3Min,
    ModelId::EfficientNetLite0,
];

/// A random non-empty, duplicate-free subset of the pool.
fn random_models(rng: &mut Rng) -> Vec<ModelId> {
    let k = rng.usize(1, POOL.len());
    let start = rng.usize(0, POOL.len() - 1);
    (0..k).map(|i| POOL[(start + i) % POOL.len()]).collect()
}

/// Random class weights with at least one non-zero entry.
fn random_mix(rng: &mut Rng) -> PriorityMix {
    let mut mix = PriorityMix {
        realtime: rng.usize(0, 2) as u32,
        standard: rng.usize(0, 2) as u32,
        batch: rng.usize(0, 2) as u32,
    };
    if mix.realtime + mix.standard + mix.batch == 0 {
        mix.standard = 1;
    }
    mix
}

/// Random scheduler knobs across the whole option space. The PR-7 knobs
/// (pipelining, weight residency, warm routing) stay at their off
/// defaults here — this suite pins down the baseline invariants, and the
/// differential suite (`executor_differential.rs`) owns the knobs-on
/// properties under the distributions where they provably hold.
fn random_scheduler(rng: &mut Rng) -> SchedulerOptions {
    SchedulerOptions {
        instances: rng.usize(1, 4),
        queue_capacity: if rng.bool() { Some(rng.usize(1, 8)) } else { None },
        policy: if rng.bool() {
            AdmissionPolicy::RejectNewest
        } else {
            AdmissionPolicy::DropOldest
        },
        max_batch: rng.usize(1, 4),
        dynamic_batch: rng.bool(),
        age_after_cycles: if rng.bool() { Some(rng.int(1, 500_000) as u64) } else { None },
        ..SchedulerOptions::default()
    }
}

fn makespan(completions: &[Completion]) -> u64 {
    completions.iter().map(|c| c.finish_cycles).max().unwrap_or(0)
}

/// Total instance-occupancy of a completion list: full service for batch
/// leaders and solo requests, marginal tail for followers (batches are
/// contiguous in dispatch order, leader first).
fn occupancy_total(completions: &[Completion]) -> u64 {
    completions
        .iter()
        .enumerate()
        .map(|(i, c)| {
            if c.batch_index == 0 {
                c.finish_cycles - c.start_cycles
            } else {
                c.finish_cycles - completions[i - 1].finish_cycles
            }
        })
        .sum()
}

#[test]
fn prop_conservation_offered_equals_completed_plus_shed() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(16, 0x5E41, |rng| {
        let models = random_models(rng);
        let n = rng.usize(1, 40);
        let sched = random_scheduler(rng);
        let gap = rng.int(0, 2_000_000) as u64;
        let mix = random_mix(rng);
        let trace = synthetic_trace_with_mix(&models, n, gap, rng.next_u64(), &mix);
        let outcome = run_trace(&cfg, &trace, &sched, &mut cache);

        // Every offered request either completes or is shed, exactly once.
        assert_eq!(
            outcome.completions.len() + outcome.shed.len(),
            n,
            "completed + shed must equal offered"
        );
        let mut ids: Vec<u64> = outcome
            .completions
            .iter()
            .map(|c| c.id)
            .chain(outcome.shed.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "ids partition the trace");
        if sched.queue_capacity.is_none() {
            assert!(outcome.shed.is_empty(), "an unbounded queue never sheds");
        }

        for c in &outcome.completions {
            let req = trace[c.id as usize];
            assert_eq!(req.model, c.model);
            assert_eq!(req.priority, c.priority);
            assert_eq!(req.arrival_cycles, c.arrival_cycles);
            assert!(c.start_cycles >= c.arrival_cycles, "no request starts before arrival");
            assert!(c.finish_cycles > c.start_cycles, "service time must be positive");
            assert!(c.instance < sched.instances);
            assert!((c.batch_index as usize) < sched.max_batch);
            assert_eq!(
                c.latency_cycles(),
                c.queue_cycles() + c.service_cycles(),
                "latency decomposes into queueing delay + service time"
            );
        }
        assert_eq!(outcome.per_instance_busy_cycles.len(), sched.instances);
        assert_eq!(
            occupancy_total(&outcome.completions),
            outcome.per_instance_busy_cycles.iter().sum::<u64>(),
            "per-completion occupancy must sum to per-instance busy cycles"
        );
    });
}

#[test]
fn prop_more_instances_never_increase_makespan() {
    // The pointwise claim is specific to the FIFO configuration (single
    // class, no batching, unbounded queue): extra instances can only move
    // every request earlier. Priority reordering and batch coalescing
    // intentionally trade individual finish times, so the claim is not
    // made for them.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(15, 0x9A7E, |rng| {
        let models = random_models(rng);
        let n = rng.usize(1, 30);
        let gap = rng.int(0, 1_500_000) as u64;
        let trace = synthetic_trace(&models, n, gap, rng.next_u64());
        let k = rng.usize(1, 4);
        let extra = rng.usize(1, 4);
        let small_opts = SchedulerOptions { instances: k, ..SchedulerOptions::default() };
        let big_opts = SchedulerOptions { instances: k + extra, ..SchedulerOptions::default() };
        let small = run_trace(&cfg, &trace, &small_opts, &mut cache).completions;
        let big = run_trace(&cfg, &trace, &big_opts, &mut cache).completions;
        assert!(
            makespan(&big) <= makespan(&small),
            "{} instances (makespan {}) vs {} instances (makespan {})",
            k + extra,
            makespan(&big),
            k,
            makespan(&small)
        );
        for (a, b) in small.iter().zip(big.iter()) {
            assert_eq!(a.id, b.id, "FIFO dispatch order is the admission order");
            assert!(
                b.finish_cycles <= a.finish_cycles,
                "request {} finished later with more instances",
                a.id
            );
        }
    });
}

#[test]
fn prop_higher_class_never_waits_behind_later_lower_class_dispatch() {
    // Absent aging, the scheduler must never dispatch a lower-class
    // request while a higher-class request that has already arrived is
    // still waiting — in particular a `Realtime` request never waits
    // behind a later-admitted `Batch` request. Batching cannot leak
    // around this: followers share their leader's class.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(12, 0xB477, |rng| {
        let models = random_models(rng);
        let n = rng.usize(2, 50);
        let gap = rng.int(0, 800_000) as u64;
        let mix = PriorityMix { realtime: 1, standard: 1, batch: 1 };
        let trace = synthetic_trace_with_mix(&models, n, gap, rng.next_u64(), &mix);
        let sched = SchedulerOptions {
            age_after_cycles: None,
            ..random_scheduler(rng)
        };
        let outcome = run_trace(&cfg, &trace, &sched, &mut cache);
        for hi in &outcome.completions {
            for lo in &outcome.completions {
                if hi.priority.rank() < lo.priority.rank() {
                    // `hi` had arrived strictly before `lo` was dispatched
                    // yet started strictly after it: a class inversion.
                    assert!(
                        !(hi.arrival_cycles < lo.start_cycles
                            && hi.start_cycles > lo.start_cycles),
                        "{:?} request {} (arrival {}, start {}) waited behind {:?} \
                         request {} dispatched at {}",
                        hi.priority,
                        hi.id,
                        hi.arrival_cycles,
                        hi.start_cycles,
                        lo.priority,
                        lo.id,
                        lo.start_cycles
                    );
                }
            }
        }
    });
}

#[test]
fn prop_batching_never_changes_which_requests_complete() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(10, 0xBA7C, |rng| {
        let models = random_models(rng);
        let n = rng.usize(2, 40);
        // Tight gaps so backlog builds and batching actually engages.
        let gap = rng.int(0, 300_000) as u64;
        let mix = random_mix(rng);
        let trace = synthetic_trace_with_mix(&models, n, gap, rng.next_u64(), &mix);
        let instances = rng.usize(1, 3);
        let unbatched_opts = SchedulerOptions { instances, ..SchedulerOptions::default() };
        let batched_opts = SchedulerOptions {
            instances,
            max_batch: rng.usize(2, 6),
            ..SchedulerOptions::default()
        };
        let unbatched = run_trace(&cfg, &trace, &unbatched_opts, &mut cache);
        let batched = run_trace(&cfg, &trace, &batched_opts, &mut cache);

        let ids = |o: &TraceOutcome| {
            let mut v: Vec<u64> = o.completions.iter().map(|c| c.id).collect();
            v.sort_unstable();
            v
        };
        // With an unbounded queue everything completes either way: batching
        // may only change WHEN requests finish, never WHICH finish.
        assert_eq!(unbatched.completions.len(), n);
        assert_eq!(ids(&unbatched), ids(&batched));
        assert!(unbatched.completions.iter().all(|c| c.batch_index == 0));
        // Followers pay the marginal service time, so batching can only
        // reduce the total cycles instances spend occupied.
        assert!(occupancy_total(&batched.completions) <= occupancy_total(&unbatched.completions));
    });
}

#[test]
fn prop_dynamic_batching_is_neutral_and_bounded_by_the_static_ceiling() {
    // Dynamic batch sizing (ceiling scales with queue depth) keeps both
    // batching invariants: it never changes WHICH requests complete (only
    // when), never exceeds the static max_batch ceiling, and — like the
    // serve suite's other knobs — is deterministic under a fixed seed.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(10, 0xD1BA, |rng| {
        let models = random_models(rng);
        let n = rng.usize(2, 40);
        let gap = rng.int(0, 300_000) as u64;
        let mix = random_mix(rng);
        let trace = synthetic_trace_with_mix(&models, n, gap, rng.next_u64(), &mix);
        let instances = rng.usize(1, 3);
        let max_batch = rng.usize(2, 6);
        let static_opts = SchedulerOptions {
            instances,
            max_batch,
            dynamic_batch: false,
            ..SchedulerOptions::default()
        };
        let dynamic_opts = SchedulerOptions { dynamic_batch: true, ..static_opts.clone() };
        let fixed = run_trace(&cfg, &trace, &static_opts, &mut cache);
        let dynamic = run_trace(&cfg, &trace, &dynamic_opts, &mut cache);

        let ids = |o: &TraceOutcome| {
            let mut v: Vec<u64> = o.completions.iter().map(|c| c.id).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(ids(&fixed), ids(&dynamic), "dynamic sizing only re-times requests");
        assert!(
            dynamic.completions.iter().all(|c| (c.batch_index as usize) < max_batch),
            "the static knob stays the ceiling"
        );
        // Batching (dynamic or static) only ever removes parameter-fetch
        // work, so neither run can occupy instances longer than a
        // batching-free one.
        let plain_opts = SchedulerOptions {
            instances,
            ..SchedulerOptions::default()
        };
        let plain = run_trace(&cfg, &trace, &plain_opts, &mut cache);
        assert!(occupancy_total(&dynamic.completions) <= occupancy_total(&plain.completions));
        assert!(occupancy_total(&fixed.completions) <= occupancy_total(&plain.completions));
        // Determinism: the same trace + knobs reproduce the run exactly.
        let again = run_trace(&cfg, &trace, &dynamic_opts, &mut cache);
        assert_eq!(dynamic, again);
    });
}

#[test]
fn batching_saturated_single_instance_cuts_makespan() {
    // Deterministic overload shape: 12 same-model, same-class requests all
    // arriving at cycle 0 on one instance, batches of up to 4. The first
    // request dispatches solo before the backlog exists ("service precedes
    // admission at equal times"); the remaining 11 queue up and coalesce
    // into batches of 4 + 4 + 3, so the batched makespan is exactly
    // 4·full + 8·marginal vs 12·full unbatched.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    let model = ModelId::MobileNetV3Min;
    let trace = synthetic_trace(&[model], 12, 0, 9);
    assert!(trace.iter().all(|r| r.arrival_cycles == 0));

    let solo_opts = SchedulerOptions { instances: 1, ..SchedulerOptions::default() };
    let batch_opts = SchedulerOptions { instances: 1, max_batch: 4, ..SchedulerOptions::default() };
    let solo = run_trace(&cfg, &trace, &solo_opts, &mut cache);
    let batched = run_trace(&cfg, &trace, &batch_opts, &mut cache);

    let entry = cache.get(model);
    let full = Executor::with_config(cfg.clone())
        .run_program(&entry.program, None)
        .unwrap()
        .sim_cycles;
    let marginal = marginal_service_cycles(&entry.program).max(1);
    assert!(marginal <= full);

    assert_eq!(makespan(&solo.completions), 12 * full);
    assert_eq!(makespan(&batched.completions), 4 * full + 8 * marginal);
    assert_eq!(batched.completions.iter().filter(|c| c.batch_index > 0).count(), 8);
    if marginal < full {
        assert!(
            makespan(&batched.completions) < makespan(&solo.completions),
            "batching must cut the saturated makespan when followers are cheaper"
        );
    }
}

#[test]
fn prop_cache_hit_is_bit_identical_to_cold_compile() {
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(15, 0xCAC4E, |rng| {
        // Cheapest three models — each case compiles twice (cache + cold).
        let model = *rng.choose(&POOL[..3]);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let miss = cache.get(model);
        let hit = cache.get(model);
        assert!(Arc::ptr_eq(&miss, &hit), "hit must return the cached entry");
        assert_eq!((cache.hits, cache.misses), (1, 1));

        // Bit-identical to an independent cold compile under the same
        // (deterministic, node-limited) options.
        let graph = model.build();
        let cold = compile(&graph, &cfg, &deterministic_compile_options());
        let cold_program = emit(&cold, &graph.name);
        assert_eq!(
            hit.program, cold_program,
            "{model:?}: cached program differs from cold compile"
        );
        // Re-emission from the cached mid-end artifact is also stable.
        assert_eq!(emit(&hit.compiled, &graph.name), hit.program);
    });
}

#[test]
fn prop_same_seed_produces_identical_reports() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    // Pre-warm so both runs of a pair observe identical cache deltas.
    for model in POOL {
        cache.get(model);
    }
    for_each_case(15, 0xD37, |rng| {
        let opts = ServeOptions {
            models: random_models(rng),
            requests: rng.usize(1, 30),
            mean_gap_cycles: rng.int(0, 1_000_000) as u64,
            seed: rng.next_u64(),
            priority_mix: random_mix(rng),
            scheduler: random_scheduler(rng),
            ..ServeOptions::default()
        };
        let a = serve_with_cache(&cfg, &opts, &mut cache);
        let b = serve_with_cache(&cfg, &opts, &mut cache);
        assert_eq!(
            a, b,
            "same seed + same trace + same scheduler options must give identical ServeReport"
        );
    });
}

/// The acceptance scenario: a 200-request mixed-class trace over 3 zoo
/// models and 2 virtual NPU instances, ≥50% cache hit rate, sane
/// percentiles, no shedding with the default unbounded queue, and
/// cold-cache rerun reproducibility.
#[test]
fn acceptance_200_request_mixed_trace() {
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions::default();
    assert!(opts.models.len() >= 3);
    assert!(opts.scheduler.instances >= 2);
    assert_eq!(opts.requests, 200);

    let r1 = serve(&cfg, &opts);
    assert_eq!(r1.offered, 200);
    assert_eq!(r1.completed, 200);
    assert_eq!(r1.shed, 0, "the default unbounded queue never sheds");
    assert_eq!(r1.cache_misses, opts.models.len() as u64);
    assert!(
        r1.cache_hit_rate() >= 0.5,
        "cache hit rate {:.2} below the 50% floor",
        r1.cache_hit_rate()
    );
    assert!(r1.p50_ms > 0.0);
    assert!(r1.p50_ms <= r1.p95_ms && r1.p95_ms <= r1.p99_ms);
    assert!(r1.goodput_inf_s > 0.0);
    assert!(r1.offered_load_inf_s > 0.0);
    assert!(r1.utilization() > 0.0 && r1.utilization() <= 1.0);
    assert_eq!(r1.per_model.iter().map(|m| m.requests).sum::<u64>(), 200);
    assert_eq!(r1.per_class.iter().map(|c| c.completed).sum::<u64>(), 200);
    assert_eq!(r1.per_class.iter().map(|c| c.shed).sum::<u64>(), 0);

    // Second cold-cache run: the whole report must reproduce bit-for-bit.
    let r2 = serve(&cfg, &opts);
    assert_eq!(r1, r2);

    let s = r1.summary();
    assert!(s.contains("p50") && s.contains("hit rate"));
    assert!(s.contains("goodput") && s.contains("shed"));
}

/// Flaky-guard for the persistent artifact store: same-seed determinism
/// extends across a server restart through `--artifact-dir`. Run 1 serves
/// on a cache pre-warmed by compiling + saving every model (the cold
/// start); run 2 pre-warms a fresh cache purely from the `.npu` files on
/// disk (the restart). Both runs must produce a bit-identical
/// `ServeReport` — including the cache counters, because pre-warming
/// happens before the serve loop snapshots them — and the restarted run
/// must perform zero CP solves.
#[test]
fn artifact_dir_restart_reproduces_the_report_with_zero_cold_compiles() {
    use eiq_neutron::runtime::{options_fingerprint, ArtifactStore};

    let cfg = NeutronConfig::flagship_2tops();
    let dir = std::env::temp_dir().join(format!("eiq_serve_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ArtifactStore::open(&dir).unwrap();
    let fp = options_fingerprint(&deterministic_compile_options());
    let opts = ServeOptions {
        models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
        requests: 40,
        mean_gap_cycles: 400_000,
        seed: 11,
        scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
        ..ServeOptions::default()
    };

    // Run 1 (cold start): compile every model, save the artifacts.
    let mut cold_cache = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        let calibration = cold_cache.default_calibration().clone();
        let entry = cold_cache.get_with_calibration(model, &cfg, &calibration);
        store.save(model, &cfg, &entry.compiled, fp).unwrap();
    }
    let compiles_before_serving = cold_cache.misses;
    let cold_report = serve_with_cache(&cfg, &opts, &mut cold_cache);
    assert_eq!(compiles_before_serving, opts.models.len() as u64);

    // Run 2 (restart): a fresh cache warmed purely from disk.
    let mut warm_cache = CompileCache::for_serving(cfg.clone());
    for &model in &opts.models {
        let calibration = warm_cache.default_calibration().clone();
        let compiled = store.load(model, &cfg, &calibration, fp).unwrap();
        warm_cache.insert_artifact(model, &cfg, compiled);
    }
    assert_eq!(warm_cache.misses, 0, "restart must not run the CP solver");
    let warm_report = serve_with_cache(&cfg, &opts, &mut warm_cache);
    assert_eq!(warm_cache.misses, 0, "serving on a warmed cache must stay solver-free");

    assert_eq!(
        cold_report, warm_report,
        "disk-warmed restart must reproduce the cold run's report bit for bit"
    );
    assert_eq!(warm_report.cache_misses, 0);
    assert_eq!(warm_report.cache_hits, cold_report.cache_hits);

    let _ = std::fs::remove_dir_all(&dir);
}
