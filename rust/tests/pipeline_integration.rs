//! Integration tests across the whole compiler + simulator stack,
//! including property-based invariants driven by the in-tree prop harness.

use eiq_neutron::arch::{Format, NeutronConfig};
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::coordinator::{emit, Executor};
use eiq_neutron::ir::{Activation, ConvGeometry, GraphBuilder, Padding};
use eiq_neutron::sim::{simulate, SimOptions};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Random small CNNs: the whole pipeline must hold its invariants on
/// arbitrary (valid) graphs, not just the zoo.
fn random_cnn(rng: &mut Rng) -> eiq_neutron::ir::Graph {
    let hw = *rng.choose(&[16usize, 32, 56, 64]);
    let mut b = GraphBuilder::with_input("prop_cnn", hw, hw, rng.usize(1, 8));
    let layers = rng.usize(2, 7);
    let mut residual_from = None;
    for i in 0..layers {
        let k = *rng.choose(&[1usize, 3, 5]);
        let s = *rng.choose(&[1usize, 1, 2]);
        let act = *rng.choose(&[Activation::Relu, Activation::Relu6, Activation::Swish]);
        if rng.f64() < 0.25 {
            b.dwconv(&format!("dw{i}"), ConvGeometry::square(k, s, Padding::Same), act);
        } else {
            let c = rng.usize(4, 96);
            b.conv(&format!("c{i}"), c, ConvGeometry::square(k, s, Padding::Same), act);
        }
        if rng.f64() < 0.2 {
            residual_from = Some(b.current());
        }
        if let Some(r) = residual_from {
            let cur = b.current();
            let (rs, cs) = {
                let g = &b.graph;
                (g.tensor(r).shape.clone(), g.tensor(cur).shape.clone())
            };
            if rs == cs && r != cur && rng.f64() < 0.5 {
                b.add(&format!("res{i}"), r, cur);
                residual_from = None;
            }
        }
    }
    b.global_avg_pool("gap");
    b.fc("fc", rng.usize(2, 20), Activation::None);
    b.finish()
}

#[test]
fn prop_pipeline_invariants_on_random_graphs() {
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(25, 0xC0FFEE, |rng| {
        let g = random_cnn(rng);
        g.validate().expect("generated graph must validate");
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());

        // Invariant 1: every compute step's inputs were produced/fetched
        // before its tick (checked structurally by the scheduler test; here
        // via simulation which recomputes residency).
        let r = simulate(&c, &cfg, &SimOptions::default());
        assert!(r.total_cycles > 0);

        // Invariant 2: simulated latency within 2x of compiler estimate.
        let ratio = r.latency_ms / c.inference_ms.max(1e-9);
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");

        // Invariant 3: effective TOPS never exceeds peak.
        let eff = r.effective_tops(g.total_macs());
        assert!(eff <= cfg.peak_tops() * 1.001, "eff {eff}");

        // Invariant 4: every tile placed by allocation fits the bank space.
        for p in c.allocation.placements.values() {
            assert!(p.first_bank < cfg.tcm_banks);
            assert!(p.first_bank + p.banks <= cfg.tcm_banks);
        }

        // Invariant 5: DAE ≤ serialized latency.
        let ser = simulate(&c, &cfg, &SimOptions { serialize_dae: true, ..Default::default() });
        assert!(r.total_cycles <= ser.total_cycles);
    });
}

#[test]
fn prop_format_choice_is_never_catastrophic() {
    // The DP trades per-layer optimality against format-conversion cost:
    // a layer may run in the locally-worse format when converting its
    // input would cost more than the difference. The bound is therefore
    // (best + conversion cost of its inputs), not best alone.
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(15, 0xF0F0, |rng| {
        let g = random_cnn(rng);
        let plan = eiq_neutron::compiler::select_formats(&g, &cfg);
        for op in &g.ops {
            let chosen =
                eiq_neutron::compiler::layer_latency_cycles(&g, op, &cfg, plan.format_of(op.id));
            let best = [Format::Depth, Format::Line]
                .into_iter()
                .map(|f| eiq_neutron::compiler::layer_latency_cycles(&g, op, &cfg, f))
                .min()
                .unwrap();
            let conv_slack: u64 = op
                .inputs
                .iter()
                .map(|&t| {
                    eiq_neutron::compiler::cost::format_switch_cycles(
                        g.tensor(t).padded_size_bytes(cfg.bus_bytes) as u64,
                        &cfg,
                    )
                })
                .sum();
            assert!(
                chosen <= best + conv_slack + 1000,
                "{}: chosen {chosen} vs best {best} (+slack {conv_slack})",
                op.name
            );
        }
    });
}

#[test]
fn coordinator_replays_all_zoo_models() {
    let cfg = NeutronConfig::flagship_2tops();
    for id in [ModelId::MobileNetV1, ModelId::MobileNetV3Min, ModelId::MobileNetV2Ssd] {
        let g = id.build();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, &g.name);
        let mut ex = Executor::new(cfg.clone(), p);
        let r = ex.run_request(None).unwrap();
        assert_eq!(r.sim_cycles, c.schedule.total_cycles(), "{id:?}");
    }
}

#[test]
fn scaling_with_cores_is_monotonic() {
    // More cores (same memory) must never be slower on a compute-heavy net.
    let g = ModelId::ResNet50V1.build();
    let mut last = f64::INFINITY;
    for cores in [1usize, 2, 4] {
        let cfg = NeutronConfig { cores, ..NeutronConfig::flagship_2tops() };
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let r = simulate(&c, &cfg, &SimOptions::default());
        assert!(
            r.latency_ms <= last * 1.05,
            "{cores} cores: {} vs previous {last}",
            r.latency_ms
        );
        last = r.latency_ms;
    }
}

#[test]
fn bigger_tcm_never_hurts() {
    let g = ModelId::YoloV8nDet.build();
    let small = NeutronConfig::flagship_2tops();
    let big = NeutronConfig { tcm_bytes: 2 << 20, tcm_banks: 64, ..small.clone() };
    let cs = compile(&g, &small, &CompileOptions::default_partitioned());
    let cb = compile(&g, &big, &CompileOptions::default_partitioned());
    let rs = simulate(&cs, &small, &SimOptions::default());
    let rb = simulate(&cb, &big, &SimOptions::default());
    assert!(
        rb.latency_ms <= rs.latency_ms * 1.1,
        "2 MiB TCM {} vs 1 MiB {}",
        rb.latency_ms,
        rs.latency_ms
    );
    // And it must cut DDR traffic (fewer spills).
    assert!(rb.ddr_bytes <= rs.ddr_bytes);
}
