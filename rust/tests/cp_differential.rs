//! Differential property suite: the incremental cached-activity propagation
//! engine must be **node-for-node equivalent** to the frozen recompute
//! oracle (`cp::reference`) — same `Status`, same objective, same
//! assignment, same explored-node count, same backtrack/peak-trail
//! accounting — on randomized linear models (feasible, infeasible, and
//! budget-limited) and on real compiler workloads.
//!
//! Why this holds by construction, and what "equivalent" deliberately does
//! NOT cover (the propagation-layer counters, which differ by design), is
//! documented in `docs/solver.md`. Every incremental run here also enables
//! `SearchConfig::validate`, which recomputes the cached activities from
//! scratch after **every backtrack** and panics on any divergence — the
//! trail-undo exactness check rides along with every case below.
//!
//! Differential comparisons pin `time_limit_ms: None`: wall-clock cutoffs
//! are the one config knob that could make two correct engines diverge
//! (they run at different speeds), so equivalence is only claimed — and
//! tested — under deterministic node budgets.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile_with_stats, CompileOptions};
use eiq_neutron::cp::{solve, CpModel, EngineKind, LinExpr, SearchConfig, Solution, Status};
use eiq_neutron::serve::deterministic_compile_options;
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// A random bounded-integer linear model: mixed-sign bounds and
/// coefficients, `≤`/`=`/`≥` constraints, optional objective. `=`
/// constraints with random right-hand sides make a healthy fraction of the
/// pool infeasible; nothing below assumes feasibility.
fn random_linear_model(rng: &mut Rng, max_vars: usize, max_width: i64) -> CpModel {
    let n = rng.usize(2, max_vars);
    let mut m = CpModel::new();
    let vars: Vec<_> = (0..n)
        .map(|i| {
            let lb = rng.int(-3, 2);
            m.int_var(lb, lb + rng.int(0, max_width), format!("x{i}"))
        })
        .collect();
    for _ in 0..rng.usize(1, n + 1) {
        let mut e = LinExpr::new();
        for &v in &vars {
            let c = rng.int(-3, 3);
            if c != 0 {
                e.push(c, v);
            }
        }
        if e.is_empty() {
            e.push(1, vars[0]);
        }
        let rhs = rng.int(-8, 8);
        match rng.usize(0, 2) {
            0 => m.add_le(e, rhs),
            1 => m.add_ge(e, rhs),
            _ => m.add_eq(e, rhs),
        }
    }
    if rng.bool() {
        let mut obj = LinExpr::new();
        for &v in &vars {
            obj.push(rng.int(-4, 4), v);
        }
        m.minimize(obj);
    }
    m
}

/// A random warm-start hint: valid assignments, out-of-bounds values and
/// wrong arities all occur. Both engines share one hint validator, so the
/// accept/reject decision — and `hints_rejected` — must agree exactly.
fn random_hint(rng: &mut Rng, m: &CpModel) -> Option<Vec<i64>> {
    match rng.usize(0, 3) {
        0 => None,
        1 => Some(vec![rng.int(-2, 2); m.num_vars() + rng.usize(0, 2)]),
        _ => Some((0..m.num_vars()).map(|_| rng.int(-4, 6)).collect()),
    }
}

/// Run both engines on the same (model, budget, hint) and assert the whole
/// search-level surface matches. The propagation-layer counters
/// (`propagations`, `tightenings`, `entailments`) are excluded on purpose:
/// entailment skipping makes the incremental engine visit *fewer*
/// constraints — that is the optimization — while the tree it explores
/// stays identical.
fn assert_engines_agree(m: &CpModel, node_limit: Option<u64>, hint: Option<Vec<i64>>) {
    let run = |engine: EngineKind, validate: bool| -> Solution {
        solve(
            m,
            SearchConfig {
                node_limit,
                time_limit_ms: None,
                hint: hint.clone(),
                validate,
                engine,
                ..SearchConfig::default()
            },
        )
    };
    let inc = run(EngineKind::Incremental, true);
    let oracle = run(EngineKind::Reference, false);
    let what = format!("node_limit={node_limit:?} hint={hint:?}");
    assert_eq!(inc.status, oracle.status, "status diverged ({what})");
    assert_eq!(inc.objective, oracle.objective, "objective diverged ({what})");
    assert_eq!(inc.assignment, oracle.assignment, "assignment diverged ({what})");
    assert_eq!(inc.nodes, oracle.nodes, "node count diverged ({what})");
    assert_eq!(inc.stats.nodes, inc.nodes, "stats.nodes must mirror Solution::nodes ({what})");
    assert_eq!(oracle.stats.nodes, oracle.nodes, "oracle stats.nodes must mirror nodes ({what})");
    assert_eq!(
        inc.stats.backtracks, oracle.stats.backtracks,
        "backtrack count diverged ({what})"
    );
    assert_eq!(
        inc.stats.peak_trail, oracle.stats.peak_trail,
        "peak trail diverged ({what})"
    );
    assert_eq!(
        inc.stats.hints_rejected, oracle.stats.hints_rejected,
        "hint accounting diverged ({what})"
    );
    // Whatever was found must actually satisfy the model — equivalence to
    // a wrong oracle would be vacuous.
    if let Some(a) = &inc.assignment {
        assert!(m.violated(a).is_none(), "solution violates the model ({what})");
    }
    // The oracle has no entailment machinery; the incremental engine must
    // never report entailments the reference could "miss" as extra nodes.
    assert_eq!(oracle.stats.entailments, 0, "oracle must report no entailments");
}

#[test]
fn engines_agree_on_random_models_with_unbounded_budgets() {
    // ≥200 models solved to completion: status is proven (Optimal or
    // Infeasible), so equivalence covers full trees including conflict-
    // heavy infeasible ones. Small sizes keep full enumeration cheap.
    let mut infeasible = 0u32;
    let mut feasible = 0u32;
    for_each_case(220, 0xd1ff_01, |rng| {
        let m = random_linear_model(rng, 4, 4);
        let hint = random_hint(rng, &m);
        assert_engines_agree(&m, None, hint);
        let s = solve(
            &m,
            SearchConfig { node_limit: None, time_limit_ms: None, ..Default::default() },
        );
        match s.status {
            Status::Infeasible => infeasible += 1,
            _ => feasible += 1,
        }
    });
    // The generator must actually exercise both regimes.
    assert!(infeasible >= 20, "only {infeasible} infeasible cases generated");
    assert!(feasible >= 20, "only {feasible} feasible cases generated");
}

#[test]
fn engines_agree_under_tight_node_budgets() {
    // Budget expiry paths: the limit must trip at the same node in both
    // engines, returning the same incumbent (or the same Unknown).
    for_each_case(120, 0xd1ff_02, |rng| {
        let m = random_linear_model(rng, 6, 6);
        let budget = rng.int(0, 400) as u64;
        let hint = random_hint(rng, &m);
        assert_engines_agree(&m, Some(budget), hint);
    });
}

#[test]
fn engines_agree_with_last_conflict_branching() {
    // The branching refinement changes the tree shape — but identically in
    // both engines, since the conflict signal (which branch failed
    // propagation) must itself be equivalent.
    for_each_case(80, 0xd1ff_03, |rng| {
        let m = random_linear_model(rng, 5, 4);
        let run = |engine: EngineKind| {
            solve(
                &m,
                SearchConfig {
                    node_limit: None,
                    time_limit_ms: None,
                    last_conflict: true,
                    validate: engine == EngineKind::Incremental,
                    engine,
                    ..SearchConfig::default()
                },
            )
        };
        let inc = run(EngineKind::Incremental);
        let oracle = run(EngineKind::Reference);
        assert_eq!(inc.status, oracle.status);
        assert_eq!(inc.objective, oracle.objective);
        assert_eq!(inc.assignment, oracle.assignment);
        assert_eq!(inc.nodes, oracle.nodes);
    });
}

/// Compiler-workload equivalence: compiling a zoo model with every CP pass
/// pinned to the reference oracle must reproduce the production plan
/// bit-for-bit (same tiled program, schedule ticks, placements, DDR
/// traffic). The deterministic serving budgets are node-limited with no
/// time limit, so the comparison is exact. The full-zoo sweep (all 13
/// models) lives in `benches/solver_hotpath.rs`, which additionally bounds
/// the node counts; here two cheap models keep the test suite fast.
#[test]
fn zoo_models_compile_identically_under_both_engines() {
    let cfg = NeutronConfig::flagship_2tops();
    for model in [ModelId::MobileNetV3Min, ModelId::EfficientNetLite0] {
        let g = model.build();
        let base = deterministic_compile_options();
        let with_engine = |engine: EngineKind| -> CompileOptions {
            let mut o = base.clone();
            o.tiling.solver.engine = engine;
            o.scheduling.solver.engine = engine;
            o.allocation_solver.engine = engine;
            o
        };
        let (inc, inc_stats) = compile_with_stats(&g, &cfg, &with_engine(EngineKind::Incremental));
        let (oracle, oracle_stats) =
            compile_with_stats(&g, &cfg, &with_engine(EngineKind::Reference));
        assert_eq!(inc.program, oracle.program, "{model:?}: tiled programs diverged");
        assert_eq!(inc.schedule.ticks, oracle.schedule.ticks, "{model:?}: schedules diverged");
        assert_eq!(inc.schedule.ddr, oracle.schedule.ddr, "{model:?}: DDR traffic diverged");
        assert_eq!(
            inc.allocation.placements, oracle.allocation.placements,
            "{model:?}: placements diverged"
        );
        assert_eq!(
            inc.allocation.v2p_updates, oracle.allocation.v2p_updates,
            "{model:?}: v2p updates diverged"
        );
        assert_eq!(
            inc.inference_ms.to_bits(),
            oracle.inference_ms.to_bits(),
            "{model:?}: latency bits diverged"
        );
        // Search-level accounting matches across the whole compile; the
        // propagation layer is where the engines are allowed to differ.
        assert_eq!(inc_stats.nodes, oracle_stats.nodes, "{model:?}: node counts diverged");
        assert_eq!(
            inc_stats.backtracks, oracle_stats.backtracks,
            "{model:?}: backtracks diverged"
        );
        assert_eq!(
            inc_stats.peak_trail, oracle_stats.peak_trail,
            "{model:?}: peak trail diverged"
        );
        assert_eq!(
            inc_stats.hints_rejected, oracle_stats.hints_rejected,
            "{model:?}: hint accounting diverged"
        );
        assert_eq!(oracle_stats.entailments, 0, "{model:?}: oracle reported entailments");
    }
}

/// The production default must BE the incremental engine — a regression
/// that silently flips the default would invalidate every benchmark claim.
#[test]
fn default_engine_is_incremental() {
    assert_eq!(SearchConfig::default().engine, EngineKind::Incremental);
    assert_eq!(EngineKind::default(), EngineKind::Incremental);
}
