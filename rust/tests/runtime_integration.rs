//! Integration tests over the PJRT runtime: load the AOT artifacts built by
//! `make artifacts` and verify the rust-side numerics match the manifest's
//! build-time expectations (which were themselves checked against the
//! pure-jnp oracle by aot.py / pytest). Skipped gracefully when artifacts
//! are absent.

use eiq_neutron::ir::Requant;
use eiq_neutron::runtime::{literal_i32_1d, literal_i8, literal_to_i32s, Manifest, Runtime};
use eiq_neutron::util::prop::Rng;

fn manifest() -> Option<Manifest> {
    // Tests run from the crate root; artifacts/ lives beside Cargo.toml.
    Manifest::load("artifacts").ok()
}

#[test]
fn kernel_artifact_matches_rust_requant_reference() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(m.artifact_path("kernel.path").unwrap()).unwrap();

    let km = m.get_usize("kernel.m").unwrap();
    let kk = m.get_usize("kernel.k").unwrap();
    let kn = m.get_usize("kernel.n").unwrap();
    let mult: i32 = m.get("kernel.multiplier").unwrap().parse().unwrap();
    let shift: i32 = m.get("kernel.shift").unwrap().parse().unwrap();
    let rq = Requant { multiplier: mult, shift };

    // Random operands generated on the rust side; the oracle is the rust
    // reference implementation of the same integer arithmetic.
    let mut rng = Rng::new(2024);
    let lhs: Vec<i8> = (0..km * kk).map(|_| rng.i8()).collect();
    let rhs: Vec<i8> = (0..kk * kn).map(|_| rng.i8()).collect();
    let bias: Vec<i32> = (0..kn).map(|_| rng.int(-4096, 4096) as i32).collect();

    let out = exe
        .run(&[
            literal_i8(&lhs, &[km, kk]).unwrap(),
            literal_i8(&rhs, &[kk, kn]).unwrap(),
            literal_i32_1d(&bias).unwrap(),
        ])
        .unwrap();
    let got = literal_to_i32s(&out[0]).unwrap();
    assert_eq!(got.len(), km * kn);

    // Rust-side oracle.
    for mi in 0..km {
        for ni in 0..kn {
            let mut acc: i64 = bias[ni] as i64;
            for ki in 0..kk {
                acc += lhs[mi * kk + ki] as i64 * rhs[ki * kn + ni] as i64;
            }
            let want = rq.apply(acc as i32).clamp(-128, 127);
            let got_v = got[mi * kn + ni];
            assert_eq!(
                got_v, want,
                "mismatch at ({mi},{ni}): pjrt={got_v} rust={want}"
            );
        }
    }
}

#[test]
fn model_artifact_runs_and_is_deterministic() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(m.artifact_path("model.path").unwrap()).unwrap();
    let shape: Vec<usize> = m
        .get("model.input_shape")
        .unwrap()
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();
    let n: usize = shape.iter().product();
    let classes = m.get_usize("model.num_classes").unwrap();

    let input = eiq_neutron::runtime::deterministic_i8(7, n);
    let a = literal_to_i32s(&exe.run(&[literal_i8(&input, &shape).unwrap()]).unwrap()[0]).unwrap();
    let b = literal_to_i32s(&exe.run(&[literal_i8(&input, &shape).unwrap()]).unwrap()[0]).unwrap();
    assert_eq!(a, b, "model execution must be deterministic");
    assert_eq!(a.len(), classes);
    // Different inputs produce different logits (the artifact is not a
    // constant function).
    let input2 = eiq_neutron::runtime::deterministic_i8(8, n);
    let c = literal_to_i32s(&exe.run(&[literal_i8(&input2, &shape).unwrap()]).unwrap()[0]).unwrap();
    assert_ne!(a, c);
}

#[test]
fn manifest_expected_logits_are_wellformed() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let logits = m.get_i32s("model.expected_logits").unwrap();
    assert_eq!(logits.len(), m.get_usize("model.num_classes").unwrap());
    let row0 = m.get_i32s("kernel.expected_row0").unwrap();
    assert!(row0.iter().all(|&v| (-128..=127).contains(&v)));
}
