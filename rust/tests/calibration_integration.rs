//! Integration properties of calibration-aware compilation: identity
//! transparency (the refactored mid-end reproduces the pre-calibration
//! compiler bit for bit), calibration-keyed compile caching, replay
//! speed-scaling determinism and the closed tune loop.

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions, CostCalibration};
use eiq_neutron::coordinator::emit;
use eiq_neutron::ir::OpClass;
use eiq_neutron::serve::{
    calibration_fingerprint, deterministic_compile_options, marginal_service_cycles,
    CompileCache, SchedulerOptions, ServeOptions,
};
use eiq_neutron::trace::{serve_recorded, tune_from_trace, ReplayDriver, ReplayOptions, Trace};
use eiq_neutron::zoo::ModelId;

fn small_serve(seed: u64) -> ServeOptions {
    ServeOptions {
        models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
        requests: 12,
        mean_gap_cycles: 250_000,
        seed,
        scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
        ..ServeOptions::default()
    }
}

fn record(cfg: &NeutronConfig, seed: u64) -> Trace {
    let mut cache = CompileCache::for_serving(cfg.clone());
    serve_recorded(cfg, &small_serve(seed), &mut cache).1
}

/// With an identity calibration — implicit, explicit, or explicit with
/// redundant 1.0 entries — `compile` must produce a bit-identical
/// artifact to the pre-refactor path: same schedule cycles, same
/// allocation, same emitted job program, same `inference_ms` bits.
/// (Deterministic node-limited solver budgets, as serving uses: the
/// property quantifies over models and identity spellings.)
#[test]
fn identity_calibration_compiles_bit_identically() {
    let cfg = NeutronConfig::flagship_2tops();
    for model in [ModelId::MobileNetV3Min, ModelId::MobileNetV2, ModelId::EfficientNetLite0] {
        let g = model.build();
        let baseline = compile(&g, &cfg, &deterministic_compile_options());
        let identities = [
            CostCalibration::identity(),
            CostCalibration::from_scales(&[(OpClass::Conv, 1.0)]),
            CostCalibration::from_scales(&OpClass::all().map(|c| (c, 1.0))),
        ];
        for cal in identities {
            let opts = CompileOptions { calibration: cal, ..deterministic_compile_options() };
            let c = compile(&g, &cfg, &opts);
            assert_eq!(
                c.schedule.total_cycles(),
                baseline.schedule.total_cycles(),
                "{model:?}: schedule cycles drifted under identity calibration"
            );
            assert_eq!(
                c.inference_ms.to_bits(),
                baseline.inference_ms.to_bits(),
                "{model:?}: inference_ms drifted under identity calibration"
            );
            assert_eq!(
                c.allocation.placements, baseline.allocation.placements,
                "{model:?}: allocation drifted under identity calibration"
            );
            assert_eq!(
                emit(&c, "m"),
                emit(&baseline, "m"),
                "{model:?}: emitted job program drifted under identity calibration"
            );
        }
    }
}

/// Distinct calibrations get distinct cache entries; identical effective
/// calibrations — whatever their spelling — hit the same entry.
#[test]
fn cache_keys_isolate_calibrations_and_dedupe_spellings() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    let model = ModelId::MobileNetV3Min;

    let plain = cache.get(model);
    let cal_a = CostCalibration::from_scales(&[(OpClass::Conv, 1.5)]);
    let cal_b = CostCalibration::from_scales(&[(OpClass::Conv, 2.0)]);
    let a = cache.get_with_calibration(model, &cfg, &cal_a);
    let b = cache.get_with_calibration(model, &cfg, &cal_b);
    assert_eq!(cache.len(), 3, "identity + two fitted calibrations coexist");
    assert!(!Arc::ptr_eq(&plain, &a) && !Arc::ptr_eq(&a, &b));
    assert_eq!(cache.misses, 3);
    assert_eq!(cache.hits, 0);

    // A different spelling of cal_a (same effective scales, extra 1.0
    // entries) is the same key.
    let respelled = CostCalibration::from_scales(&[(OpClass::Pool, 1.0), (OpClass::Conv, 1.5)]);
    assert_eq!(calibration_fingerprint(&cal_a), calibration_fingerprint(&respelled));
    let again = cache.get_with_calibration(model, &cfg, &respelled);
    assert!(Arc::ptr_eq(&a, &again), "respelled calibration must hit");
    assert_eq!(cache.hits, 1);

    // The calibrated artifacts really were priced differently: scaling
    // Conv changes some compute job's cycles, so the emitted programs
    // cannot coincide.
    assert_ne!(a.program, plain.program, "Conv×1.5 left the job program unchanged");
    assert_ne!(b.program, a.program, "Conv×2.0 equals Conv×1.5's job program");
    assert_eq!(a.compiled.calibration, cal_a);
    // And every cost consumer reads the same artifact: the batch-marginal
    // price derives from the same calibrated job program, so it can never
    // exceed the full calibrated service time.
    assert!(
        marginal_service_cycles(&a.program) <= a.program.service_cycles_where(|_| true)
    );
}

/// Replay speed-scaling: deterministic, monotone in offered load, and a
/// no-op at speed 1 — across several recorded traces.
#[test]
fn replay_speed_scaling_is_deterministic_and_monotone() {
    let cfg = NeutronConfig::flagship_2tops();
    for seed in [3u64, 29] {
        let trace = record(&cfg, seed);
        let span = trace.requests.last().unwrap().arrival_cycles;
        assert!(span > 1_000, "seed {seed}: degenerate arrival span {span}");
        let driver = ReplayDriver::new(trace);
        let base = driver.replay(&cfg).unwrap();
        assert!(base.matches_recording());

        // Warm cache shared across the sweep: a replay's scheduling
        // decisions are cache-independent, so only the hit/miss counters
        // differ — and the determinism check replays twice on equally
        // warm caches.
        let mut warm = CompileCache::for_serving(cfg.clone());
        let mut last_load = 0.0f64;
        for speed in [0.5, 1.0, 2.0, 4.0] {
            let opts = ReplayOptions { speed, ..ReplayOptions::default() };
            let a = driver.replay_with_options_cached(&cfg, &opts, &mut warm).unwrap();
            let b = driver.replay_with_options_cached(&cfg, &opts, &mut warm).unwrap();
            assert_eq!(
                a.report.makespan_cycles, b.report.makespan_cycles,
                "seed {seed} speed {speed}: non-deterministic makespan"
            );
            assert_eq!(a.report.p99_ms.to_bits(), b.report.p99_ms.to_bits());
            assert_eq!(a.report.offered, base.report.offered);
            assert!(
                a.report.offered_load_inf_s >= last_load,
                "seed {seed} speed {speed}: offered load not monotone"
            );
            last_load = a.report.offered_load_inf_s;
            if speed == 1.0 {
                assert_eq!(
                    a.report.makespan_cycles, base.report.makespan_cycles,
                    "speed 1.0 must reproduce the faithful replay's timing"
                );
            }
        }
        // Doubling the rate strictly raises offered load on a real span.
        let fast = driver
            .replay_with_options_cached(
                &cfg,
                &ReplayOptions { speed: 2.0, ..ReplayOptions::default() },
                &mut warm,
            )
            .unwrap();
        assert!(fast.report.offered_load_inf_s > base.report.offered_load_inf_s);
    }
}

/// The closed loop end-to-end: record → fit → recompile → replay. The
/// guard makes the fit improve (or leave) every kept class on the
/// recorded data; the tune outcome reports both sides and stays
/// deterministic.
#[test]
fn tune_loop_closes_over_a_recorded_trace() {
    let cfg = NeutronConfig::flagship_2tops();
    let trace = record(&cfg, 11);
    let outcome = tune_from_trace(&cfg, &trace).unwrap();
    assert!(outcome.mape_before_pct().is_finite() && outcome.mape_before_pct() >= 0.0);
    assert!(outcome.mape_after_pct().is_finite() && outcome.mape_after_pct() >= 0.0);
    assert!(outcome.report_after.makespan_cycles > 0);
    assert_eq!(
        outcome.report_before.offered, outcome.report_after.offered,
        "tune replays the same offered requests"
    );
    // Every scale the guard kept is clamped and improving-on-recorded.
    for &(class, scale) in outcome.calibration.scales() {
        assert!((CostCalibration::MIN_SCALE..=CostCalibration::MAX_SCALE).contains(&scale));
        let row = outcome.before.rows.iter().find(|r| r.class == class).unwrap();
        assert!(row.post_fit_mape_pct <= row.mape_pct, "{class:?} kept a worsening fit");
    }
    // Determinism of the whole loop.
    let again = tune_from_trace(&cfg, &trace).unwrap();
    assert_eq!(outcome.summary_line(), again.summary_line());
    assert_eq!(outcome.report_after, again.report_after);
}
