//! Integration + property tests for the energy accounting subsystem
//! (PR 9): exact femtojoule conservation across random schedules, strict
//! knobs-off neutrality on the full `ServeReport`, v4 trace round-trips
//! with record → replay energy bit-identity, class-ordered budget
//! shedding end to end, and the improve-only energy calibration fit with
//! its fingerprint-pinned file format.

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::energy::{EnergyCalibrationFile, EnergyChannel, EnergyMode};
use eiq_neutron::serve::{
    run_trace, synthetic_trace_with_mix, CompileCache, Priority, PriorityMix, SchedulerOptions,
    ServeOptions,
};
use eiq_neutron::trace::{serve_recorded, tune_energy_from_trace, EnergyFitReport, ReplayDriver};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Cheap zoo subset (mirrors the trace suite's pool).
const POOL: [ModelId; 3] =
    [ModelId::MobileNetV1, ModelId::MobileNetV3Min, ModelId::EfficientNetLite0];

fn random_energy_options(rng: &mut Rng) -> ServeOptions {
    let k = rng.usize(1, POOL.len());
    let start = rng.usize(0, POOL.len() - 1);
    let mut opts = ServeOptions {
        models: (0..k).map(|i| POOL[(start + i) % POOL.len()]).collect(),
        requests: rng.usize(1, 20),
        mean_gap_cycles: rng.int(0, 800_000) as u64,
        seed: rng.next_u64(),
        priority_mix: PriorityMix { realtime: 1, standard: 2, batch: 1 },
        scheduler: SchedulerOptions {
            instances: rng.usize(1, 3),
            max_batch: rng.usize(1, 4),
            energy: true,
            energy_mode: if rng.bool() { EnergyMode::Stretch } else { EnergyMode::RaceToIdle },
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    };
    // A quarter of the cases exercise decode pricing end to end.
    if rng.usize(0, 3) == 0 {
        opts.models = vec![ModelId::GptTiny];
        opts.requests = rng.usize(1, 6);
        opts.decode = true;
        opts.prompt_tokens = rng.usize(1, 8) as u32;
        opts.decode_tokens = rng.usize(1, 6) as u32;
        opts.max_context = 16;
        opts.scheduler.continuous_batch = rng.bool();
    }
    opts
}

#[test]
fn prop_energy_is_exactly_conserved_across_random_schedules() {
    // compute + dma + idle == total, in integer femtojoules, for the
    // fleet report of every random schedule — batching, stretch mode and
    // decode included. Conservation is exact, not approximate: the whole
    // pipeline is u64 arithmetic.
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(12, 0x0E9E51, |rng| {
        let opts = random_energy_options(rng);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (report, trace) = serve_recorded(&cfg, &opts, &mut cache);
        assert_eq!(
            report.energy_compute_fj + report.energy_dma_fj + report.energy_idle_fj,
            report.energy_total_fj,
            "fleet conservation must be exact"
        );
        if report.completed > 0 {
            assert!(report.energy_total_fj > 0, "leakage floors every metered run above 0");
            assert!(report.joules_per_inference > 0.0);
        }
        // Per-completion attribution sums to the fleet total minus the
        // report-level inter-dispatch idle pricing — i.e. never exceeds
        // the total, and matches the recorded trace exactly.
        let completion_sum: u64 = trace
            .completions
            .iter()
            .map(|c| c.energy_compute_fj + c.energy_dma_fj + c.energy_idle_fj)
            .sum();
        assert!(completion_sum <= report.energy_total_fj);
        assert_eq!(
            trace.completions.iter().map(|c| c.energy_compute_fj).sum::<u64>(),
            report.energy_compute_fj
        );
        assert_eq!(
            trace.completions.iter().map(|c| c.energy_dma_fj).sum::<u64>(),
            report.energy_dma_fj
        );
    });
}

#[test]
fn prop_energy_off_is_bit_transparent_on_the_full_report() {
    // With the meter off (the default), the entire ServeReport — every
    // counter, every f64 percentile — is bit-identical to a metered run
    // of the same workload with its energy fields zeroed: pricing is pure
    // observation and moves nothing else.
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(10, 0x0FF0, |rng| {
        let on_opts = random_energy_options(rng);
        // Stretch changes dispatch decisions by design; neutrality is
        // only claimed for the meter itself.
        let mut on_opts = on_opts;
        on_opts.scheduler.energy_mode = EnergyMode::RaceToIdle;
        let mut off_opts = on_opts.clone();
        off_opts.scheduler.energy = false;

        let mut cache = CompileCache::for_serving(cfg.clone());
        let (on, _) = serve_recorded(&cfg, &on_opts, &mut cache);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (off, off_trace) = serve_recorded(&cfg, &off_opts, &mut cache);

        assert_eq!(off.energy_total_fj, 0);
        assert_eq!(off.joules_per_inference, 0.0);
        assert_eq!(off.joules_per_token, 0.0);
        assert!(!off.summary().contains("energy:"), "no meter, no summary line");
        assert!(off_trace.completions.iter().all(|c| {
            c.energy_compute_fj == 0 && c.energy_dma_fj == 0 && c.energy_idle_fj == 0
        }));

        let mut neutralized = on.clone();
        neutralized.energy_total_fj = 0;
        neutralized.energy_compute_fj = 0;
        neutralized.energy_dma_fj = 0;
        neutralized.energy_idle_fj = 0;
        neutralized.joules_per_inference = 0.0;
        neutralized.joules_per_token = 0.0;
        assert_eq!(neutralized, off, "the meter must not move any non-energy field");
    });
}

#[test]
fn prop_metered_traces_replay_their_energy_bit_for_bit() {
    // The v4 contract: a trace recorded with the meter on replays to a
    // bit-identical report — joules included — after a full JSONL
    // round-trip, and the header carries the energy knobs.
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(8, 0x4EA1, |rng| {
        let opts = random_energy_options(rng);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
        assert!(trace.meta.scheduler.energy);
        assert_eq!(trace.meta.scheduler.energy_mode, opts.scheduler.energy_mode);

        let replayed = ReplayDriver::from_jsonl(&trace.to_jsonl())
            .unwrap_or_else(|e| panic!("reparse failed: {e}"))
            .replay(&cfg)
            .unwrap_or_else(|e| panic!("replay failed: {e}"));
        assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
        assert_eq!(replayed.report, recorded, "joules must replay bit-identically");
        assert_eq!(replayed.report.energy_total_fj, recorded.energy_total_fj);
    });
}

#[test]
fn energy_budget_sheds_by_class_end_to_end() {
    // A draining budget sheds Batch before Standard and never Realtime,
    // through the full serving path (not just the scheduler unit): under
    // a budget tight enough to shed, every shed request is Batch or
    // Standard and every Realtime request completes.
    let cfg = NeutronConfig::flagship_2tops();
    let trace = synthetic_trace_with_mix(
        &[ModelId::MobileNetV1],
        40,
        100_000,
        21,
        &PriorityMix { realtime: 1, standard: 1, batch: 1 },
    );
    let realtime_offered = trace.iter().filter(|r| r.priority == Priority::Realtime).count();
    assert!(realtime_offered > 0, "the mix must offer realtime work");
    let run = |budget: Option<u64>| {
        let opts = SchedulerOptions {
            instances: 2,
            energy: true,
            energy_budget_fj: budget,
            ..SchedulerOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        run_trace(&cfg, &trace, &opts, &mut cache)
    };
    let free = run(None);
    assert!(free.shed.is_empty(), "no budget, no shedding");
    let spent_unbounded: u64 = free
        .completions
        .iter()
        .map(|c| c.energy_compute_fj + c.energy_dma_fj + c.energy_idle_fj)
        .sum();
    // A budget around a third of the unbounded spend must bind.
    let capped = run(Some(spent_unbounded / 3));
    assert!(!capped.shed.is_empty(), "a binding budget must shed");
    assert!(
        capped.shed.iter().all(|r| r.priority != Priority::Realtime),
        "realtime is never shed for energy"
    );
    let realtime_done =
        capped.completions.iter().filter(|c| c.priority == Priority::Realtime).count();
    assert_eq!(realtime_done, realtime_offered, "every realtime request still completes");
}

#[test]
fn energy_calibration_fit_improves_and_round_trips_its_file() {
    // The fit is improve-only (guarded per channel), deterministic, and
    // its file format round-trips exactly — including the config
    // fingerprint pin that rejects a fit measured on a different config.
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions {
        models: vec![ModelId::MobileNetV1, ModelId::MobileNetV3Min],
        requests: 30,
        mean_gap_cycles: 150_000,
        seed: 5,
        priority_mix: PriorityMix::default(),
        scheduler: SchedulerOptions {
            instances: 2,
            max_batch: 3,
            energy: true,
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    };
    let mut cache = CompileCache::for_serving(cfg.clone());
    let (_, trace) = serve_recorded(&cfg, &opts, &mut cache);

    let report = EnergyFitReport::from_trace(&trace, &cfg).unwrap();
    assert_eq!(report.rows.len(), EnergyChannel::all().len());
    assert!(report.overall_mape_pct.is_finite() && report.overall_mape_pct >= 0.0);
    // Guarded fit: never worse than the identity it started from (the
    // tiny epsilon absorbs integer-femtojoule rounding in `apply`).
    let outcome = tune_energy_from_trace(&cfg, &trace).unwrap();
    assert!(
        outcome.mape_after_pct() <= outcome.mape_before_pct() + 1e-6,
        "fit must be improve-only: {} -> {}",
        outcome.mape_before_pct(),
        outcome.mape_after_pct()
    );
    // Deterministic: the same trace fits the same calibration.
    assert_eq!(tune_energy_from_trace(&cfg, &trace).unwrap().calibration, outcome.calibration);

    // File round-trip, scale clamping, fingerprint pinning.
    let fitted = report.calibration_guarded();
    let file = EnergyCalibrationFile::new(&cfg, fitted.clone());
    let parsed = EnergyCalibrationFile::parse(&file.to_json()).unwrap();
    assert_eq!(parsed.calibration_for(&cfg).unwrap(), fitted);
    for c in EnergyChannel::all() {
        let s = fitted.scale_for(c);
        assert!((0.25..=4.0).contains(&s), "{c:?} scale {s} outside the clamp");
    }
    let mut other = cfg.clone();
    other.tcm_banks += 1;
    let err = parsed.calibration_for(&other).unwrap_err().to_string();
    assert!(err.contains("config mismatch"), "wrong-config fits are rejected by name: {err}");

    // An unmetered trace cannot be fitted, and says how to fix that.
    let mut unmetered_opts = opts.clone();
    unmetered_opts.scheduler.energy = false;
    let mut cache = CompileCache::for_serving(cfg.clone());
    let (_, unmetered) = serve_recorded(&cfg, &unmetered_opts, &mut cache);
    let err = EnergyFitReport::from_trace(&unmetered, &cfg).unwrap_err().to_string();
    assert!(err.contains("--energy"), "{err}");
}
