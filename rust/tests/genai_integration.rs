//! Integration + property tests for the autoregressive GenAI serving
//! path: the token-metric decomposition (`TTFT ≤ latency` universally,
//! and `TTFT + TPOT·(tokens−1)` reconstructs the end-to-end latency
//! exactly), knobs-off neutrality (the decode-shaping fields are inert
//! for single-shot traffic, so the PR-7 serving behavior is reproduced
//! bit for bit), and the decode record → replay loop (a recorded decode
//! run survives the v3 JSONL round trip and replays to an identical
//! `ServeReport` under every knob combination).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::serve::{
    run_trace, serve_with_cache, synthetic_decode_trace, CompileCache, PriorityMix,
    SchedulerOptions, ServeOptions,
};
use eiq_neutron::trace::{serve_recorded, ReplayDriver, Trace};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Random decode-relevant scheduler knobs. Residency/continuous-batch
/// draw independently so every legal combination appears; the quota only
/// makes sense under residency, mirroring `SchedulerOptions::validate`.
fn random_decode_scheduler(rng: &mut Rng) -> SchedulerOptions {
    let weight_residency = rng.bool();
    SchedulerOptions {
        instances: rng.usize(1, 2),
        weight_residency,
        residency_quota_bytes: if weight_residency && rng.bool() {
            Some(rng.int(64_000, 2_000_000) as u64)
        } else {
            None
        },
        continuous_batch: rng.bool(),
        ..SchedulerOptions::default()
    }
}

#[test]
fn prop_ttft_and_tpot_decompose_latency_exactly() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(10, 0x6E4A1, |rng| {
        let n = rng.usize(1, 10);
        let prompt_tokens = rng.usize(1, 8) as u32;
        let decode_tokens = rng.usize(1, 6) as u32;
        let gap = rng.int(0, 400_000) as u64;
        // Fixed max_context is implied by the trace itself: the scheduler
        // derives the bucket ladder from prompt+decode, so a shared cache
        // still reuses compiled buckets across cases.
        let trace = synthetic_decode_trace(
            &[ModelId::GptTiny],
            n,
            gap,
            rng.next_u64(),
            prompt_tokens,
            decode_tokens,
        );
        let sched = random_decode_scheduler(rng);
        let outcome = run_trace(&cfg, &trace, &sched, &mut cache);

        assert_eq!(outcome.completions.len(), n, "unbounded queue completes everything");
        let mut tokens_total = 0u64;
        for c in &outcome.completions {
            tokens_total += c.tokens as u64;
            assert_eq!(c.tokens, decode_tokens, "a decode request emits decode_tokens tokens");
            // TTFT is anchored at the end of prefill, so it can never
            // exceed the end-to-end latency…
            assert!(c.first_token_cycles > c.start_cycles);
            assert!(c.first_token_cycles <= c.finish_cycles);
            assert!(c.ttft_cycles() <= c.latency_cycles());
            // …and the phases tile the latency exactly on the virtual
            // clock: arrival→first token, then first token→finish.
            assert_eq!(c.ttft_cycles() + c.decode_phase_cycles(), c.latency_cycles());
            match c.tpot_cycles() {
                // TPOT is the mean inter-token gap, so scaling it back up
                // by (tokens−1) reconstructs the decode phase to within
                // one f64 rounding step per token.
                Some(tpot) => {
                    let rebuilt = c.ttft_cycles() as f64 + tpot * (c.tokens - 1) as f64;
                    let err = (rebuilt - c.latency_cycles() as f64).abs();
                    assert!(err <= 1e-6 * rebuilt.max(1.0), "|{rebuilt} - {}|", c.latency_cycles());
                }
                None => {
                    assert_eq!(c.tokens, 1, "TPOT is only undefined for single-token output");
                    assert_eq!(c.first_token_cycles, c.finish_cycles);
                }
            }
        }
        assert_eq!(outcome.tokens_generated, tokens_total, "token accounting must balance");
    });
}

#[test]
fn prop_decode_knob_fields_are_inert_for_single_shot_traffic() {
    // The PR-7 oracle: with `decode: false`, the token-shape fields must
    // not influence the run in any way — the single-shot path is the
    // pre-GenAI scheduler, bit for bit (f64s included).
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(8, 0x0FF0, |rng| {
        let base = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: rng.usize(1, 20),
            mean_gap_cycles: rng.int(0, 800_000) as u64,
            seed: rng.next_u64(),
            priority_mix: PriorityMix::default(),
            scheduler: SchedulerOptions {
                instances: rng.usize(1, 2),
                ..SchedulerOptions::default()
            },
            ..ServeOptions::default()
        };
        let reference = serve_with_cache(&cfg, &base, &mut cache);
        assert_eq!(reference.decode_requests, 0);
        assert_eq!(
            reference.tokens_generated, reference.completed,
            "single-shot inference counts one token per request"
        );
        let scrambled = ServeOptions {
            prompt_tokens: rng.usize(1, 100) as u32,
            decode_tokens: rng.usize(1, 100) as u32,
            max_context: rng.usize(2, 4096) as u32,
            ..base.clone()
        };
        assert_eq!(
            serve_with_cache(&cfg, &scrambled, &mut cache),
            reference,
            "token-shape knobs must be inert without --decode"
        );
    });
}

#[test]
fn prop_decode_record_replay_reproduces_the_report() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(6, 0x4EC0DE, |rng| {
        let prompt_tokens = rng.usize(1, 6) as u32;
        let decode_tokens = rng.usize(1, 5) as u32;
        let opts = ServeOptions {
            models: vec![ModelId::GptTiny],
            requests: rng.usize(1, 8),
            mean_gap_cycles: rng.int(0, 300_000) as u64,
            seed: rng.next_u64(),
            scheduler: random_decode_scheduler(rng),
            decode: true,
            prompt_tokens,
            decode_tokens,
            // Fixed budget so the shared cache reuses one bucket ladder.
            max_context: 16,
            ..ServeOptions::default()
        };
        let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
        assert_eq!(recorded.decode_requests, opts.requests as u64);
        assert_eq!(
            recorded.tokens_generated,
            opts.requests as u64 * decode_tokens as u64,
            "every request generates its full budget with an unbounded queue"
        );

        // The v3 JSONL round trip preserves every field the replay needs.
        let parsed = Trace::parse(&trace.to_jsonl()).unwrap();
        assert_eq!(parsed, trace, "trace must survive serialization unchanged");
        let replayed = ReplayDriver::new(parsed).replay(&cfg).unwrap();
        assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
        assert_eq!(replayed.report, recorded, "faithful replay must reproduce the report");
    });
}
