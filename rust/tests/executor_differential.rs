//! Differential test layer for intra-instance pipelining + TCM weight
//! residency (PR 7). Two directions, both against independent oracles:
//!
//! * **Off ⇒ bit-identical to sequential.** With every new knob off, the
//!   refactored tick-loop executor and the knob-aware scheduler must
//!   reproduce the sequential run-to-completion behavior exactly: same
//!   per-request cycle attribution, same executor `Metrics` (host time
//!   excluded — it is wall clock), same `TraceOutcome`/`ServeReport`
//!   down to every f64. The scheduler side is checked against a
//!   hand-rolled FIFO earliest-idle reference simulator, not against
//!   itself.
//! * **On ⇒ makespan never increases.** Over the restricted distribution
//!   where the monotonicity argument holds (single class, unbounded
//!   queue, no batching, earliest-idle placement), turning pipelining
//!   and/or residency on can only shrink per-dispatch service times, so
//!   the makespan of a random synthetic trace never exceeds the
//!   baseline's.
//!
//! Plus the residency property suite: the capacity invariant holds at
//! every dispatch, eviction is deterministic across identical runs, a
//! one-hot-model workload converges to a 100% hit rate after the first
//! request, and utilization stays in `[0, 1]` for every knob combo.

use std::collections::HashMap;

use eiq_neutron::arch::{NeutronConfig, ResidencyEntry};
use eiq_neutron::compiler::TileId;
use eiq_neutron::coordinator::{Executor, Job, JobProgram, Metrics};
use eiq_neutron::serve::{
    marginal_service_cycles, run_trace, serve_with_cache, synthetic_trace, Completion,
    CompileCache, PriorityMix, Scheduler, SchedulerOptions, ServeOptions,
};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Cheap zoo subset (mirrors the serve suite's pool).
const POOL: [ModelId; 4] = [
    ModelId::MobileNetV1,
    ModelId::MobileNetV2,
    ModelId::MobileNetV3Min,
    ModelId::EfficientNetLite0,
];

/// A random non-empty, duplicate-free subset of the pool.
fn random_models(rng: &mut Rng) -> Vec<ModelId> {
    let k = rng.usize(1, POOL.len());
    let start = rng.usize(0, POOL.len() - 1);
    (0..k).map(|i| POOL[(start + i) % POOL.len()]).collect()
}

fn makespan(completions: &[Completion]) -> u64 {
    completions.iter().map(|c| c.finish_cycles).max().unwrap_or(0)
}

/// Bank-rounded install size of every distinct parameter tile a program
/// fetches, in first-appearance order — the capacity charge the
/// scheduler's residency pre-pass applies per tile.
fn param_tile_install_sizes(program: &JobProgram, bank_bytes: u64) -> Vec<u64> {
    let params = program.param_tiles();
    let mut seen: Vec<(TileId, u64)> = Vec::new();
    for job in &program.jobs {
        if let Job::Dma { tile, bytes, .. } = job {
            if params.contains(tile) {
                match seen.iter_mut().find(|(t, _)| t == tile) {
                    Some((_, b)) => *b = (*b).max(*bytes),
                    None => seen.push((*tile, *bytes)),
                }
            }
        }
    }
    seen.into_iter().map(|(_, b)| b.div_ceil(bank_bytes).max(1) * bank_bytes).collect()
}

/// A [`Metrics`] clone with the wall-clock field zeroed, so two runs of
/// the same simulated work compare equal.
fn sim_metrics(m: &Metrics) -> Metrics {
    Metrics { total_host_us: 0, ..m.clone() }
}

#[test]
fn resumable_tick_loop_matches_run_to_completion() {
    // The tentpole refactor must be invisible when driven to completion:
    // stepping a `ProgramRun` tick by tick and sealing it yields the same
    // per-request cycle attribution and the same aggregate `Metrics` as
    // the one-shot `run_program` path, and the per-tick latencies sum to
    // exactly the program's tick service time.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for model in [ModelId::MobileNetV3Min, ModelId::MobileNetV1] {
        let entry = cache.get(model);

        let mut whole = Executor::with_config(cfg.clone());
        let full = whole.run_program(&entry.program, None).unwrap();

        let mut stepped = Executor::with_config(cfg.clone());
        let mut run = stepped.begin(&entry.program);
        let mut latency_sum = 0u64;
        while let Some(t) = run.step_tick(|_| true) {
            assert_eq!(
                t.latency_cycles,
                t.compute_cycles.max(t.dm_cycles),
                "{model:?}: tick latency must follow the DAE max(compute, dm) model"
            );
            latency_sum += t.latency_cycles;
        }
        let result = run.finish(None).unwrap();

        assert_eq!(result.sim_cycles, full.sim_cycles, "{model:?}: sim cycles diverge");
        assert_eq!(result.ticks, full.ticks, "{model:?}: tick counts diverge");
        assert_eq!(result.compute_jobs, full.compute_jobs);
        assert_eq!(result.dma_jobs, full.dma_jobs);
        assert_eq!(result.ddr_bytes, full.ddr_bytes);
        assert_eq!(result.v2p_updates, full.v2p_updates);
        assert_eq!(latency_sum, result.sim_cycles, "{model:?}: tick latencies must sum up");
        assert_eq!(
            latency_sum,
            entry.program.service_cycles_where(|_| true),
            "{model:?}: the stepped clock must agree with the static tick accounting"
        );
        assert_eq!(
            sim_metrics(&whole.metrics),
            sim_metrics(&stepped.metrics),
            "{model:?}: tick-loop metrics diverge from run-to-completion"
        );
    }
}

#[test]
fn prop_knobs_off_reproduces_the_sequential_oracle() {
    // With pipelining and residency off, the scheduler must be
    // bit-identical to the sequential baseline. The baseline here is an
    // independent oracle: FIFO in admission order onto the earliest-idle
    // instance (lowest id on ties), every request paying its program's
    // full tick service time — the documented pre-PR contract.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(10, 0xD1FF, |rng| {
        let models = random_models(rng);
        let n = rng.usize(1, 30);
        let gap = rng.int(0, 1_000_000) as u64;
        let instances = rng.usize(1, 4);
        let trace = synthetic_trace(&models, n, gap, rng.next_u64());
        let opts = SchedulerOptions { instances, ..SchedulerOptions::default() };
        let outcome = run_trace(&cfg, &trace, &opts, &mut cache);

        let full: HashMap<ModelId, u64> = models
            .iter()
            .map(|&m| (m, cache.get(m).program.service_cycles_where(|_| true)))
            .collect();
        let mut busy = vec![0u64; instances];
        assert_eq!(outcome.completions.len(), n, "unbounded queue completes everything");
        for (c, r) in outcome.completions.iter().zip(trace.iter()) {
            let i = (0..instances).min_by_key(|&i| (busy[i], i)).unwrap();
            let start = busy[i].max(r.arrival_cycles);
            let finish = start + full[&r.model];
            busy[i] = finish;
            assert_eq!(
                (c.id, c.instance, c.start_cycles, c.finish_cycles),
                (r.id, i, start, finish),
                "request {} diverges from the sequential oracle",
                r.id
            );
            assert_eq!(c.batch_index, 0);
            assert_eq!(c.overlap_cycles, 0, "no overlap may be attributed with pipelining off");
            assert_eq!(c.residency_hit_cycles, 0, "no hits may be attributed with residency off");
        }
        assert_eq!(
            (
                outcome.overlap_cycles,
                outcome.residency_hits,
                outcome.residency_misses,
                outcome.residency_evictions,
                outcome.warm_dispatches
            ),
            (0, 0, 0, 0, 0),
            "off-knob counters must stay zero"
        );
        // Explicitly-disabled knobs are bit-identical to the defaults —
        // the whole outcome, not just the makespan.
        let off = SchedulerOptions {
            pipeline: false,
            weight_residency: false,
            warm_routing: false,
            residency_capacity_bytes: None,
            ..opts.clone()
        };
        assert_eq!(run_trace(&cfg, &trace, &off, &mut cache), outcome);
    });
}

#[test]
fn prop_pipelining_and_residency_never_increase_makespan() {
    // The restricted distribution for which monotonicity provably holds:
    // single class, unbounded queue, no batching, earliest-idle placement
    // (no warm routing). Both knobs only ever shrink a dispatch's
    // effective service time (hits elide DMA cycles, overlap hides head
    // cycles), dispatch order is fixed by admission order, and shrinking
    // service times under FIFO earliest-idle can only move every busy
    // horizon earlier — so the makespan never exceeds the baseline's.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(10, 0x9107, |rng| {
        let models = random_models(rng);
        let n = rng.usize(2, 30);
        let gap = rng.int(0, 800_000) as u64;
        let instances = rng.usize(1, 3);
        let trace = synthetic_trace(&models, n, gap, rng.next_u64());
        let base_opts = SchedulerOptions { instances, ..SchedulerOptions::default() };
        let base = run_trace(&cfg, &trace, &base_opts, &mut cache);
        let base_makespan = makespan(&base.completions);

        for (pipeline, weight_residency) in [(true, false), (false, true), (true, true)] {
            let on = SchedulerOptions { pipeline, weight_residency, ..base_opts.clone() };
            let outcome = run_trace(&cfg, &trace, &on, &mut cache);
            assert_eq!(outcome.completions.len(), n);
            assert!(
                makespan(&outcome.completions) <= base_makespan,
                "pipeline={pipeline} residency={weight_residency}: makespan {} exceeds \
                 baseline {base_makespan}",
                makespan(&outcome.completions)
            );
            // Every individual request also finishes no later — the
            // pointwise form of the same induction.
            for (on_c, base_c) in outcome.completions.iter().zip(base.completions.iter()) {
                assert_eq!(on_c.id, base_c.id, "dispatch order is the admission order");
                assert!(
                    on_c.finish_cycles <= base_c.finish_cycles,
                    "request {} finished later with the knobs on",
                    on_c.id
                );
            }
        }
    });
}

#[test]
fn prop_residency_capacity_invariant_and_eviction_determinism() {
    // At every dispatch, on every instance, the resident set must stay
    // within the configured capacity and sum-consistent; and a second
    // identical run must reproduce the completions, the final resident
    // sets (eviction victims included) and the executor metrics exactly.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    let bank_bytes = cfg.bank_bytes() as u64;
    for_each_case(8, 0x7C31, |rng| {
        let models = random_models(rng);
        let n = rng.usize(2, 20);
        let instances = rng.usize(1, 2);
        // Small capacities (1–8 banks) force rejects and evictions.
        let capacity = bank_bytes * rng.int(1, 8) as u64;
        let trace = synthetic_trace(&models, n, rng.int(0, 400_000) as u64, rng.next_u64());
        let opts = SchedulerOptions {
            instances,
            weight_residency: true,
            residency_capacity_bytes: Some(capacity),
            pipeline: rng.bool(),
            ..SchedulerOptions::default()
        };

        type DriveResult = (Vec<Completion>, Vec<Vec<ResidencyEntry>>, Vec<Metrics>, [u64; 5]);
        let drive = |cache: &mut CompileCache| -> DriveResult {
            let mut s = Scheduler::new(&cfg, &opts);
            for &r in &trace {
                s.admit(r);
            }
            let mut completions = Vec::new();
            while let Some(model) = s.next_model() {
                let entry = cache.get(model);
                completions.extend(s.dispatch_next(model, &entry.program));
                for inst in s.instances() {
                    let r = inst.residency().expect("residency is enabled");
                    assert!(
                        r.resident_bytes() <= r.capacity_bytes(),
                        "instance {}: resident {} exceeds capacity {}",
                        inst.id,
                        r.resident_bytes(),
                        r.capacity_bytes()
                    );
                    assert_eq!(
                        r.resident_bytes(),
                        r.entries().iter().map(|e| e.bytes).sum::<u64>(),
                        "resident-byte accounting must match the entry list"
                    );
                    assert_eq!(r.capacity_bytes(), capacity);
                }
            }
            let residency_states = s
                .instances()
                .iter()
                .map(|i| i.residency().unwrap().entries().to_vec())
                .collect();
            let metrics = s.instances().iter().map(|i| sim_metrics(i.metrics())).collect();
            let counters = [
                s.residency_hits(),
                s.residency_misses(),
                s.residency_evictions(),
                s.warm_dispatches(),
                s.overlap_cycles(),
            ];
            (completions, residency_states, metrics, counters)
        };

        let a = drive(&mut cache);
        let b = drive(&mut cache);
        assert_eq!(
            a, b,
            "same trace + same knobs must reproduce completions, resident sets \
             (eviction victims included), metrics and counters exactly"
        );
    });
}

#[test]
fn one_hot_workload_converges_to_full_hit_rate_after_first_request() {
    // A single hot model under an ample capacity override: the first
    // request compulsory-misses every parameter tile, every later request
    // runs fully warm — the convergence property the TCM residency model
    // exists to provide. The warm service time must equal the batching
    // follower's marginal service time: both elide exactly the parameter
    // tiles' DMA jobs.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    let model = ModelId::MobileNetV3Min;
    let n = 16u64;
    let trace = synthetic_trace(&[model], n as usize, 200_000, 3);
    let opts = SchedulerOptions {
        instances: 1,
        weight_residency: true,
        residency_capacity_bytes: Some(64 << 20),
        ..SchedulerOptions::default()
    };

    let entry = cache.get(model);
    let k = param_tile_install_sizes(&entry.program, cfg.bank_bytes() as u64).len() as u64;
    assert!(k >= 1, "a real model program fetches parameter tiles");

    let outcome = run_trace(&cfg, &trace, &opts, &mut cache);
    assert_eq!(outcome.completions.len(), n as usize);
    assert_eq!(outcome.residency_misses, k, "only the first request compulsory-misses");
    assert_eq!(outcome.residency_hits, (n - 1) * k, "every later request runs fully warm");
    assert_eq!(outcome.residency_evictions, 0, "nothing evicts under an ample capacity");
    assert_eq!(outcome.warm_dispatches, n - 1);

    let hit_cycles: Vec<u64> = outcome.completions.iter().map(|c| c.residency_hit_cycles).collect();
    assert_eq!(hit_cycles[0], 0, "the first dispatch is cold");
    assert!(
        hit_cycles[1..].iter().all(|&c| c == hit_cycles[1] && c > 0),
        "warm dispatches all save the same (positive) fetch cycles: {hit_cycles:?}"
    );
    let warm_service = outcome.completions.last().unwrap().service_cycles();
    assert_eq!(
        warm_service,
        marginal_service_cycles(&entry.program),
        "warm pricing and batching-follower pricing share the parameter-tile skip rule"
    );
}

#[test]
fn prop_utilization_stays_in_bounds_for_every_knob_combo() {
    // Overlapped cycles are counted once (inside the predecessor's
    // occupied interval), so utilization must stay within [0, 1] for
    // every knob combination — and with everything off, the whole
    // `ServeReport` (f64s included) must equal the baseline's.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for model in POOL {
        cache.get(model);
    }
    for_each_case(8, 0x07F1, |rng| {
        let base_opts = ServeOptions {
            models: random_models(rng),
            requests: rng.usize(1, 25),
            mean_gap_cycles: rng.int(0, 600_000) as u64,
            seed: rng.next_u64(),
            priority_mix: PriorityMix::standard_only(),
            scheduler: SchedulerOptions {
                instances: rng.usize(1, 3),
                ..SchedulerOptions::default()
            },
            ..ServeOptions::default()
        };
        let base = serve_with_cache(&cfg, &base_opts, &mut cache);
        assert!(base.utilization() > 0.0 && base.utilization() <= 1.0 + 1e-12);

        let combos =
            [(true, false, false), (false, true, false), (true, true, false), (true, true, true)];
        for (pipeline, weight_residency, warm_routing) in combos {
            let o = ServeOptions {
                scheduler: SchedulerOptions {
                    pipeline,
                    weight_residency,
                    warm_routing,
                    ..base_opts.scheduler.clone()
                },
                ..base_opts.clone()
            };
            let r = serve_with_cache(&cfg, &o, &mut cache);
            assert!(
                r.utilization() > 0.0 && r.utilization() <= 1.0 + 1e-12,
                "pipeline={pipeline} residency={weight_residency} routing={warm_routing}: \
                 utilization {} out of bounds",
                r.utilization()
            );
            assert_eq!(r.offered, base.offered);
            assert_eq!(r.completed, base.completed, "knobs re-time requests, never drop them");
        }

        let off = ServeOptions {
            scheduler: SchedulerOptions {
                pipeline: false,
                weight_residency: false,
                warm_routing: false,
                residency_capacity_bytes: None,
                ..base_opts.scheduler.clone()
            },
            ..base_opts.clone()
        };
        assert_eq!(
            serve_with_cache(&cfg, &off, &mut cache),
            base,
            "knobs explicitly off must reproduce the baseline report bit for bit"
        );
    });
}
