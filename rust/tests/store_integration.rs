//! Property/golden tests for the persistent `.npu` artifact store and the
//! warm-started anytime CP search:
//!
//! * save→load round-trips are **bit-identical** (same schedule,
//!   allocation, program, and the exact `f64` bits of every latency)
//!   across zoo models × random calibrations, and encoding is canonical
//!   (same artifact → same bytes);
//! * corrupted, truncated, version-skewed and fingerprint-mismatched
//!   artifacts are rejected with errors naming the offending section —
//!   never a panic, never a silently wrong plan;
//! * a warm-started search seeded with a feasible solution is **never
//!   worse** than the cold search under the same node budget, degrades to
//!   the seed itself at budget zero (anytime floor), and with an
//!   unlimited budget converges to the identical optimal assignment;
//! * warm-started compilation is deterministic: the same seed artifact
//!   yields the same deterministic artifact parts, twice.

use std::sync::Arc;

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions, Compiled, CostCalibration};
use eiq_neutron::cp::{solve, CpModel, LinExpr, SearchConfig, Solution, Status};
use eiq_neutron::ir::OpClass;
use eiq_neutron::runtime::{
    decode_npu, encode_npu, options_fingerprint, ArtifactStore, StoreError, NPU_VERSION,
};
use eiq_neutron::serve::deterministic_compile_options;
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Small zoo subset: every case compiles, so keep the pool cheap.
const POOL: [ModelId; 2] = [ModelId::MobileNetV3Min, ModelId::EfficientNetLite0];

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("eiq_npu_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A random calibration: a random subset of op classes scaled in
/// [0.5, 2.0] (always valid: finite and positive).
fn random_calibration(rng: &mut Rng) -> CostCalibration {
    let classes = OpClass::all();
    let mut scales = Vec::new();
    for &class in classes.iter() {
        if rng.bool() {
            scales.push((class, 0.5 + 1.5 * rng.f64()));
        }
    }
    if scales.is_empty() {
        CostCalibration::identity()
    } else {
        CostCalibration::from_scales(&scales)
    }
}

// --- Satellite 1: round-trip bit-identity across zoo × calibrations ---

#[test]
fn npu_round_trip_is_bit_identical_across_zoo_and_calibrations() {
    let cfg = NeutronConfig::flagship_2tops();
    let store = ArtifactStore::open(tmp_dir("roundtrip")).unwrap();
    for_each_case(4, 0x5703_11, |rng| {
        let model = *rng.choose(&POOL);
        let calibration = random_calibration(rng);
        let opts = CompileOptions { calibration, ..deterministic_compile_options() };
        let fp = options_fingerprint(&opts);
        let compiled = compile(&model.build(), &cfg, &opts);

        // Canonical encoding: same artifact, same bytes.
        let bytes = encode_npu(model, &cfg, &compiled, fp);
        assert_eq!(bytes, encode_npu(model, &cfg, &compiled, fp), "encoding must be canonical");

        // Disk round-trip through the store: bit-identical artifact.
        store.save(model, &cfg, &compiled, fp).unwrap();
        let loaded = store.load(model, &cfg, &compiled.calibration, fp).unwrap();
        assert_eq!(loaded, compiled, "{model:?}: save→load round-trip drifted");
        assert_eq!(
            loaded.inference_ms.to_bits(),
            compiled.inference_ms.to_bits(),
            "{model:?}: inference_ms f64 bits drifted"
        );
        assert_eq!(loaded.schedule.ticks, compiled.schedule.ticks);
        assert_eq!(loaded.allocation.placements, compiled.allocation.placements);
        assert_eq!(loaded.program, compiled.program);
        assert_eq!(loaded.formats, compiled.formats);

        // In-memory round-trip agrees with the disk one.
        let art = decode_npu(&bytes).unwrap();
        assert_eq!(art.compiled, compiled);
        assert_eq!(art.model_slug, model.slug());
        assert_eq!(art.options_fp, fp);
    });
}

// --- Satellite 2 (validation half): rejection with named errors ---

#[test]
fn corrupted_artifacts_are_rejected_with_named_errors() {
    let cfg = NeutronConfig::flagship_2tops();
    let model = ModelId::MobileNetV3Min;
    let opts = deterministic_compile_options();
    let fp = options_fingerprint(&opts);
    let compiled = compile(&model.build(), &cfg, &opts);
    let bytes = encode_npu(model, &cfg, &compiled, fp);

    // Bad magic.
    let mut wrong = bytes.clone();
    wrong[3] ^= 0x01;
    assert!(matches!(decode_npu(&wrong), Err(StoreError::BadMagic)));
    assert!(matches!(decode_npu(b"not an artifact"), Err(StoreError::BadMagic)));
    assert!(matches!(decode_npu(&[]), Err(StoreError::BadMagic)));

    // Version skew names both versions.
    let mut skewed = bytes.clone();
    skewed[8] = NPU_VERSION as u8 + 1;
    match decode_npu(&skewed) {
        Err(StoreError::VersionSkew { found, expected }) => {
            assert_eq!(found, NPU_VERSION + 1);
            assert_eq!(expected, NPU_VERSION);
        }
        other => panic!("expected VersionSkew, got {other:?}"),
    }

    // Every strict prefix is rejected (length-prefixed framing means a
    // truncated file can never decode), and the error names a section.
    for_each_case(64, 0x5703_22, |rng| {
        let cut = rng.usize(0, bytes.len() - 1);
        match decode_npu(&bytes[..cut]) {
            Err(StoreError::BadMagic) => assert!(cut < 8, "BadMagic only for header cuts"),
            Err(StoreError::Truncated { section }) => {
                assert!(
                    ["header", "formats", "program", "schedule", "allocation", "meta",
                     "calibration"]
                        .contains(&section),
                    "unnamed section in truncation error: {section:?}"
                );
            }
            Err(other) => panic!("truncation at {cut} gave unexpected error {other:?}"),
            Ok(_) => panic!("truncated artifact ({cut}/{} bytes) decoded", bytes.len()),
        }
    });

    // Header fingerprint bytes (config 12..20, calibration 20..28,
    // options 28..36): tampering is caught by name at load time.
    let store = ArtifactStore::open(tmp_dir("reject")).unwrap();
    let path = store.save(model, &cfg, &compiled, fp).unwrap();
    for (offset, which) in [(12usize, "config"), (28usize, "options")] {
        let mut tampered = bytes.clone();
        tampered[offset] ^= 0xff;
        std::fs::write(&path, &tampered).unwrap();
        match store.load(model, &cfg, &compiled.calibration, fp) {
            Err(StoreError::FingerprintMismatch { which: w, expected, found }) => {
                assert_eq!(w, which);
                assert_ne!(expected, found);
            }
            other => panic!("expected {which} FingerprintMismatch, got {other:?}"),
        }
    }
    // A tampered calibration fingerprint is caught even earlier: the
    // calibration *section* no longer matches the header.
    let mut tampered = bytes.clone();
    tampered[20] ^= 0xff;
    match decode_npu(&tampered) {
        Err(StoreError::Corrupt { section: "calibration", .. }) => {}
        other => panic!("expected calibration Corrupt, got {other:?}"),
    }

    // Asking the store for a different calibration resolves a different
    // path — a missing artifact, not a wrong one.
    std::fs::write(&path, &bytes).unwrap();
    let other_cal = CostCalibration::from_scales(&[(OpClass::Conv, 1.25)]);
    assert!(matches!(
        store.load(model, &cfg, &other_cal, fp),
        Err(StoreError::Io(_))
    ));
    // Copying the artifact onto that other key's path forges the name but
    // not the content: rejected as a calibration mismatch by fingerprint.
    std::fs::copy(&path, store.path_for(model, &cfg, &other_cal)).unwrap();
    match store.load(model, &cfg, &other_cal, fp) {
        Err(StoreError::FingerprintMismatch { which: "calibration", .. }) => {}
        other => panic!("expected calibration FingerprintMismatch, got {other:?}"),
    }
    // And the untampered original still loads — rejection is per-file.
    assert_eq!(store.load(model, &cfg, &compiled.calibration, fp).unwrap(), compiled);
}

// --- Satellite 2 (search half): warm-started anytime search properties ---

/// A random feasible minimization CP: bounded non-negative vars, `≥`
/// covering constraints with non-negative coefficients (so the all-upper
/// assignment is always feasible), positive objective coefficients.
fn random_model(rng: &mut Rng) -> (CpModel, Vec<i64>) {
    let n = rng.usize(2, 5);
    let mut m = CpModel::new();
    let mut ubs = Vec::new();
    let vars: Vec<_> = (0..n)
        .map(|i| {
            let ub = rng.int(1, 4);
            ubs.push(ub);
            m.int_var(0, ub, format!("x{i}"))
        })
        .collect();
    for c in 0..rng.usize(1, 3) {
        let mut e = LinExpr::new();
        let mut max_lhs = 0i64;
        for (i, &v) in vars.iter().enumerate() {
            let coef = rng.int(0, 3);
            if coef > 0 {
                e = e.add(coef, v);
                max_lhs += coef * ubs[i];
            }
        }
        // rhs ≤ max_lhs keeps the all-upper assignment feasible.
        m.add_ge(e, rng.int(0, max_lhs.max(0)));
        let _ = c;
    }
    let mut obj = LinExpr::new();
    for &v in &vars {
        obj = obj.add(rng.int(1, 5), v);
    }
    m.minimize(obj);
    (m, ubs)
}

fn solve_with(m: &CpModel, node_limit: Option<u64>, hint: Option<Vec<i64>>) -> Solution {
    solve(
        m,
        SearchConfig { node_limit, time_limit_ms: None, hint, ..SearchConfig::default() },
    )
}

#[test]
fn warm_started_search_is_anytime_and_never_worse_than_cold() {
    for_each_case(64, 0x5703_33, |rng| {
        let (m, ubs) = random_model(rng);
        // The all-upper assignment is feasible by construction: the
        // "neighbor solution" every warm start seeds from.
        let seed = ubs.clone();

        // Unlimited cold search: the reference optimum.
        let cold_opt = solve_with(&m, None, None);
        assert_eq!(cold_opt.status, Status::Optimal, "random model must be feasible");
        let best_obj = cold_opt.objective.unwrap();
        let best_assignment = cold_opt.assignment.clone().unwrap();

        // Anytime floor: at node budget zero, the warm search returns the
        // seed itself instead of failing.
        let floor = solve_with(&m, Some(0), Some(seed.clone()));
        assert_eq!(floor.status, Status::Feasible);
        assert_eq!(floor.assignment.as_deref(), Some(seed.as_slice()));

        // Never worse: under the same node budget, the warm search's
        // objective is ≤ the cold search's (when cold found one at all),
        // and always ≤ the seed's objective.
        let budget = rng.int(0, 40) as u64;
        let cold = solve_with(&m, Some(budget), None);
        let warm = solve_with(&m, Some(budget), Some(seed.clone()));
        let warm_obj = warm.objective.expect("warm search always has its seed");
        if let Some(cold_obj) = cold.objective {
            assert!(
                warm_obj <= cold_obj,
                "warm {warm_obj} worse than cold {cold_obj} at budget {budget}"
            );
        }
        assert!(warm_obj >= best_obj, "objective below the proven optimum");

        // Convergence: with an unlimited budget, the warm search lands on
        // the identical optimal assignment the cold search found —
        // including when seeded with the optimum itself (strict
        // improvement never replaces an equal incumbent).
        let warm_opt = solve_with(&m, None, Some(seed));
        assert_eq!(warm_opt.status, Status::Optimal);
        assert_eq!(warm_opt.objective, Some(best_obj));
        let warm_self = solve_with(&m, None, Some(best_assignment.clone()));
        assert_eq!(warm_self.status, Status::Optimal);
        assert_eq!(warm_self.assignment, Some(best_assignment));
    });
}

#[test]
fn invalid_warm_seeds_degrade_to_cold_search() {
    for_each_case(32, 0x5703_44, |rng| {
        let (m, ubs) = random_model(rng);
        let cold = solve_with(&m, None, None);
        assert_eq!(cold.stats.hints_rejected, 0, "cold search has no seed to reject");
        // Wrong arity and out-of-bounds seeds are dropped, not trusted —
        // and the drop is *counted*, never silent.
        let bad_arity = vec![0i64; ubs.len() + 3];
        let out_of_bounds: Vec<i64> = ubs.iter().map(|&u| u + 10).collect();
        for bad in [bad_arity, out_of_bounds] {
            let s = solve_with(&m, None, Some(bad));
            assert_eq!(s.status, Status::Optimal);
            assert_eq!(s.objective, cold.objective);
            assert_eq!(s.stats.hints_rejected, 1, "rejected seed must be counted");
        }
    });
}

// --- Warm-started compilation: deterministic, structurally valid ---

/// Compare every deterministic part of two artifacts.
///
/// The wall-clock fields — `Compiled::compile_ms`, `Schedule::solve_ms`,
/// `Allocation::solve_ms` — are **deliberately excluded**: they are the
/// only nondeterministic values in an otherwise deterministic compile, and
/// golden comparisons must never flake on them. The same contract holds
/// one level down: `cp::Solution`'s `PartialEq` ignores its own
/// `solve_ms`, so whole `Solution`s compare deterministically too (see
/// `docs/solver.md`). Solver telemetry (`cp::SolveStats`) lives outside
/// `Compiled` entirely and never enters any plan comparison.
fn assert_same_plan(a: &Compiled, b: &Compiled, what: &str) {
    assert_eq!(a.formats, b.formats, "{what}: formats differ");
    assert_eq!(a.program, b.program, "{what}: tiled programs differ");
    assert_eq!(a.schedule.ticks, b.schedule.ticks, "{what}: schedules differ");
    assert_eq!(a.schedule.ddr, b.schedule.ddr, "{what}: DDR traffic differs");
    assert_eq!(a.allocation.placements, b.allocation.placements, "{what}: placements differ");
    assert_eq!(a.allocation.v2p_updates, b.allocation.v2p_updates, "{what}: v2p differs");
    assert_eq!(
        a.inference_ms.to_bits(),
        b.inference_ms.to_bits(),
        "{what}: inference_ms bits differ"
    );
}

#[test]
fn warm_started_compile_is_deterministic_and_well_formed() {
    let cfg = NeutronConfig::flagship_2tops();
    let model = ModelId::MobileNetV3Min;
    let graph = model.build();
    let cold = Arc::new(compile(&graph, &cfg, &deterministic_compile_options()));

    // Seed a recompile under a different calibration with the identity
    // artifact — the serving cache's nearest-neighbor path.
    let cal = CostCalibration::from_scales(&[(OpClass::Conv, 1.4), (OpClass::Pool, 0.8)]);
    let warm_opts = CompileOptions {
        calibration: cal.clone(),
        warm_start: Some(Arc::clone(&cold)),
        ..deterministic_compile_options()
    };
    let a = compile(&graph, &cfg, &warm_opts);
    let b = compile(&graph, &cfg, &warm_opts);
    assert_same_plan(&a, &b, "warm-started compile repeated");
    assert_eq!(a.calibration, cal);
    assert!(!a.program.steps.is_empty() && !a.schedule.ticks.is_empty());
    assert!(a.inference_ms.is_finite() && a.inference_ms > 0.0);

    // Seeding a compile with its own artifact under the same calibration
    // reproduces it: the seed is already each CP's incumbent, and strict
    // improvement never replaces an equal solution.
    let self_opts = CompileOptions {
        warm_start: Some(Arc::clone(&cold)),
        ..deterministic_compile_options()
    };
    let replayed = compile(&graph, &cfg, &self_opts);
    assert_same_plan(&replayed, &cold, "self-seeded warm compile vs its seed");
}
