//! Integration + property tests for the trace subsystem: format
//! round-trips (serialize → parse → identical trace), corrupt-line and
//! version-mismatch rejection, record → replay bit-identical
//! `ServeReport`s across random scheduler options, and timing-model
//! validation whose per-op-class MAPE is computed from real sim ticks
//! (the observed cycles in a trace must sum to exactly what the executor
//! charges for the program).

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::coordinator::Executor;
use eiq_neutron::energy::EnergyMode;
use eiq_neutron::ir::OpClass;
use eiq_neutron::serve::{
    AdmissionPolicy, Completion, CompileCache, Priority, PriorityMix, Request, SchedulerOptions,
    ServeOptions,
};
use eiq_neutron::trace::{
    serve_recorded, ModelOps, OpRecord, ReplayDriver, Trace, TraceMeta, ValidationReport,
    TRACE_FORMAT_VERSION,
};
use eiq_neutron::util::prop::{for_each_case, Rng};
use eiq_neutron::zoo::ModelId;

/// Cheap zoo subset (mirrors the serve suite's pool).
const POOL: [ModelId; 4] = [
    ModelId::MobileNetV1,
    ModelId::MobileNetV2,
    ModelId::MobileNetV3Min,
    ModelId::EfficientNetLite0,
];

fn random_models(rng: &mut Rng) -> Vec<ModelId> {
    let k = rng.usize(1, POOL.len());
    let start = rng.usize(0, POOL.len() - 1);
    (0..k).map(|i| POOL[(start + i) % POOL.len()]).collect()
}

fn random_scheduler(rng: &mut Rng) -> SchedulerOptions {
    // The PR-7/PR-8/PR-9 knobs respect their coupling rules (warm
    // routing, a capacity override and a per-owner quota all require
    // residency, a quota never exceeds the capacity, and the energy mode
    // and budget require the meter — `validate()` and the header parser
    // reject anything else).
    let weight_residency = rng.bool();
    let energy = rng.bool();
    let residency_capacity_bytes = if weight_residency && rng.bool() {
        Some(rng.int(1, 2_000_000) as u64)
    } else {
        None
    };
    let residency_quota_bytes = if weight_residency && rng.bool() {
        Some((rng.int(1, 2_000_000) as u64).min(residency_capacity_bytes.unwrap_or(u64::MAX)))
    } else {
        None
    };
    SchedulerOptions {
        instances: rng.usize(1, 4),
        queue_capacity: if rng.bool() { Some(rng.usize(1, 8)) } else { None },
        policy: if rng.bool() {
            AdmissionPolicy::RejectNewest
        } else {
            AdmissionPolicy::DropOldest
        },
        max_batch: rng.usize(1, 6),
        dynamic_batch: rng.bool(),
        age_after_cycles: if rng.bool() { Some(rng.int(1, 500_000) as u64) } else { None },
        pipeline: rng.bool(),
        weight_residency,
        warm_routing: weight_residency && rng.bool(),
        residency_capacity_bytes,
        residency_quota_bytes,
        continuous_batch: rng.bool(),
        energy,
        energy_mode: if energy && rng.bool() { EnergyMode::Stretch } else { EnergyMode::RaceToIdle },
        energy_budget_fj: if energy && rng.bool() {
            Some(rng.int(1, 1_000_000_000) as u64 * 1_000)
        } else {
            None
        },
    }
}

fn random_priority(rng: &mut Rng) -> Priority {
    *rng.choose(&Priority::all())
}

/// A structurally arbitrary (not necessarily schedulable) trace, for
/// format round-trip testing: extreme u64 cycle values, every priority
/// class and op class, optional shed/completion/ops sections.
fn random_trace(rng: &mut Rng) -> Trace {
    let models = random_models(rng);
    let n = rng.usize(0, 20);
    let mut clock = 0u64;
    let requests: Vec<Request> = (0..n as u64)
        .map(|id| {
            clock = clock.saturating_add(rng.next_u64() >> rng.usize(8, 63));
            // Mix single-shot (0/0) and decode requests — the v3 format
            // carries both, and a decode request needs both token counts.
            let decode = rng.bool();
            Request {
                id,
                model: *rng.choose(&models),
                priority: random_priority(rng),
                arrival_cycles: clock,
                prompt_tokens: if decode { rng.usize(1, 64) as u32 } else { 0 },
                decode_tokens: if decode { rng.usize(1, 16) as u32 } else { 0 },
            }
        })
        .collect();
    let mut completions: Vec<Completion> = Vec::new();
    for (i, r) in requests.iter().enumerate() {
        if !rng.bool() {
            continue;
        }
        let finish_cycles =
            r.arrival_cycles.saturating_add((rng.next_u64() >> 40) + i as u64 + 1);
        completions.push(Completion {
            id: r.id,
            model: r.model,
            priority: r.priority,
            instance: rng.usize(0, 3),
            batch_index: rng.usize(0, 5) as u32,
            arrival_cycles: r.arrival_cycles,
            start_cycles: r.arrival_cycles.saturating_add(rng.next_u64() >> 40),
            finish_cycles,
            overlap_cycles: rng.next_u64() >> rng.usize(8, 63),
            residency_hit_cycles: rng.next_u64() >> rng.usize(8, 63),
            // The parser enforces first_token ≤ finish and tokens ≥ 1.
            first_token_cycles: finish_cycles.saturating_sub(rng.next_u64() >> 44),
            tokens: rng.usize(1, 16) as u32,
            kv_refetch_cycles: rng.next_u64() >> rng.usize(8, 63),
            energy_compute_fj: rng.next_u64() >> rng.usize(8, 63),
            energy_dma_fj: rng.next_u64() >> rng.usize(8, 63),
            energy_idle_fj: rng.next_u64() >> rng.usize(8, 63),
        });
    }
    let shed_ids: Vec<u64> = requests.iter().filter(|_| rng.bool()).map(|r| r.id).collect();
    let model_ops: Vec<ModelOps> = models
        .iter()
        .map(|&model| ModelOps {
            model,
            ops: (0..rng.usize(0, 12) as u32)
                .map(|op| OpRecord {
                    op,
                    class: *rng.choose(&OpClass::all()),
                    predicted_cycles: rng.next_u64() >> rng.usize(0, 40),
                    observed_cycles: rng.next_u64() >> rng.usize(0, 40),
                })
                .collect(),
        })
        .collect();
    Trace {
        meta: TraceMeta {
            version: TRACE_FORMAT_VERSION,
            config_fingerprint: rng.next_u64(),
            freq_ghz: rng.f64() * 3.0 + 0.1,
            seed: rng.next_u64(),
            models,
            scheduler: random_scheduler(rng),
        },
        requests,
        shed_ids,
        completions,
        model_ops,
    }
}

#[test]
fn prop_trace_format_round_trips() {
    // serialize → parse → identical trace, across arbitrary metadata,
    // extreme u64 cycle counts, every priority and op class.
    for_each_case(64, 0x7C4CE, |rng| {
        let trace = random_trace(rng);
        let jsonl = trace.to_jsonl();
        let parsed = Trace::parse(&jsonl).unwrap_or_else(|e| panic!("parse failed: {e}"));
        assert_eq!(parsed, trace, "round-trip must be lossless");
        // Serialization is deterministic (byte-identical re-render).
        assert_eq!(parsed.to_jsonl(), jsonl);
    });
}

#[test]
fn prop_corrupt_lines_are_rejected_with_their_line_number() {
    for_each_case(24, 0xBAD1, |rng| {
        let trace = random_trace(rng);
        let jsonl = trace.to_jsonl();
        let n_lines = jsonl.lines().count();
        // Corrupt one random line (truncate it mid-JSON).
        let victim = rng.usize(1, n_lines);
        let corrupted: String = jsonl
            .lines()
            .enumerate()
            .map(|(i, l)| {
                if i + 1 == victim {
                    format!("{}\n", &l[..l.len() / 2])
                } else {
                    format!("{l}\n")
                }
            })
            .collect();
        let err = Trace::parse(&corrupted).unwrap_err().to_string();
        assert!(
            err.contains(&format!("line {victim}")),
            "error should name line {victim}: {err}"
        );
    });
}

#[test]
fn version_mismatch_and_foreign_files_are_rejected() {
    let mut rng = Rng::new(3);
    let trace = random_trace(&mut rng);
    let jsonl = trace.to_jsonl();
    // Future version.
    let future = jsonl.replace("\"version\":4", "\"version\":5");
    let err = Trace::parse(&future).unwrap_err().to_string();
    assert!(err.contains("version 5"), "{err}");
    // Stale version: a PR-8-era v3 trace (no per-completion energy
    // fields) must be rejected by name, not half-parsed with silent
    // defaults.
    let stale = jsonl.replace("\"version\":4", "\"version\":3");
    let err = Trace::parse(&stale).unwrap_err().to_string();
    assert!(
        err.contains("unsupported trace format version 3") && err.contains("version 4"),
        "stale-version error must name both versions: {err}"
    );
    // Wrong format name.
    let foreign = jsonl.replace("eiq-neutron-trace", "some-other-format");
    assert!(Trace::parse(&foreign).is_err());
    // Empty file.
    assert!(Trace::parse("").unwrap_err().to_string().contains("header"));
}

fn random_serve_options(rng: &mut Rng) -> ServeOptions {
    let mut scheduler = random_scheduler(rng);
    // Keep property runtime bounded.
    scheduler.instances = rng.usize(1, 2);
    let mut opts = ServeOptions {
        models: random_models(rng),
        requests: rng.usize(1, 25),
        mean_gap_cycles: rng.int(0, 1_000_000) as u64,
        seed: rng.next_u64(),
        priority_mix: PriorityMix { realtime: 1, standard: 2, batch: 1 },
        scheduler,
        ..ServeOptions::default()
    };
    // Roughly a quarter of the cases exercise the decode path end to end
    // (GptTiny is the zoo's decode-capable model).
    if rng.usize(0, 3) == 0 {
        opts.models = vec![ModelId::GptTiny];
        opts.requests = rng.usize(1, 8);
        opts.decode = true;
        opts.prompt_tokens = rng.usize(1, 8) as u32;
        opts.decode_tokens = rng.usize(1, 6) as u32;
        opts.max_context = 16;
    }
    opts
}

#[test]
fn prop_recorded_serve_replays_to_a_bit_identical_report() {
    // The acceptance property: record a serve run (fresh cache), push the
    // trace through its serialized JSONL form, replay it — the
    // ServeReport must reproduce bit-for-bit (every f64 included) and the
    // replayed completions must match the recording, across random
    // scheduler knobs, shedding policies and batching modes.
    let cfg = NeutronConfig::flagship_2tops();
    for_each_case(8, 0x5EED, |rng| {
        let opts = random_serve_options(rng);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
        let driver = ReplayDriver::from_jsonl(&trace.to_jsonl())
            .unwrap_or_else(|e| panic!("reparse failed: {e}"));
        let replayed = driver.replay(&cfg).unwrap_or_else(|e| panic!("replay failed: {e}"));
        assert!(
            replayed.matches_recording(),
            "replay diverged: {:?}",
            replayed.divergence
        );
        assert_eq!(
            replayed.report, recorded,
            "replayed ServeReport must be bit-identical to the recorded one"
        );
    });
}

#[test]
fn prop_validation_mape_is_computed_from_real_sim_ticks() {
    // The calibration join is grounded in the executor's tick timing: for
    // every profiled model, the observed per-op cycles in the trace must
    // sum to exactly the cycles the executor charges for that program —
    // the same number the serving layer bills a solo dispatch.
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    for_each_case(6, 0xCA1B, |rng| {
        let mut opts = random_serve_options(rng);
        opts.scheduler.queue_capacity = None; // everything dispatches...
        opts.scheduler.energy_budget_fj = None; // ...and nothing is shed
        opts.requests = rng.usize(4, 16);
        let mut fresh = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &opts, &mut fresh);
        assert!(!trace.model_ops.is_empty(), "a dispatching run must profile its models");

        let mut ex = Executor::with_config(cfg.clone());
        for m in &trace.model_ops {
            let entry = cache.get(m.model);
            let observed_total: u64 = m.ops.iter().map(|o| o.observed_cycles).sum();
            let sim = ex.run_program(&entry.program, None).unwrap().sim_cycles;
            assert_eq!(
                observed_total, sim,
                "{:?}: per-op observed cycles must sum to the executor's sim cycles",
                m.model
            );
        }

        let v = ValidationReport::from_trace(&trace).unwrap();
        assert!(!v.rows.is_empty());
        assert!(v.overall_mape_pct.is_finite() && v.overall_mape_pct >= 0.0);
        assert!(v.post_fit_mape_pct.is_finite() && v.post_fit_mape_pct >= 0.0);
        let table = v.table();
        for r in &v.rows {
            assert!(r.ops > 0);
            assert!(r.scale.is_finite() && r.scale > 0.0);
            assert!(table.contains(r.class.name()), "table must list {:?}", r.class);
        }
        // The fitted corrections form a valid calibration the compiler
        // can apply (CostCalibration::from_scales panics on degenerate
        // scales — constructing it IS the check).
        let cal = v.calibration();
        for r in &v.rows {
            assert!(cal.apply(r.class, 1_000) >= 1);
        }
        // Validating the same models directly (no trace) agrees with the
        // trace-derived join — both sides read the same tick attribution.
        let direct = ValidationReport::from_models(
            &trace.model_ops.iter().map(|m| m.model).collect::<Vec<_>>(),
            &cfg,
        );
        assert_eq!(direct, v);
    });
}

#[test]
fn acceptance_record_replay_validate_pipeline() {
    // The CI smoke pipeline in library form: one mixed workload, recorded
    // with shedding + dynamic batching active, replayed bit-identically,
    // then validated with a non-trivial per-class table.
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions {
        models: vec![ModelId::MobileNetV2, ModelId::MobileNetV1, ModelId::EfficientNetLite0],
        requests: 60,
        mean_gap_cycles: 120_000,
        seed: 11,
        priority_mix: PriorityMix::default(),
        scheduler: SchedulerOptions {
            instances: 2,
            queue_capacity: Some(8),
            policy: AdmissionPolicy::RejectNewest,
            max_batch: 4,
            dynamic_batch: true,
            age_after_cycles: Some(2_000_000),
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    };
    let mut cache = CompileCache::for_serving(cfg.clone());
    let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
    assert_eq!(recorded.offered, 60);
    assert!(recorded.p99_ms <= recorded.p999_ms);

    let jsonl = trace.to_jsonl();
    assert!(jsonl.starts_with("{\"event\":\"header\""));
    let replayed = ReplayDriver::from_jsonl(&jsonl).unwrap().replay(&cfg).unwrap();
    assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
    assert_eq!(replayed.report, recorded);

    let v = ValidationReport::from_trace(&trace).unwrap();
    assert!(v.rows.len() >= 3, "a CNN mix spans several op classes: {:?}", v.rows);
    assert!(v.rows.iter().any(|r| r.class == OpClass::Conv));
    assert!(v.table().contains("overall MAPE"));
}

#[test]
fn recorded_pipelined_resident_run_round_trips_its_new_fields() {
    // PR-7 fields end to end: record a pipelined + resident run that
    // actually warms the TCM (one hot model, saturating arrivals, a
    // capacity override big enough that the whole parameter set stays
    // resident), push the trace through its JSONL form, and check that
    // (a) the header round-trips the new scheduler knobs, (b) non-zero
    // `residency_hit_cycles` / `overlap_cycles` survive the format, and
    // (c) replay still reproduces the report bit for bit.
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ServeOptions {
        models: vec![ModelId::MobileNetV3Min],
        requests: 24,
        mean_gap_cycles: 0,
        seed: 13,
        priority_mix: PriorityMix::standard_only(),
        scheduler: SchedulerOptions {
            instances: 1,
            pipeline: true,
            weight_residency: true,
            residency_capacity_bytes: Some(64 << 20),
            ..SchedulerOptions::default()
        },
        ..ServeOptions::default()
    };
    let mut cache = CompileCache::for_serving(cfg.clone());
    let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
    assert_eq!(trace.meta.version, TRACE_FORMAT_VERSION);
    assert!(trace.meta.scheduler.pipeline && trace.meta.scheduler.weight_residency);
    assert!(
        recorded.residency_hits > 0,
        "a single hot model under an ample capacity override must go warm"
    );
    assert!(
        trace.completions.iter().any(|c| c.residency_hit_cycles > 0),
        "warm dispatches must carry their hit cycles into the trace"
    );
    assert_eq!(
        trace.completions.iter().map(|c| c.overlap_cycles).sum::<u64>(),
        recorded.overlap_cycles,
        "per-completion overlap must sum to the report's total"
    );

    let jsonl = trace.to_jsonl();
    let parsed = Trace::parse(&jsonl).unwrap_or_else(|e| panic!("parse failed: {e}"));
    assert_eq!(parsed, trace, "v2 completion fields must survive the JSONL round-trip");
    assert_eq!(parsed.meta.scheduler, opts.scheduler, "header must round-trip the new knobs");

    let replayed = ReplayDriver::from_jsonl(&jsonl).unwrap().replay(&cfg).unwrap();
    assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
    assert_eq!(replayed.report, recorded);
}
