//! iNPU baseline model (Table III's 11-TOPS AI-Vision-processor NPU): a
//! Hailo-class distributed dataflow fabric.
//!
//! The fabric spatially maps the graph and pipelines frames, so the vendor
//! zoo reports *throughput*; per the paper's fairness note we approximate
//! latency as inverse throughput (a lower bound favouring the iNPU).
//!
//! The model's characteristic shape, visible in Table III: excellent on
//! dense-conv pipelines (MobileNetV1/V2, ResNet, YOLO backbones) where the
//! fabric streams at high utilization, but collapsing on workloads that
//! break the spatial mapping — many-branch heads (SSD), non-conv plumbing
//! (resize/concat-heavy BiFPN), very deep thin models (MobileNetV3-Min,
//! EfficientNet-Lite) where per-layer fabric reconfiguration ("context
//! switches") dominates because the graph does not fit in one mapping.

use crate::ir::{Graph, OpKind};

/// iNPU configuration.
#[derive(Debug, Clone)]
pub struct InpuConfig {
    pub name: &'static str,
    pub peak_tops: f64,
    /// Sustained fraction of peak on dense streaming conv work.
    pub dense_efficiency: f64,
    /// Fabric resource budget: ops (layers) mappable per context.
    pub layers_per_context: usize,
    /// Cost of a context switch (fabric reconfiguration), seconds.
    pub context_switch_s: f64,
    /// Per-frame fixed overhead (host I/O, control), seconds.
    pub frame_overhead_s: f64,
}

impl InpuConfig {
    /// The 11-TOPS vision-SoC NPU of Table III.
    pub fn vision_11tops() -> Self {
        Self {
            name: "iNPU",
            peak_tops: 11.0,
            dense_efficiency: 0.55,
            layers_per_context: 64,
            context_switch_s: 450e-6,
            frame_overhead_s: 120e-6,
        }
    }
}

/// Per-model estimate.
#[derive(Debug, Clone, Default)]
pub struct InpuReport {
    pub latency_ms: f64,
    pub contexts: usize,
    pub avg_efficiency: f64,
}

/// Per-op fabric efficiency class.
fn op_efficiency(graph: &Graph, op: &crate::ir::Op, cfg: &InpuConfig) -> f64 {
    let oc = graph.tensor(op.output).shape.c();
    match &op.kind {
        OpKind::Conv2d { geom, .. } => {
            // Dense convs stream well; tiny 1×1 reductions less so.
            let k = geom.filter_h * geom.filter_w;
            let width_factor = (oc as f64 / 64.0).min(1.0).max(0.25);
            if k >= 9 {
                cfg.dense_efficiency * width_factor.max(0.8)
            } else {
                cfg.dense_efficiency * width_factor
            }
        }
        // Depthwise: fabric elements idle on the reduction dimension.
        // 5×5 kernels are not native to the fabric and decompose into
        // chained 3×3 passes (EfficientNet-Lite's Achilles heel here).
        OpKind::DepthwiseConv2d { geom } if geom.filter_h >= 5 => cfg.dense_efficiency * 0.03,
        OpKind::DepthwiseConv2d { .. } => cfg.dense_efficiency * 0.15,
        OpKind::FullyConnected { .. } | OpKind::MatMul { .. } => cfg.dense_efficiency * 0.5,
        _ => cfg.dense_efficiency * 0.25, // vector/data plumbing
    }
}

/// How many fabric contexts the graph needs: one per `layers_per_context`
/// mappable ops, plus extra contexts for each distinct output head beyond
/// the first two (multi-head detection graphs fragment the mapping).
fn contexts_needed(graph: &Graph, cfg: &InpuConfig) -> usize {
    let compute_ops = graph.ops.iter().filter(|o| o.is_compute()).count();
    let base = compute_ops.div_ceil(cfg.layers_per_context);
    let head_penalty = graph.outputs.len().saturating_sub(2) / 2;
    // 5×5-depthwise stages break the streaming mapping (decomposed
    // kernels need their own fabric segment).
    let k5_dw = graph
        .ops
        .iter()
        .filter(|o| matches!(&o.kind, OpKind::DepthwiseConv2d { geom } if geom.filter_h >= 5))
        .count();
    base + head_penalty + k5_dw
}

/// Estimate batch-1 "latency" (inverse throughput) of `graph`.
pub fn estimate(graph: &Graph, cfg: &InpuConfig) -> InpuReport {
    let mut seconds = cfg.frame_overhead_s;
    let mut weighted_eff = 0f64;
    let mut total_macs = 0f64;
    for op in &graph.ops {
        let macs = graph.op_macs(op) as f64;
        if macs == 0.0 {
            continue;
        }
        let eff = op_efficiency(graph, op, cfg);
        seconds += 2.0 * macs / (cfg.peak_tops * 1e12 * eff);
        weighted_eff += eff * macs;
        total_macs += macs;
    }
    let contexts = contexts_needed(graph, cfg);
    if contexts > 1 {
        seconds += contexts as f64 * cfg.context_switch_s;
    }
    InpuReport {
        latency_ms: seconds * 1e3,
        contexts,
        avg_efficiency: if total_macs > 0.0 { weighted_eff / total_macs } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn fast_on_dense_conv_models() {
        let cfg = InpuConfig::vision_11tops();
        let v1 = estimate(&zoo::mobilenet::mobilenet_v1(), &cfg);
        assert!(v1.latency_ms < 1.0, "MNv1 should be sub-ms, got {}", v1.latency_ms);
    }

    #[test]
    fn slow_on_fragmented_detection_heads() {
        let cfg = InpuConfig::vision_11tops();
        let ssd = estimate(&zoo::ssd::mobilenet_v2_ssdlite(), &cfg);
        let v2 = estimate(&zoo::mobilenet::mobilenet_v2(), &cfg);
        // SSD heads fragment the fabric mapping: much worse than the bare
        // backbone despite only ~2.7× the MACs.
        assert!(ssd.latency_ms > 8.0 * v2.latency_ms);
    }

    #[test]
    fn yolo_remains_competitive() {
        let cfg = InpuConfig::vision_11tops();
        let y = estimate(&zoo::yolo::yolov8n_det(), &cfg);
        // Paper: iNPU leads raw latency on YOLOv8n (3.5 ms).
        assert!(y.latency_ms < 8.0, "got {}", y.latency_ms);
    }

    #[test]
    fn context_count_grows_with_depth() {
        let cfg = InpuConfig::vision_11tops();
        let shallow = contexts_needed(&zoo::mobilenet::mobilenet_v1(), &cfg);
        let deep = contexts_needed(&zoo::efficientnet::efficientdet_lite0(), &cfg);
        assert!(deep > shallow);
    }
}
