//! eNPU baseline model (Table III's eNPU-A / eNPU-B): an Arm-Ethos-class
//! embedded NPU with a weight-stationary 2-D MAC array and a conventional
//! (non-CP) compiler.
//!
//! The model captures the two effects the paper's speedup comes from:
//!
//!   1. **Utilization collapse on mismatched shapes.** The MAC array is a
//!      fixed IC×OC grid; layers with few input or output channels strand
//!      rows/columns (depthwise convs use one row). The Neutron dot-product
//!      structure + two-way spatial tiling avoids most of this.
//!   2. **No cross-layer fusion.** Execution is layer-by-layer with the
//!      SRAM used as a feature-map cache: any intermediate activation that
//!      does not fit must round-trip to DRAM, and weights stream from DRAM
//!      every layer. The Neutron compiler's fusion keeps high-resolution
//!      intermediates on-chip — the YOLO-class win.
//!
//!   Per layer: latency = max(compute, DDR stream) + dispatch overhead —
//!   an optimistic double-buffered model (the vendor's real scheduler
//!   hides DMA behind compute within a layer, so we grant that).

use crate::ir::{Graph, OpKind, TensorKind};

/// eNPU configuration.
#[derive(Debug, Clone)]
pub struct EnpuConfig {
    pub name: &'static str,
    /// MAC array geometry: input-channel rows × output-channel columns.
    pub array_ic: usize,
    pub array_oc: usize,
    pub freq_ghz: f64,
    pub sram_bytes: usize,
    pub ddr_gbps: f64,
    /// Per-layer command/dispatch overhead in cycles.
    pub layer_overhead: u64,
    /// Effective bandwidth of host-CPU fallback processing, GB/s. The
    /// eNPU's activation path fuses ReLU-family functions only; Swish/Mish
    /// (YOLOv8's SiLU) fall back to the host runtime — the feature map
    /// round-trips through DRAM and the host computes the nonlinearity at
    /// CPU speeds (cf. Sec. II: "fallback to host resources for
    /// unsupported operators"; the Neutron activation engine runs these
    /// natively, Sec. III-B).
    pub host_fallback_gbps: f64,
}

impl EnpuConfig {
    /// eNPU-A: 2 TOPS, 1 MiB SRAM, 12 GB/s (Table III row 2).
    pub fn enpu_a() -> Self {
        Self {
            name: "eNPU-A",
            array_ic: 32,
            array_oc: 32,
            freq_ghz: 1.0,
            sram_bytes: 1 << 20,
            ddr_gbps: 12.0,
            layer_overhead: 2048,
            host_fallback_gbps: 1.0,
        }
    }

    /// eNPU-B: 4 TOPS, 2 MiB SRAM, 24 GB/s (Table III row 3).
    pub fn enpu_b() -> Self {
        Self {
            name: "eNPU-B",
            array_ic: 64,
            array_oc: 32,
            sram_bytes: 2 << 20,
            ddr_gbps: 24.0,
            ..Self::enpu_a()
        }
    }

    pub fn peak_tops(&self) -> f64 {
        2.0 * (self.array_ic * self.array_oc) as f64 * self.freq_ghz * 1e9 / 1e12
    }
}

/// Per-model latency estimate.
#[derive(Debug, Clone, Default)]
pub struct EnpuReport {
    pub latency_ms: f64,
    pub ddr_bytes: u64,
    /// MAC-array utilization averaged over compute cycles.
    pub avg_utilization: f64,
}

/// Estimate batch-1 latency of `graph` on the eNPU.
pub fn estimate(graph: &Graph, cfg: &EnpuConfig) -> EnpuReport {
    let freq = cfg.freq_ghz * 1e9;
    let ddr_bytes_per_cycle = cfg.ddr_gbps / cfg.freq_ghz;
    let mut total_cycles = 0f64;
    let mut ddr_bytes = 0u64;
    let mut util_weighted = 0f64;
    let mut compute_cycles_sum = 0f64;

    // Liveness: last consumer index per tensor. The SRAM cache must hold
    // every tensor produced but not yet fully consumed (branches of C2f /
    // residual / FPN structures stay alive for long spans), not just the
    // current layer's operands — this is what breaks cache-managed NPUs on
    // YOLO-class graphs while the Neutron compiler's fusion handles them.
    let mut last_consumer: std::collections::HashMap<crate::ir::TensorId, usize> =
        std::collections::HashMap::new();
    for (oi, op) in graph.ops.iter().enumerate() {
        for &t in &op.inputs {
            last_consumer.insert(t, oi);
        }
    }
    let mut alive: std::collections::HashMap<crate::ir::TensorId, u64> =
        std::collections::HashMap::new();

    for (oi, op) in graph.ops.iter().enumerate() {
        let out = graph.tensor(op.output);
        let (oh, ow, oc) = (out.shape.h(), out.shape.w(), out.shape.c());
        let in_t = op.inputs.first().map(|&t| graph.tensor(t));
        let ic = in_t.map(|t| t.shape.c()).unwrap_or(1);

        // --- Array utilization per op class ---
        let (macs, eff_rows, eff_cols): (u64, f64, f64) = match &op.kind {
            OpKind::Conv2d { geom, .. } => {
                let macs = (oh * ow * oc * geom.filter_h * geom.filter_w * ic) as u64;
                // Weight-stationary array: rows = input channels (×kernel
                // positions folded over time), cols = output channels.
                let rows = (ic.min(cfg.array_ic)) as f64 / cfg.array_ic as f64;
                let cols = (oc.min(cfg.array_oc)) as f64 / cfg.array_oc as f64;
                (macs, rows, cols)
            }
            OpKind::DepthwiseConv2d { geom } => {
                let macs = (oh * ow * oc * geom.filter_h * geom.filter_w) as u64;
                // Depthwise occupies one array row per channel batch.
                (macs, 1.0 / cfg.array_ic as f64, (oc.min(cfg.array_oc)) as f64 / cfg.array_oc as f64)
            }
            OpKind::FullyConnected { .. } | OpKind::MatMul { .. } => {
                let macs = (oh * ow * oc) as u64 * ic as u64;
                let rows = (ic.min(cfg.array_ic)) as f64 / cfg.array_ic as f64;
                let cols = (oc.min(cfg.array_oc)) as f64 / cfg.array_oc as f64;
                (macs, rows, cols)
            }
            OpKind::Add | OpKind::Mul | OpKind::ScalarAddMul | OpKind::Pool { .. }
            | OpKind::GlobalAvgPool | OpKind::ActivationOnly(_) | OpKind::Softmax => {
                // Vector engine: one lane row.
                let elems = (oh * ow * oc) as u64;
                (elems, 1.0 / cfg.array_ic as f64, 1.0)
            }
            OpKind::Concat | OpKind::Reshape | OpKind::ResizeNearest { .. }
            | OpKind::ResizeTo { .. } | OpKind::SpaceToDepth { .. } => (0, 1.0, 1.0),
        };
        let util = (eff_rows * eff_cols).max(1e-4);
        let peak_macs_cycle = (cfg.array_ic * cfg.array_oc) as f64;
        let compute_cycles = if macs > 0 {
            macs as f64 / (peak_macs_cycle * util)
        } else {
            0.0
        };

        // --- DDR traffic: weights stream every layer; activations
        // round-trip when the *live set* (current operands + all branch
        // tensors still awaiting consumers) exceeds SRAM. ---
        let w_bytes = op
            .params
            .map(|p| graph.tensor(p).size_bytes() as u64)
            .unwrap_or(0);
        let in_bytes: u64 = op
            .inputs
            .iter()
            .map(|&t| graph.tensor(t).size_bytes() as u64)
            .sum();
        let out_bytes = out.size_bytes() as u64;

        // Update the live set: this op's output joins; fully-consumed
        // tensors leave.
        alive.insert(op.output, out_bytes);
        alive.retain(|t, _| last_consumer.get(t).is_none_or(|&l| l > oi));
        let alive_bytes: u64 = alive.values().sum();

        let mut layer_ddr = w_bytes; // weights always stream (cache-managed)
        if alive_bytes + w_bytes + in_bytes > cfg.sram_bytes as u64 {
            // Cache thrashes: the layer's activations round-trip off-chip
            // (write output now, re-read inputs that were evicted).
            layer_ddr += in_bytes + out_bytes;
        }
        // Data-plumbing ops the array cannot fuse (concat / reshape /
        // space-to-depth) flush through memory on this class of NPU.
        if !op.is_compute() {
            layer_ddr += in_bytes + out_bytes;
        }
        // Graph inputs always arrive from DRAM; outputs always leave.
        if op.inputs.iter().any(|&t| graph.tensor(t).kind == TensorKind::Input) {
            layer_ddr += in_bytes;
        }
        if graph.outputs.contains(&op.output) {
            layer_ddr += out_bytes;
        }
        ddr_bytes += layer_ddr;
        let ddr_cycles = layer_ddr as f64 / ddr_bytes_per_cycle;

        // Double-buffered layer execution: bound by the slower engine.
        total_cycles += compute_cycles.max(ddr_cycles) + cfg.layer_overhead as f64;

        // Host fallback for activations outside the ReLU family: the
        // feature map leaves the NPU, the host reads+transforms+writes it,
        // and the NPU reads it back. Strictly sequential (no overlap).
        if matches!(
            op.fused_activation,
            crate::ir::Activation::Swish | crate::ir::Activation::Mish
        ) {
            let host_bytes_per_cycle = cfg.host_fallback_gbps / cfg.freq_ghz;
            // NPU→DRAM→host(read+write)→DRAM→NPU ≈ 3 passes over the map.
            let host_cycles = 3.0 * out_bytes as f64 / host_bytes_per_cycle;
            ddr_bytes += 2 * out_bytes;
            total_cycles += host_cycles + cfg.layer_overhead as f64;
        }

        util_weighted += util * compute_cycles;
        compute_cycles_sum += compute_cycles;
    }

    EnpuReport {
        latency_ms: total_cycles / freq * 1e3,
        ddr_bytes,
        avg_utilization: if compute_cycles_sum > 0.0 {
            util_weighted / compute_cycles_sum
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo;

    #[test]
    fn configs_have_expected_peaks() {
        assert!((EnpuConfig::enpu_a().peak_tops() - 2.048).abs() < 0.05);
        assert!((EnpuConfig::enpu_b().peak_tops() - 4.096).abs() < 0.1);
    }

    #[test]
    fn enpu_b_is_faster_than_a() {
        let g = zoo::mobilenet::mobilenet_v2();
        let a = estimate(&g, &EnpuConfig::enpu_a());
        let b = estimate(&g, &EnpuConfig::enpu_b());
        assert!(b.latency_ms < a.latency_ms);
    }

    #[test]
    fn depthwise_models_have_low_utilization() {
        let g = zoo::mobilenet::mobilenet_v1();
        let r = estimate(&g, &EnpuConfig::enpu_a());
        assert!(r.avg_utilization < 0.6, "util={}", r.avg_utilization);
    }

    #[test]
    fn yolo_spills_heavily() {
        let g = zoo::yolo::yolov8n_det();
        let r = estimate(&g, &EnpuConfig::enpu_a());
        // 640×640 activations cannot be cached layer-by-layer in 1 MiB:
        // tens of MB of spill + fallback traffic vs ~3 MB of weights.
        assert!(r.ddr_bytes > 60_000_000, "ddr={}", r.ddr_bytes);
    }
}
