//! CPU baseline: 4× Cortex-A55 cluster for the Gen-AI comparison (Sec. VI:
//! "tenfold speedups compared to execution on four Cortex-A55 cores at
//! 1.8× the clock frequency").
//!
//! Analytical NEON INT8 GEMM model: one 128-bit NEON pipe per A55 issues a
//! 16-wide int8 dot-product-accumulate (SDOT) per cycle at best; real GEMM
//! kernels sustain a fraction of that (load/store pressure, L1/L2 misses on
//! panel traversal), lower still for memory-bound thin matrices.

use crate::ir::{Graph, OpKind};

/// CPU cluster configuration.
#[derive(Debug, Clone)]
pub struct CpuConfig {
    pub name: &'static str,
    pub cores: usize,
    pub freq_ghz: f64,
    /// Peak int8 MACs per cycle per core (SDOT on one 128-bit pipe).
    pub macs_per_cycle: f64,
    /// Sustained GEMM efficiency for cache-resident panels.
    pub gemm_efficiency: f64,
    /// DDR bandwidth available to the cluster, GB/s.
    pub ddr_gbps: f64,
}

impl CpuConfig {
    /// 4×A55 at 1.8 GHz (the paper's NPU runs at 1.0 GHz ⇒ CPU has 1.8×
    /// the clock, as Sec. VI specifies).
    pub fn quad_a55_1_8ghz() -> Self {
        Self {
            name: "4xCortex-A55",
            cores: 4,
            freq_ghz: 1.8,
            macs_per_cycle: 16.0,
            gemm_efficiency: 0.55,
            ddr_gbps: 12.0,
        }
    }

    pub fn peak_tops(&self) -> f64 {
        2.0 * self.cores as f64 * self.macs_per_cycle * self.freq_ghz * 1e9 / 1e12
    }
}

/// Estimate latency of the graph's GEMM work on the CPU cluster.
pub fn estimate_ms(graph: &Graph, cfg: &CpuConfig) -> f64 {
    let mut seconds = 0f64;
    for op in &graph.ops {
        let macs = graph.op_macs(op) as f64;
        if macs == 0.0 {
            continue;
        }
        let eff = match &op.kind {
            OpKind::MatMul { .. } | OpKind::FullyConnected { .. } | OpKind::Conv2d { .. } => {
                cfg.gemm_efficiency
            }
            OpKind::DepthwiseConv2d { .. } => cfg.gemm_efficiency * 0.4,
            _ => cfg.gemm_efficiency * 0.5,
        };
        let compute_s =
            macs / (cfg.cores as f64 * cfg.macs_per_cycle * cfg.freq_ghz * 1e9 * eff);
        // Memory bound for thin GEMMs: weights must stream at least once.
        let w_bytes = op
            .params
            .map(|p| graph.tensor(p).size_bytes() as f64)
            .unwrap_or(0.0);
        let mem_s = w_bytes / (cfg.ddr_gbps * 1e9);
        seconds += compute_s.max(mem_s);
    }
    seconds * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::{decoder_prefill, TransformerConfig};

    #[test]
    fn peak_is_fraction_of_a_tops() {
        let c = CpuConfig::quad_a55_1_8ghz();
        // 2·4·16·1.8e9 = 0.23 TOPS peak.
        assert!((c.peak_tops() - 0.2304).abs() < 0.001);
    }

    #[test]
    fn transformer_prefill_takes_tens_of_ms() {
        let g = decoder_prefill(TransformerConfig::gpt_100m(128));
        let ms = estimate_ms(&g, &CpuConfig::quad_a55_1_8ghz());
        // ~14 GMACs of GEMMs on ~0.13 effective TOPS → O(100 ms).
        assert!(ms > 50.0 && ms < 2000.0, "ms={ms}");
    }
}
