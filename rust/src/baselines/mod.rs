//! Baseline accelerator models for the Table I/III comparisons: an
//! Ethos-class embedded NPU (eNPU-A/B), a Hailo-class 11-TOPS vision-SoC
//! NPU (iNPU), and a 4×Cortex-A55 CPU cluster (Gen-AI claim, Sec. VI).
//!
//! These replace the vendor toolchains/model zoos the paper measured; see
//! DESIGN.md §2 for the substitution rationale. Parameters are calibrated
//! so the *shape* of Table III (who wins where, rough factors) reproduces —
//! absolute numbers are not the claim.

pub mod cpu;
pub mod enpu;
pub mod inpu;

pub use cpu::CpuConfig;
pub use enpu::{EnpuConfig, EnpuReport};
pub use inpu::{InpuConfig, InpuReport};
