//! Operator set of the IR.
//!
//! Mirrors the paper's lowering rules (Sec. IV-A): fully-connected layers
//! and matmuls are 1×1 convolutions; element-wise add/mul are paired
//! depthwise ops; scalar ops are 1×1 depthwise ops. Every op carries enough
//! metadata for the cost model (MACs, operand footprints) and for the
//! format-selection pass (spatial structure).

use super::tensor::TensorId;

/// Activation functions applied by the dedicated activation engine
/// (Sec. III-B) — fused into the compute job, zero extra memory traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Relu6,
    /// Swish / SiLU (EfficientNet, YOLOv8).
    Swish,
    /// Hard-swish (MobileNetV3).
    HardSwish,
    Sigmoid,
    Mish,
}

/// Padding mode for spatial ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

/// Convolution geometry shared by conv / depthwise-conv.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeometry {
    pub filter_h: usize,
    pub filter_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub padding: Padding,
    pub dilation: usize,
}

impl ConvGeometry {
    pub fn unit() -> Self {
        Self { filter_h: 1, filter_w: 1, stride_h: 1, stride_w: 1, padding: Padding::Same, dilation: 1 }
    }

    pub fn square(k: usize, s: usize, padding: Padding) -> Self {
        Self { filter_h: k, filter_w: k, stride_h: s, stride_w: s, padding, dilation: 1 }
    }

    /// Output spatial size given input spatial size.
    pub fn out_dim(&self, in_dim: usize, filter: usize, stride: usize) -> usize {
        match self.padding {
            Padding::Same => in_dim.div_ceil(stride),
            Padding::Valid => {
                let eff = (filter - 1) * self.dilation + 1;
                if in_dim < eff {
                    0
                } else {
                    (in_dim - eff) / stride + 1
                }
            }
        }
    }
}

/// Pooling flavour (on-the-fly min/max pooling is fused by the activation
/// engine; average pooling is a standalone kernel-library op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Operator kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Standard convolution: ifmap (H,W,Cin) ⊛ params (Cout,fh,fw,Cin).
    Conv2d { geom: ConvGeometry, out_c: usize },
    /// Depthwise convolution (multiplier 1).
    DepthwiseConv2d { geom: ConvGeometry },
    /// Fully connected == 1×1 conv over a 1×1 spatial map (paper IV-A).
    FullyConnected { out_features: usize },
    /// Matmul over (tokens, emb) treated as H=tokens, C=emb (paper IV-A).
    MatMul { out_features: usize },
    /// Element-wise add of two tensors (paired depthwise op).
    Add,
    /// Element-wise multiply (Hadamard; paired depthwise op).
    Mul,
    /// Scalar op (constant operand): 1×1 depthwise.
    ScalarAddMul,
    /// Pooling.
    Pool { kind: PoolKind, size: usize, stride: usize },
    /// Global average pool to 1×1×C.
    GlobalAvgPool,
    /// Resize (nearest) — upsampling in detection heads / FPN necks.
    ResizeNearest { scale: usize },
    /// Resize (nearest) to an explicit spatial size — BiFPN levels with
    /// odd sizes (e.g. 5→3) that integer scaling cannot express.
    ResizeTo { h: usize, w: usize },
    /// Channel concat of inputs.
    Concat,
    /// Spatial reshape/flatten — zero-compute, may need data rearrangement.
    Reshape,
    /// Softmax — host/activation-engine op in classifiers and heads.
    Softmax,
    /// Standalone activation (when not fuseable into a producer).
    ActivationOnly(Activation),
    /// Space-to-depth style stem (YOLO focus) — data movement only.
    SpaceToDepth { block: usize },
}

/// Coarse operator class used by the timing-model calibration pass
/// (`trace/validate.rs`): per-class predicted-vs-observed statistics are
/// only meaningful when ops with the same cost structure are grouped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    /// Standard convolutions (dense dot-product work).
    Conv,
    /// Depthwise convolutions (per-channel dot products).
    DepthwiseConv,
    /// FC / matmul layers (1×1-conv lowering, Sec. IV-A).
    Matmul,
    /// Element-wise and standalone-activation ops (paired depthwise).
    Elementwise,
    /// Pooling (windowed and global).
    Pool,
    /// Softmax (activation-engine / host op).
    Softmax,
    /// Pure data movement (reshape, concat, resize, space-to-depth).
    DataMovement,
}

impl OpClass {
    /// Every class, in the fixed reporting order.
    pub fn all() -> [OpClass; 7] {
        use OpClass::*;
        [Conv, DepthwiseConv, Matmul, Elementwise, Pool, Softmax, DataMovement]
    }

    /// Stable machine-readable name (also the trace-format spelling).
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Conv => "conv",
            OpClass::DepthwiseConv => "depthwise",
            OpClass::Matmul => "matmul",
            OpClass::Elementwise => "elementwise",
            OpClass::Pool => "pool",
            OpClass::Softmax => "softmax",
            OpClass::DataMovement => "data-movement",
        }
    }

    /// Parse the [`OpClass::name`] spelling back.
    pub fn parse(s: &str) -> Option<OpClass> {
        OpClass::all().into_iter().find(|c| c.name() == s)
    }
}

/// Unique op id inside a graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

impl OpId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One operator node.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Activation-tensor inputs (order matters: ifmap first).
    pub inputs: Vec<TensorId>,
    /// Parameter tensor (weights+bias), if any.
    pub params: Option<TensorId>,
    pub output: TensorId,
    /// Fused activation applied by the activation engine.
    pub fused_activation: Activation,
}

impl Op {
    /// True if this op runs on the dot-product array (vs pure data movement
    /// / host fallback).
    pub fn is_compute(&self) -> bool {
        !matches!(
            self.kind,
            OpKind::Reshape
                | OpKind::Concat
                | OpKind::SpaceToDepth { .. }
                | OpKind::ResizeTo { .. }
        )
    }

    /// Calibration class of this op (see [`OpClass`]).
    pub fn class(&self) -> OpClass {
        match self.kind {
            OpKind::Conv2d { .. } => OpClass::Conv,
            OpKind::DepthwiseConv2d { .. } => OpClass::DepthwiseConv,
            OpKind::FullyConnected { .. } | OpKind::MatMul { .. } => OpClass::Matmul,
            OpKind::Add | OpKind::Mul | OpKind::ScalarAddMul | OpKind::ActivationOnly(_) => {
                OpClass::Elementwise
            }
            OpKind::Pool { .. } | OpKind::GlobalAvgPool => OpClass::Pool,
            OpKind::Softmax => OpClass::Softmax,
            OpKind::Reshape
            | OpKind::Concat
            | OpKind::ResizeNearest { .. }
            | OpKind::ResizeTo { .. }
            | OpKind::SpaceToDepth { .. } => OpClass::DataMovement,
        }
    }

    /// True if lowered as a depthwise-style op (each engine only needs its
    /// own channel slice of the inputs — Sec. IV-A special case).
    pub fn is_depthwise_style(&self) -> bool {
        matches!(
            self.kind,
            OpKind::DepthwiseConv2d { .. }
                | OpKind::Add
                | OpKind::Mul
                | OpKind::ScalarAddMul
                | OpKind::Pool { .. }
                | OpKind::ActivationOnly(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_dims_same_padding() {
        let g = ConvGeometry::square(3, 2, Padding::Same);
        assert_eq!(g.out_dim(224, 3, 2), 112);
        assert_eq!(g.out_dim(7, 3, 2), 4);
    }

    #[test]
    fn conv_out_dims_valid_padding() {
        let g = ConvGeometry::square(3, 1, Padding::Valid);
        assert_eq!(g.out_dim(224, 3, 1), 222);
        let g2 = ConvGeometry::square(7, 2, Padding::Valid);
        assert_eq!(g2.out_dim(224, 7, 2), 109);
    }

    #[test]
    fn depthwise_style_classification() {
        let op = Op {
            id: OpId(0),
            name: "dw".into(),
            kind: OpKind::DepthwiseConv2d { geom: ConvGeometry::square(3, 1, Padding::Same) },
            inputs: vec![TensorId(0)],
            params: Some(TensorId(1)),
            output: TensorId(2),
            fused_activation: Activation::Relu6,
        };
        assert!(op.is_depthwise_style());
        assert!(op.is_compute());
        let reshape = Op {
            id: OpId(1),
            name: "rs".into(),
            kind: OpKind::Reshape,
            inputs: vec![TensorId(2)],
            params: None,
            output: TensorId(3),
            fused_activation: Activation::None,
        };
        assert!(!reshape.is_compute());
    }
}
