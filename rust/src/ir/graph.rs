//! The NN graph: tensors + ops with shape inference and MAC accounting.

use super::op::{Activation, ConvGeometry, Op, OpId, OpKind, PoolKind};
use super::quant::QuantParams;
use super::tensor::{DType, Shape, TensorId, TensorInfo, TensorKind};

/// A directed acyclic graph of operators over tensors. Built by the `zoo`
/// model builders, consumed by the compiler pipeline.
#[derive(Debug, Default, Clone)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<Op>,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    /// Register a tensor.
    pub fn add_tensor(
        &mut self,
        name: impl Into<String>,
        shape: Shape,
        dtype: DType,
        kind: TensorKind,
    ) -> TensorId {
        let id = TensorId(self.tensors.len() as u32);
        self.tensors.push(TensorInfo {
            id,
            name: name.into(),
            shape,
            dtype,
            kind,
            quant: Some(QuantParams::new(0.05, 0)),
        });
        if kind == TensorKind::Input {
            self.inputs.push(id);
        }
        id
    }

    /// Register an op; returns its id.
    pub fn add_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        params: Option<TensorId>,
        output: TensorId,
        fused_activation: Activation,
    ) -> OpId {
        let id = OpId(self.ops.len() as u32);
        self.ops.push(Op { id, name: name.into(), kind, inputs, params, output, fused_activation });
        id
    }

    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.index()]
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id.index()]
    }

    /// The op producing a tensor, if any.
    pub fn producer(&self, t: TensorId) -> Option<&Op> {
        self.ops.iter().find(|o| o.output == t)
    }

    /// Ops consuming a tensor as an activation input.
    pub fn consumers(&self, t: TensorId) -> Vec<&Op> {
        self.ops.iter().filter(|o| o.inputs.contains(&t)).collect()
    }

    /// Mark a tensor as a network output.
    pub fn mark_output(&mut self, t: TensorId) {
        self.tensors[t.index()].kind = TensorKind::Output;
        if !self.outputs.contains(&t) {
            self.outputs.push(t);
        }
    }

    /// MAC count of one op (0 for data-movement ops). Element-wise and pool
    /// ops are counted at one op/output-element like the paper's G-MACs
    /// accounting (dominated by convs anyway).
    pub fn op_macs(&self, op: &Op) -> u64 {
        let out = &self.tensor(op.output).shape;
        let (oh, ow, oc) = (out.h() as u64, out.w() as u64, out.c() as u64);
        match &op.kind {
            OpKind::Conv2d { geom, .. } => {
                let in_c = self.tensor(op.inputs[0]).shape.c() as u64;
                oh * ow * oc * geom.filter_h as u64 * geom.filter_w as u64 * in_c
            }
            OpKind::DepthwiseConv2d { geom } => {
                oh * ow * oc * geom.filter_h as u64 * geom.filter_w as u64
            }
            OpKind::FullyConnected { .. } | OpKind::MatMul { .. } => {
                let in_c = self.tensor(op.inputs[0]).shape.c() as u64;
                oh * ow * oc * in_c
            }
            OpKind::Add | OpKind::Mul | OpKind::ScalarAddMul | OpKind::ActivationOnly(_) => 0,
            OpKind::Pool { size, .. } => oh * ow * oc * (*size as u64).pow(2) / 2,
            OpKind::GlobalAvgPool => {
                let inp = &self.tensor(op.inputs[0]).shape;
                (inp.num_elements() as u64) / 2
            }
            OpKind::Softmax
            | OpKind::Reshape
            | OpKind::Concat
            | OpKind::ResizeNearest { .. }
            | OpKind::ResizeTo { .. }
            | OpKind::SpaceToDepth { .. } => 0,
        }
    }

    /// Total MACs of the graph.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| self.op_macs(o)).sum()
    }

    /// Total parameter count (weights + biases).
    pub fn total_params(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Parameter)
            .map(|t| t.shape.num_elements() as u64)
            .sum()
    }

    /// Ops in topological order (the builders emit them in order; verify).
    pub fn topo_order(&self) -> Vec<OpId> {
        // Builders append in dependency order. Validate with a ready-set
        // sweep so a malformed zoo model fails loudly.
        let mut ready: Vec<bool> = self
            .tensors
            .iter()
            .map(|t| matches!(t.kind, TensorKind::Input | TensorKind::Parameter))
            .collect();
        let mut order = Vec::with_capacity(self.ops.len());
        let mut emitted = vec![false; self.ops.len()];
        loop {
            let mut progressed = false;
            for op in &self.ops {
                if emitted[op.id.index()] {
                    continue;
                }
                if op.inputs.iter().all(|t| ready[t.index()]) {
                    ready[op.output.index()] = true;
                    emitted[op.id.index()] = true;
                    order.push(op.id);
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        assert_eq!(
            order.len(),
            self.ops.len(),
            "graph {} has a cycle or dangling input",
            self.name
        );
        order
    }

    /// Structural sanity check: shapes consistent with op geometry.
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            let out = &self.tensor(op.output).shape;
            match &op.kind {
                OpKind::Conv2d { geom, out_c } => {
                    let inp = &self.tensor(op.inputs[0]).shape;
                    let eh = geom.out_dim(inp.h(), geom.filter_h, geom.stride_h);
                    let ew = geom.out_dim(inp.w(), geom.filter_w, geom.stride_w);
                    if (out.h(), out.w(), out.c()) != (eh, ew, *out_c) {
                        return Err(format!(
                            "{}: conv output {:?} != expected ({eh},{ew},{out_c})",
                            op.name, out.0
                        ));
                    }
                }
                OpKind::DepthwiseConv2d { geom } => {
                    let inp = &self.tensor(op.inputs[0]).shape;
                    if out.c() != inp.c() {
                        return Err(format!("{}: depthwise changes channels", op.name));
                    }
                    let eh = geom.out_dim(inp.h(), geom.filter_h, geom.stride_h);
                    if out.h() != eh {
                        return Err(format!("{}: depthwise H {} != {}", op.name, out.h(), eh));
                    }
                }
                OpKind::Add | OpKind::Mul => {
                    let a = &self.tensor(op.inputs[0]).shape;
                    let b = &self.tensor(op.inputs[1]).shape;
                    if a != b || a != out {
                        return Err(format!("{}: eltwise shape mismatch", op.name));
                    }
                }
                OpKind::Concat => {
                    let total_c: usize =
                        op.inputs.iter().map(|&t| self.tensor(t).shape.c()).sum();
                    if out.c() != total_c {
                        return Err(format!("{}: concat channels {} != {}", op.name, out.c(), total_c));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// Fluent helper for the zoo builders: tracks the "current" tensor and
/// appends quantized conv blocks with correct shape inference.
pub struct GraphBuilder {
    pub graph: Graph,
    cur: TensorId,
    /// Default activation used by zoo helpers that are parametric over the
    /// model family's nonlinearity (e.g. SiLU for YOLOv8, ReLU for the
    /// DAMO-YOLO edge deployment).
    default_act: Activation,
}

impl GraphBuilder {
    /// Start a graph with an HWC input image.
    pub fn with_input(name: impl Into<String>, h: usize, w: usize, c: usize) -> Self {
        let mut graph = Graph::new(name);
        let cur = graph.add_tensor("input", Shape::hwc(h, w, c), DType::Int8, TensorKind::Input);
        Self { graph, cur, default_act: Activation::Relu }
    }

    /// Set the family default activation (see `default_act`).
    pub fn set_default_activation(&mut self, a: Activation) {
        self.default_act = a;
    }

    /// The family default activation.
    pub fn act_override(&self) -> Activation {
        self.default_act
    }

    pub fn current(&self) -> TensorId {
        self.cur
    }

    pub fn set_current(&mut self, t: TensorId) {
        self.cur = t;
    }

    pub fn current_shape(&self) -> &Shape {
        &self.graph.tensor(self.cur).shape
    }

    fn act_tensor(&mut self, name: String, shape: Shape) -> TensorId {
        self.graph.add_tensor(name, shape, DType::Int8, TensorKind::Activation)
    }

    /// Conv2d + fused activation, updating the current tensor.
    pub fn conv(
        &mut self,
        name: &str,
        out_c: usize,
        geom: ConvGeometry,
        act: Activation,
    ) -> TensorId {
        self.conv_from(self.cur, name, out_c, geom, act)
    }

    /// Conv2d from an explicit input tensor.
    pub fn conv_from(
        &mut self,
        src: TensorId,
        name: &str,
        out_c: usize,
        geom: ConvGeometry,
        act: Activation,
    ) -> TensorId {
        let inp = self.graph.tensor(src).shape.clone();
        let oh = geom.out_dim(inp.h(), geom.filter_h, geom.stride_h);
        let ow = geom.out_dim(inp.w(), geom.filter_w, geom.stride_w);
        let w = self.graph.add_tensor(
            format!("{name}.w"),
            Shape(vec![out_c, geom.filter_h, geom.filter_w, inp.c()]),
            DType::Int8,
            TensorKind::Parameter,
        );
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(oh, ow, out_c));
        self.graph.add_op(name, OpKind::Conv2d { geom, out_c }, vec![src], Some(w), out, act);
        self.cur = out;
        out
    }

    /// Depthwise conv + fused activation.
    pub fn dwconv(&mut self, name: &str, geom: ConvGeometry, act: Activation) -> TensorId {
        let inp = self.graph.tensor(self.cur).shape.clone();
        let oh = geom.out_dim(inp.h(), geom.filter_h, geom.stride_h);
        let ow = geom.out_dim(inp.w(), geom.filter_w, geom.stride_w);
        let c = inp.c();
        let w = self.graph.add_tensor(
            format!("{name}.w"),
            Shape(vec![c, geom.filter_h, geom.filter_w, 1]),
            DType::Int8,
            TensorKind::Parameter,
        );
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(oh, ow, c));
        self.graph.add_op(
            name,
            OpKind::DepthwiseConv2d { geom },
            vec![self.cur],
            Some(w),
            out,
            act,
        );
        self.cur = out;
        out
    }

    /// Element-wise residual add.
    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let shape = self.graph.tensor(a).shape.clone();
        let out = self.act_tensor(format!("{name}.out"), shape);
        self.graph.add_op(name, OpKind::Add, vec![a, b], None, out, Activation::None);
        self.cur = out;
        out
    }

    /// Element-wise multiply (e.g. SE gates, attention masks).
    pub fn mul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let shape = self.graph.tensor(a).shape.clone();
        let out = self.act_tensor(format!("{name}.out"), shape);
        self.graph.add_op(name, OpKind::Mul, vec![a, b], None, out, Activation::None);
        self.cur = out;
        out
    }

    /// Max/avg pool.
    pub fn pool(&mut self, name: &str, kind: PoolKind, size: usize, stride: usize) -> TensorId {
        let inp = self.graph.tensor(self.cur).shape.clone();
        let oh = inp.h().div_ceil(stride);
        let ow = inp.w().div_ceil(stride);
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(oh, ow, inp.c()));
        self.graph.add_op(
            name,
            OpKind::Pool { kind, size, stride },
            vec![self.cur],
            None,
            out,
            Activation::None,
        );
        self.cur = out;
        out
    }

    /// Global average pool to 1×1×C.
    pub fn global_avg_pool(&mut self, name: &str) -> TensorId {
        let c = self.graph.tensor(self.cur).shape.c();
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(1, 1, c));
        self.graph.add_op(name, OpKind::GlobalAvgPool, vec![self.cur], None, out, Activation::None);
        self.cur = out;
        out
    }

    /// Fully connected head.
    pub fn fc(&mut self, name: &str, out_features: usize, act: Activation) -> TensorId {
        let inp = self.graph.tensor(self.cur).shape.clone();
        let w = self.graph.add_tensor(
            format!("{name}.w"),
            Shape(vec![out_features, 1, 1, inp.num_elements()]),
            DType::Int8,
            TensorKind::Parameter,
        );
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(1, 1, out_features));
        self.graph.add_op(
            name,
            OpKind::FullyConnected { out_features },
            vec![self.cur],
            Some(w),
            out,
            act,
        );
        self.cur = out;
        out
    }

    /// Nearest-neighbour resize to an explicit spatial size.
    pub fn resize_to(&mut self, name: &str, h: usize, w: usize) -> TensorId {
        let c = self.graph.tensor(self.cur).shape.c();
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(h, w, c));
        self.graph.add_op(
            name,
            OpKind::ResizeTo { h, w },
            vec![self.cur],
            None,
            out,
            Activation::None,
        );
        self.cur = out;
        out
    }

    /// Nearest-neighbour upsample.
    pub fn resize(&mut self, name: &str, scale: usize) -> TensorId {
        let inp = self.graph.tensor(self.cur).shape.clone();
        let out = self.act_tensor(
            format!("{name}.out"),
            Shape::hwc(inp.h() * scale, inp.w() * scale, inp.c()),
        );
        self.graph.add_op(
            name,
            OpKind::ResizeNearest { scale },
            vec![self.cur],
            None,
            out,
            Activation::None,
        );
        self.cur = out;
        out
    }

    /// Channel concat.
    pub fn concat(&mut self, name: &str, parts: Vec<TensorId>) -> TensorId {
        let h = self.graph.tensor(parts[0]).shape.h();
        let w = self.graph.tensor(parts[0]).shape.w();
        let c: usize = parts.iter().map(|&t| self.graph.tensor(t).shape.c()).sum();
        let out = self.act_tensor(format!("{name}.out"), Shape::hwc(h, w, c));
        self.graph.add_op(name, OpKind::Concat, parts, None, out, Activation::None);
        self.cur = out;
        out
    }

    /// Finish: mark current tensor as output and return the graph.
    pub fn finish(mut self) -> Graph {
        self.graph.mark_output(self.cur);
        self.graph
    }

    /// Finish with several explicit outputs (detection heads).
    pub fn finish_multi(mut self, outs: Vec<TensorId>) -> Graph {
        for o in outs {
            self.graph.mark_output(o);
        }
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut b = GraphBuilder::with_input("tiny", 8, 8, 3);
        b.conv("c1", 16, ConvGeometry::square(3, 2, crate::ir::op::Padding::Same), Activation::Relu);
        b.dwconv("dw1", ConvGeometry::square(3, 1, crate::ir::op::Padding::Same), Activation::Relu);
        b.conv("c2", 32, ConvGeometry::unit(), Activation::None);
        b.global_avg_pool("gap");
        b.fc("fc", 10, Activation::None);
        b.finish()
    }

    #[test]
    fn builder_shapes() {
        let g = tiny();
        g.validate().unwrap();
        let out = g.tensor(g.outputs[0]);
        assert_eq!(out.shape.c(), 10);
    }

    #[test]
    fn macs_counted() {
        let g = tiny();
        // c1: 4*4*16*3*3*3, dw1: 4*4*16*9, c2: 4*4*32*16, fc: 32*10
        let expect = 4 * 4 * 16 * 27 + 4 * 4 * 16 * 9 + 4 * 4 * 32 * 16 + 320;
        let gap = 16 * 2 / 2 + 0; // gap counted as elems/2 = 4*4*32/2
        let gap = 4 * 4 * 32 / 2;
        assert_eq!(g.total_macs(), (expect + gap) as u64);
        let _ = gap;
    }

    #[test]
    fn topo_order_covers_all_ops() {
        let g = tiny();
        let order = g.topo_order();
        assert_eq!(order.len(), g.ops.len());
    }

    #[test]
    fn residual_add_and_concat() {
        let mut b = GraphBuilder::with_input("res", 16, 16, 8);
        let x = b.current();
        let y = b.conv("c", 8, ConvGeometry::unit(), Activation::Relu);
        let s = b.add("add", x, y);
        let cat = b.concat("cat", vec![s, y]);
        let g = b.finish();
        g.validate().unwrap();
        assert_eq!(g.tensor(cat).shape.c(), 16);
    }
}
