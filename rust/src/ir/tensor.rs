//! Tensor metadata: shapes, dtypes, and quantization-aware sizing.
//!
//! The compiler only needs shapes, element types and quantization metadata —
//! actual INT8 payloads live either in the rust reference executor
//! (`exec/`) or in the AOT-compiled PJRT executables (`runtime/`).

use super::quant::QuantParams;

/// Element types supported by the NPU datapath (Sec. III-B: 8-bit MACs with
/// a two-cycle 8×16 decomposition; 32-bit accumulators never leave the core).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 8-bit signed integer (activations + weights in the benchmarks).
    Int8,
    /// 8-bit unsigned integer (LiteRT-style activation quantization).
    UInt8,
    /// 16-bit signed integer (high-accuracy activations, 2-cycle dot product).
    Int16,
    /// 32-bit signed accumulator / bias type.
    Int32,
    /// Float32 — host-fallback ops only, never on the NPU datapath.
    Float32,
}

impl DType {
    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::Int8 | DType::UInt8 => 1,
            DType::Int16 => 2,
            DType::Int32 | DType::Float32 => 4,
        }
    }

    /// True for the integer types the dot-product array consumes.
    pub fn is_npu_native(self) -> bool {
        !matches!(self, DType::Float32)
    }
}

/// Feature-map / parameter shape. Activations use HWC (the compute format,
/// Sec. IV-A); parameters use (outC, fH, fW, inC).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn hwc(h: usize, w: usize, c: usize) -> Self {
        Shape(vec![h, w, c])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn num_elements(&self) -> usize {
        self.0.iter().product()
    }

    /// Height of an HWC activation shape (1 for vectors).
    pub fn h(&self) -> usize {
        match self.0.len() {
            3 => self.0[0],
            _ => 1,
        }
    }

    pub fn w(&self) -> usize {
        match self.0.len() {
            3 => self.0[1],
            2 => self.0[0],
            _ => 1,
        }
    }

    /// Channel (innermost) dimension.
    pub fn c(&self) -> usize {
        *self.0.last().unwrap_or(&1)
    }
}

/// Unique tensor id inside a [`super::graph::Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

impl TensorId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// What a tensor is, from the scheduler's point of view (initial state in
/// the tile state machine of Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Network input: starts in DRAM.
    Input,
    /// Weights/biases: start in DRAM (flash/DDR resident).
    Parameter,
    /// Produced by a compute job: starts N/E.
    Activation,
    /// Network output: activation that must be pushed back to DRAM.
    Output,
}

/// Tensor metadata record.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    pub shape: Shape,
    pub dtype: DType,
    pub kind: TensorKind,
    pub quant: Option<QuantParams>,
}

impl TensorInfo {
    /// Payload size in bytes (unpadded).
    pub fn size_bytes(&self) -> usize {
        self.shape.num_elements() * self.dtype.size_bytes()
    }

    /// Size in bytes with the channel dimension padded to a multiple of the
    /// bus word (Sec. IV-A: "ifmap and ofmap are stored in TCM padded out
    /// in C to a multiple of the bus/word-width").
    pub fn padded_size_bytes(&self, word_bytes: usize) -> usize {
        let c = self.shape.c().max(1);
        let padded_c = c.div_ceil(word_bytes) * word_bytes;
        self.shape.num_elements() / c.max(1) * padded_c * self.dtype.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::Int8.size_bytes(), 1);
        assert_eq!(DType::Int16.size_bytes(), 2);
        assert_eq!(DType::Int32.size_bytes(), 4);
        assert!(DType::Int8.is_npu_native());
        assert!(!DType::Float32.is_npu_native());
    }

    #[test]
    fn shape_accessors() {
        let s = Shape::hwc(224, 224, 3);
        assert_eq!((s.h(), s.w(), s.c()), (224, 224, 3));
        assert_eq!(s.num_elements(), 224 * 224 * 3);
    }

    #[test]
    fn padded_size_rounds_channels_to_word() {
        let t = TensorInfo {
            id: TensorId(0),
            name: "x".into(),
            shape: Shape::hwc(8, 8, 3),
            dtype: DType::Int8,
            kind: TensorKind::Activation,
            quant: None,
        };
        // 3 channels pad to 16 with a 16-byte bus word.
        assert_eq!(t.padded_size_bytes(16), 8 * 8 * 16);
        assert_eq!(t.size_bytes(), 8 * 8 * 3);
    }
}
