//! INT8/INT16 affine quantization metadata and requantization arithmetic.
//!
//! Matches the LiteRT integer-quantization scheme the paper benchmarks with
//! (INT8 activations + weights, INT32 bias, per-tensor or per-channel
//! scales): `real = scale * (q - zero_point)`. Requantization of the 32-bit
//! accumulator to 8 bits uses the standard fixed-point multiplier+shift
//! decomposition so the rust reference executor and the Pallas kernel agree
//! bit-exactly.

/// Affine quantization parameters for one tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantParams {
    /// Per-tensor scale (per-channel handled as a vector at op level).
    pub scale: f64,
    /// Zero point in the quantized domain.
    pub zero_point: i32,
}

impl QuantParams {
    pub fn new(scale: f64, zero_point: i32) -> Self {
        assert!(scale > 0.0, "quant scale must be positive");
        Self { scale, zero_point }
    }

    /// Quantize a real value to i32 (caller clamps to the target dtype).
    pub fn quantize(&self, real: f64) -> i32 {
        (real / self.scale).round() as i32 + self.zero_point
    }

    /// Dequantize.
    pub fn dequantize(&self, q: i32) -> f64 {
        self.scale * (q - self.zero_point) as f64
    }
}

/// Fixed-point requantization multiplier: `real_multiplier ≈ m * 2^(-shift)`
/// with `m` a 31-bit normalized mantissa — the exact scheme LiteRT kernels
/// and our Pallas kernel use to rescale INT32 accumulators to INT8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requant {
    /// Normalized multiplier in [2^30, 2^31).
    pub multiplier: i32,
    /// Right shift (>= 0 for multipliers < 1, the common case).
    pub shift: i32,
}

impl Requant {
    /// Decompose `real` (must be in (0, 1) for typical conv rescales, but
    /// any positive value is supported) into multiplier+shift.
    pub fn from_real(real: f64) -> Self {
        assert!(real > 0.0, "requant multiplier must be positive");
        let mut shift = 0i32;
        let mut r = real;
        while r < 0.5 {
            r *= 2.0;
            shift += 1;
        }
        while r >= 1.0 {
            r /= 2.0;
            shift -= 1;
        }
        // r in [0.5, 1): mantissa in [2^30, 2^31)
        let mut multiplier = (r * (1i64 << 31) as f64).round() as i64;
        if multiplier == (1i64 << 31) {
            multiplier /= 2;
            shift -= 1;
        }
        Self { multiplier: multiplier as i32, shift }
    }

    /// The effective real multiplier this pair encodes.
    pub fn to_real(self) -> f64 {
        self.multiplier as f64 / (1i64 << 31) as f64 / 2f64.powi(self.shift)
    }

    /// Apply to an accumulator: rounding high multiply (`round(acc·m/2³¹)`)
    /// followed by a rounding right shift — the fixed-point rescale the
    /// Pallas kernel mirrors, so rust and python agree bit-exactly.
    pub fn apply(self, acc: i32) -> i32 {
        let prod = (acc as i64) * (self.multiplier as i64);
        // Rounding high part: round(prod / 2^31).
        let high = (prod + (1i64 << 30)) >> 31;
        if self.shift <= 0 {
            (high << (-self.shift) as u32).clamp(i32::MIN as i64, i32::MAX as i64) as i32
        } else {
            let s = self.shift as u32;
            let round = 1i64 << (s - 1);
            ((high + round) >> s) as i32
        }
    }
}

/// Saturate an i32 to the i8 range.
#[inline]
pub fn clamp_i8(v: i32) -> i8 {
    v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
}

/// Saturate an i32 to the i16 range.
#[inline]
pub fn clamp_i16(v: i32) -> i16 {
    v.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_round_trip() {
        let q = QuantParams::new(0.05, -3);
        let real = 1.25;
        let qi = q.quantize(real);
        let back = q.dequantize(qi);
        assert!((back - real).abs() <= 0.05 / 2.0 + 1e-9);
    }

    #[test]
    fn requant_decomposition_accuracy() {
        for &real in &[0.0003, 0.01, 0.25, 0.49, 0.5, 0.77, 0.999, 1.5, 3.25] {
            let r = Requant::from_real(real);
            let err = (r.to_real() - real).abs() / real;
            assert!(err < 1e-8, "real={real} err={err}");
            assert!(r.multiplier >= (1 << 30), "normalized mantissa");
        }
    }

    #[test]
    fn requant_apply_matches_float() {
        let real = 0.0123;
        let r = Requant::from_real(real);
        for acc in [-100_000, -1234, -1, 0, 1, 999, 54_321, 1_000_000] {
            let got = r.apply(acc);
            let want = (acc as f64 * real).round() as i32;
            assert!(
                (got - want).abs() <= 1,
                "acc={acc} got={got} want={want}"
            );
        }
    }

    #[test]
    fn clamps() {
        assert_eq!(clamp_i8(300), 127);
        assert_eq!(clamp_i8(-300), -128);
        assert_eq!(clamp_i16(40_000), 32_767);
    }
}
