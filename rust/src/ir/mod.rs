//! Graph intermediate representation.
//!
//! Frontend-agnostic IR equivalent to the paper's mid-end input (Sec. IV):
//! tensors with HWC shapes + INT8 quantization metadata, and an operator set
//! covering the benchmarked vision models. Fully-connected / matmul /
//! element-wise / scalar ops are represented directly but *lowered* by the
//! compiler using the paper's rules (1×1 convs, paired depthwise ops).

pub mod graph;
pub mod op;
pub mod quant;
pub mod tensor;

pub use graph::{Graph, GraphBuilder};
pub use op::{Activation, ConvGeometry, Op, OpClass, OpId, OpKind, Padding, PoolKind};
pub use quant::{clamp_i8, QuantParams, Requant};
pub use tensor::{DType, Shape, TensorId, TensorInfo, TensorKind};
