//! Data-mover (DMA) latency model: DDR↔TCM and TCM↔TCM transfers with
//! multi-dimensional strided access (Sec. III-C "Controller and Data
//! Movement").

use super::config::NeutronConfig;

/// Kind of a data-transfer job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransferKind {
    /// DRAM → TCM (`fetch` transition of Fig. 5).
    Fetch,
    /// TCM → DRAM (`push`).
    Push,
    /// TCM → TCM rearrangement (`l-copy`: expansion to line-parallel
    /// format, halo duplication across banks).
    LCopy,
    /// DRAM → TCM directly in line-parallel format (`l-fetch`).
    LFetch,
}

impl TransferKind {
    /// Does this transfer consume DDR bandwidth?
    pub fn uses_ddr(self) -> bool {
        matches!(self, TransferKind::Fetch | TransferKind::Push | TransferKind::LFetch)
    }
}

/// One data-transfer job.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub kind: TransferKind,
    pub bytes: u64,
    /// Number of separate strided descriptors (dimension count splits):
    /// each adds a descriptor-setup overhead.
    pub descriptors: u32,
}

impl Transfer {
    pub fn new(kind: TransferKind, bytes: u64) -> Self {
        Self { kind, bytes, descriptors: 1 }
    }

    pub fn with_descriptors(mut self, d: u32) -> Self {
        self.descriptors = d.max(1);
        self
    }

    /// Latency in core cycles on `cfg`.
    ///
    /// DDR transfers are bound by DDR bandwidth; TCM↔TCM copies run at one
    /// bus word per cycle per direction. Every descriptor adds a fixed
    /// setup cost; outstanding-transaction support means back-to-back
    /// descriptors pipeline (setup overlaps the previous burst), so setup
    /// contributes only when larger than the burst itself.
    pub fn cycles(&self, cfg: &NeutronConfig) -> u64 {
        let setup_per_desc = 64u64;
        let stream = if self.kind.uses_ddr() {
            (self.bytes as f64 / cfg.ddr_bytes_per_cycle()).ceil() as u64
        } else {
            // TCM-to-TCM: read + write through the multilayer bus; the DMA
            // moves one word per cycle.
            self.bytes.div_ceil(cfg.bus_bytes as u64)
        };
        let per_desc_bytes = self.bytes / self.descriptors as u64;
        let per_desc_stream = if self.kind.uses_ddr() {
            (per_desc_bytes as f64 / cfg.ddr_bytes_per_cycle()).ceil() as u64
        } else {
            per_desc_bytes.div_ceil(cfg.bus_bytes as u64)
        };
        let exposed_setup = if per_desc_stream >= setup_per_desc {
            setup_per_desc // only the first descriptor's setup is exposed
        } else {
            setup_per_desc * self.descriptors as u64
        };
        stream + exposed_setup + cfg.job_overhead_cycles
    }
}

/// Aggregate DDR-traffic accountant (the δ·N_DM term of Eq. (8) penalizes
/// hidden-but-bandwidth-consuming transfers; the simulator also uses this
/// to report DDR bytes per inference).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DdrTraffic {
    pub fetch_bytes: u64,
    pub push_bytes: u64,
    pub transfers: u64,
}

impl DdrTraffic {
    pub fn record(&mut self, t: &Transfer) {
        if t.kind.uses_ddr() {
            self.transfers += 1;
            match t.kind {
                TransferKind::Push => self.push_bytes += t.bytes,
                _ => self.fetch_bytes += t.bytes,
            }
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.fetch_bytes + self.push_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::NeutronConfig;

    #[test]
    fn ddr_transfer_bound_by_bandwidth() {
        let cfg = NeutronConfig::flagship_2tops();
        let t = Transfer::new(TransferKind::Fetch, 120_000);
        // 120 kB at 12 B/cycle = 10k cycles + overheads.
        let c = t.cycles(&cfg);
        assert!(c >= 10_000 && c < 11_000, "cycles={c}");
    }

    #[test]
    fn tcm_copy_runs_at_bus_speed() {
        let cfg = NeutronConfig::flagship_2tops();
        let t = Transfer::new(TransferKind::LCopy, 16 * 1024);
        let c = t.cycles(&cfg);
        // 16 kB at 16 B/cycle = 1024 cycles + overheads.
        assert!(c >= 1024 && c < 1500, "cycles={c}");
    }

    #[test]
    fn many_small_descriptors_expose_setup() {
        let cfg = NeutronConfig::flagship_2tops();
        let few = Transfer::new(TransferKind::Fetch, 4096).with_descriptors(1);
        let many = Transfer::new(TransferKind::Fetch, 4096).with_descriptors(64);
        assert!(many.cycles(&cfg) > few.cycles(&cfg));
    }

    #[test]
    fn traffic_accounting() {
        let cfg = NeutronConfig::flagship_2tops();
        let _ = cfg;
        let mut acc = DdrTraffic::default();
        acc.record(&Transfer::new(TransferKind::Fetch, 100));
        acc.record(&Transfer::new(TransferKind::Push, 50));
        acc.record(&Transfer::new(TransferKind::LCopy, 999)); // not DDR
        assert_eq!(acc.total_bytes(), 150);
        assert_eq!(acc.transfers, 2);
    }
}
