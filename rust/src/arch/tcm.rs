//! Tightly-coupled memory model: non-arbitrated banks with a
//! virtual-to-physical (V2P) translation table (Sec. III-C).
//!
//! The compiler's allocation pass assigns tiles to *virtual* bank ranges;
//! the V2P table remaps virtual banks to physical banks between jobs (in
//! idle mode) so the compute engines always see contiguous data. This
//! module provides the table the coordinator updates at runtime and the
//! conflict checks the tests/simulator use to verify bank exclusivity.

use super::config::NeutronConfig;

/// Identifier of a virtual or physical bank.
pub type Bank = usize;

/// The V2P translation table: `virt → phys`, a bijection over banks.
#[derive(Debug, Clone)]
pub struct V2pTable {
    map: Vec<Bank>,
}

impl V2pTable {
    /// Identity mapping over `banks` banks.
    pub fn identity(banks: usize) -> Self {
        Self { map: (0..banks).collect() }
    }

    pub fn banks(&self) -> usize {
        self.map.len()
    }

    /// Physical bank backing a virtual bank.
    pub fn translate(&self, virt: Bank) -> Bank {
        self.map[virt]
    }

    /// Remap a set of virtual banks to new physical banks (idle-mode V2P
    /// update). Panics if the result is not a bijection — the hardware
    /// table cannot alias two virtual banks to one physical bank.
    pub fn remap(&mut self, updates: &[(Bank, Bank)]) {
        for &(v, p) in updates {
            self.map[v] = p;
        }
        let mut seen = vec![false; self.map.len()];
        for &p in &self.map {
            assert!(!seen[p], "V2P update aliases physical bank {p}");
            seen[p] = true;
        }
    }

    /// Swap the physical backing of two virtual banks (the common update:
    /// making a freshly-written tensor appear contiguous).
    pub fn swap(&mut self, a: Bank, b: Bank) {
        self.map.swap(a, b);
    }
}

/// Occupancy tracker over physical banks for one timestep — used by the
/// simulator to verify the compiler's bank-exclusivity guarantees (a
/// violated claim means a real-hardware bus conflict, so it panics in
/// checked mode rather than silently serializing).
#[derive(Debug, Clone)]
pub struct BankOccupancy {
    /// Owner tag per bank (None = free).
    owners: Vec<Option<u32>>,
}

impl BankOccupancy {
    pub fn new(cfg: &NeutronConfig) -> Self {
        Self { owners: vec![None; cfg.tcm_banks] }
    }

    /// Claim `banks` for `owner` (a tensor/tile id). Returns false if any
    /// bank is already held by a different owner.
    pub fn claim(&mut self, owner: u32, banks: impl IntoIterator<Item = Bank>) -> bool {
        let banks: Vec<Bank> = banks.into_iter().collect();
        if banks
            .iter()
            .any(|&b| self.owners[b].map_or(false, |o| o != owner))
        {
            return false;
        }
        for b in banks {
            self.owners[b] = Some(owner);
        }
        true
    }

    /// Release every bank held by `owner`.
    pub fn release(&mut self, owner: u32) {
        for o in &mut self.owners {
            if *o == Some(owner) {
                *o = None;
            }
        }
    }

    /// Number of free banks.
    pub fn free(&self) -> usize {
        self.owners.iter().filter(|o| o.is_none()).count()
    }

    /// Find `count` contiguous free banks (first-fit), if any.
    pub fn find_contiguous(&self, count: usize) -> Option<Bank> {
        let mut run = 0;
        for (i, o) in self.owners.iter().enumerate() {
            if o.is_none() {
                run += 1;
                if run == count {
                    return Some(i + 1 - count);
                }
            } else {
                run = 0;
            }
        }
        None
    }
}

/// One parameter tile held resident in TCM across requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidencyEntry {
    /// Stable id of the model owning the tile (the serving layer uses the
    /// model-zoo index).
    pub owner: u64,
    /// The owner's tile id.
    pub tile: u32,
    /// Capacity charged for the tile (bank-rounded by the caller, so the
    /// accounting matches what the allocator would actually reserve).
    pub bytes: u64,
    /// DDR-fetch cost a hit on this tile saves.
    pub fetch_cycles: u64,
    /// Logical timestamp of the last touch (install or hit).
    pub last_use_seq: u64,
}

impl ResidencyEntry {
    /// Eviction value: cycles saved per resident byte, compared without
    /// division (`a.fetch/a.bytes < b.fetch/b.bytes` ⇔
    /// `a.fetch·b.bytes < b.fetch·a.bytes`), so the order is exact and
    /// platform-independent. Ties fall to the older entry, then to the
    /// smaller `(owner, tile)` — fully deterministic victim choice.
    fn keeps_less_value_than(&self, other: &ResidencyEntry) -> bool {
        let a = self.fetch_cycles as u128 * other.bytes as u128;
        let b = other.fetch_cycles as u128 * self.bytes as u128;
        (a, self.last_use_seq, self.owner, self.tile)
            < (b, other.last_use_seq, other.owner, other.tile)
    }
}

/// TCM weight-residency model: which parameter tiles stay resident in
/// TCM across requests, and at what capacity cost.
///
/// Generalizes the batching-only "followers skip parameter DMA" trick:
/// any request whose parameter tiles are already resident skips their
/// DDR fetches. Eviction is cost-model-driven — the victim is the entry
/// with the lowest *fetch cycles saved per resident byte* (oldest touch,
/// then smallest `(owner, tile)`, break ties), so the policy keeps the
/// tiles whose re-fetch would cost the most relative to the TCM they
/// pin. Capacity is accounted against the configured TCM size and the
/// invariant `resident_bytes ≤ capacity_bytes` is asserted after every
/// install (the simulator's strict mode, like the V2P bijection check).
#[derive(Debug, Clone)]
pub struct TcmResidency {
    capacity_bytes: u64,
    quota_bytes: Option<u64>,
    entries: Vec<ResidencyEntry>,
    resident_bytes: u64,
    seq: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl TcmResidency {
    /// An empty residency set with `capacity_bytes` of TCM to fill.
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            capacity_bytes,
            quota_bytes: None,
            entries: Vec::new(),
            resident_bytes: 0,
            seq: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Like [`TcmResidency::new`], with a per-owner residency cap: no
    /// single owner id (tenant model, or decode sequence) may pin more
    /// than `quota_bytes` at once. An install that would push its owner
    /// over quota first evicts that owner's *own* lowest-value entries —
    /// the over-quota tenant pays for its appetite before any neighbor
    /// does — and a tile larger than the quota never installs at all.
    pub fn with_quota(capacity_bytes: u64, quota_bytes: u64) -> Self {
        let mut r = Self::new(capacity_bytes);
        r.quota_bytes = Some(quota_bytes.min(capacity_bytes));
        r
    }

    /// Configured capacity the resident set is accounted against.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// The per-owner cap, if one is configured (see
    /// [`TcmResidency::with_quota`]).
    pub fn quota_bytes(&self) -> Option<u64> {
        self.quota_bytes
    }

    /// Bytes currently pinned by one owner id.
    pub fn owner_bytes(&self, owner: u64) -> u64 {
        self.entries.iter().filter(|e| e.owner == owner).map(|e| e.bytes).sum()
    }

    /// Bytes currently pinned by resident tiles (never exceeds capacity).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Number of resident tiles.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups that found their tile resident.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that missed.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Entries evicted to make room.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// True if `(owner, tile)` is resident (no counters touched).
    pub fn is_resident(&self, owner: u64, tile: u32) -> bool {
        self.entries.iter().any(|e| e.owner == owner && e.tile == tile)
    }

    /// Look up `(owner, tile)` before its fetch would issue. A hit bumps
    /// the entry's recency and returns true (the caller skips the fetch);
    /// a miss only counts and returns false (the caller fetches, then
    /// [`TcmResidency::install`]s).
    pub fn touch(&mut self, owner: u64, tile: u32) -> bool {
        self.seq += 1;
        match self.entries.iter_mut().find(|e| e.owner == owner && e.tile == tile) {
            Some(e) => {
                e.last_use_seq = self.seq;
                self.hits += 1;
                true
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    /// Install a freshly-fetched tile, evicting lowest-value entries
    /// until it fits. Charges `bytes` against capacity (callers pass the
    /// bank-rounded size). Returns false — and keeps the set unchanged —
    /// when the tile alone exceeds capacity (or the per-owner quota).
    /// Installing an already-resident tile just refreshes its recency.
    pub fn install(&mut self, owner: u64, tile: u32, bytes: u64, fetch_cycles: u64) -> bool {
        self.install_evicting(owner, tile, bytes, fetch_cycles).is_some()
    }

    /// [`TcmResidency::install`], reporting who got evicted: returns the
    /// displaced entries (possibly empty) on success, `None` — set
    /// unchanged — when the tile cannot install. The serving layer uses
    /// the victim list to charge preemption costs: a displaced KV-cache
    /// entry means its sequence must re-stream that context from DDR on
    /// its next decode step.
    ///
    /// Eviction runs in two deterministic phases: first the installing
    /// owner's own lowest-value entries until the owner fits its quota
    /// (no-op without a quota), then the globally lowest-value entries
    /// until capacity fits. Victims are returned in eviction order.
    pub fn install_evicting(
        &mut self,
        owner: u64,
        tile: u32,
        bytes: u64,
        fetch_cycles: u64,
    ) -> Option<Vec<ResidencyEntry>> {
        if bytes > self.capacity_bytes {
            return None;
        }
        if let Some(quota) = self.quota_bytes {
            if bytes > quota {
                return None;
            }
        }
        self.seq += 1;
        if let Some(e) =
            self.entries.iter_mut().find(|e| e.owner == owner && e.tile == tile)
        {
            e.last_use_seq = self.seq;
            return Some(Vec::new());
        }
        let mut victims = Vec::new();
        if let Some(quota) = self.quota_bytes {
            while self.owner_bytes(owner) + bytes > quota {
                let victim = self.lowest_value_index(|e| e.owner == owner).expect(
                    "over quota implies the owner has a resident victim",
                );
                victims.push(self.evict_at(victim));
            }
        }
        while self.resident_bytes + bytes > self.capacity_bytes {
            let victim = self
                .lowest_value_index(|_| true)
                .expect("over capacity implies a resident victim exists");
            victims.push(self.evict_at(victim));
        }
        self.entries.push(ResidencyEntry {
            owner,
            tile,
            bytes,
            fetch_cycles,
            last_use_seq: self.seq,
        });
        self.resident_bytes += bytes;
        // Strict-mode capacity invariant: a resident set larger than the
        // TCM is a simulator bug, not a tunable.
        assert!(
            self.resident_bytes <= self.capacity_bytes,
            "TCM residency overflow: {} resident bytes > {} capacity",
            self.resident_bytes,
            self.capacity_bytes
        );
        Some(victims)
    }

    /// Voluntarily release every entry one owner holds (a decode sequence
    /// leaving the instance frees its KV tiles). Returns the released
    /// entries; does **not** count toward [`TcmResidency::evictions`] —
    /// these are frees, not capacity pressure.
    pub fn release_owner(&mut self, owner: u64) -> Vec<ResidencyEntry> {
        let mut released = Vec::new();
        let mut i = 0;
        while i < self.entries.len() {
            if self.entries[i].owner == owner {
                let e = self.entries.remove(i);
                self.resident_bytes -= e.bytes;
                released.push(e);
            } else {
                i += 1;
            }
        }
        released
    }

    /// Index of the lowest-value entry among those matching `pred`.
    fn lowest_value_index(&self, pred: impl Fn(&ResidencyEntry) -> bool) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            if !pred(e) {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    if e.keeps_less_value_than(&self.entries[b]) {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Remove the entry at `i`, counting it as a capacity eviction.
    fn evict_at(&mut self, i: usize) -> ResidencyEntry {
        let evicted = self.entries.remove(i);
        self.resident_bytes -= evicted.bytes;
        self.evictions += 1;
        evicted
    }

    /// The resident entries (test/introspection aid; unspecified order).
    pub fn entries(&self) -> &[ResidencyEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::NeutronConfig;

    #[test]
    fn v2p_identity_and_swap() {
        let mut t = V2pTable::identity(8);
        assert_eq!(t.translate(3), 3);
        t.swap(1, 5);
        assert_eq!(t.translate(1), 5);
        assert_eq!(t.translate(5), 1);
    }

    #[test]
    fn v2p_remap_keeps_bijection() {
        let mut t = V2pTable::identity(4);
        t.remap(&[(0, 2), (2, 0)]);
        assert_eq!(t.translate(0), 2);
        assert_eq!(t.translate(2), 0);
    }

    #[test]
    #[should_panic(expected = "aliases")]
    fn v2p_detects_aliasing() {
        let mut t = V2pTable::identity(4);
        t.remap(&[(0, 1)]); // two virtual banks now point at phys 1
    }

    #[test]
    fn occupancy_claims_and_conflicts() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut occ = BankOccupancy::new(&cfg);
        assert!(occ.claim(1, 0..4));
        assert!(!occ.claim(2, 3..6), "bank 3 is taken");
        assert!(occ.claim(1, 3..6), "same owner may extend");
        occ.release(1);
        assert_eq!(occ.free(), cfg.tcm_banks);
    }

    #[test]
    fn contiguous_first_fit() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut occ = BankOccupancy::new(&cfg);
        occ.claim(1, 2..5);
        assert_eq!(occ.find_contiguous(2), Some(0));
        assert_eq!(occ.find_contiguous(5), Some(5));
        occ.claim(2, 0..2);
        assert_eq!(occ.find_contiguous(1), Some(5));
    }

    #[test]
    fn residency_hits_after_install_and_counts() {
        let mut r = TcmResidency::new(1_000);
        assert!(!r.touch(0, 1), "cold lookup misses");
        assert!(r.install(0, 1, 400, 5_000));
        assert!(r.is_resident(0, 1));
        assert!(r.touch(0, 1), "now warm");
        assert_eq!((r.hits(), r.misses(), r.evictions()), (1, 1, 0));
        assert_eq!(r.resident_bytes(), 400);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn residency_evicts_lowest_cycles_per_byte_first() {
        let mut r = TcmResidency::new(1_000);
        // value (fetch cycles per byte): a=10, b=2, c=5.
        assert!(r.install(0, 1, 400, 4_000)); // a
        assert!(r.install(0, 2, 300, 600)); // b — cheapest to re-fetch
        assert!(r.install(0, 3, 300, 1_500)); // c
        // 400 more bytes need room: b (300) then c (300) go, a stays.
        assert!(r.install(1, 7, 400, 4_000));
        assert!(r.is_resident(0, 1));
        assert!(!r.is_resident(0, 2));
        assert!(!r.is_resident(0, 3));
        assert!(r.is_resident(1, 7));
        assert_eq!(r.evictions(), 2);
        assert!(r.resident_bytes() <= r.capacity_bytes());
    }

    #[test]
    fn residency_value_ties_evict_older_entry() {
        let mut r = TcmResidency::new(800);
        // Identical value: ties break on recency (older goes first).
        assert!(r.install(0, 1, 400, 1_000));
        assert!(r.install(0, 2, 400, 1_000));
        r.touch(0, 1); // tile 1 is now the most recently used
        assert!(r.install(0, 3, 400, 1_000));
        assert!(r.is_resident(0, 1));
        assert!(!r.is_resident(0, 2), "older equal-value entry is the victim");
    }

    #[test]
    fn residency_rejects_tiles_larger_than_capacity() {
        let mut r = TcmResidency::new(1_000);
        assert!(r.install(0, 1, 600, 1_000));
        assert!(!r.install(0, 2, 1_001, 9_999), "oversized tile never installs");
        assert!(r.is_resident(0, 1), "a rejected install evicts nothing");
        assert_eq!(r.evictions(), 0);
        assert_eq!(r.resident_bytes(), 600);
    }

    #[test]
    fn residency_reinstall_refreshes_without_double_charging() {
        let mut r = TcmResidency::new(1_000);
        assert!(r.install(0, 1, 400, 1_000));
        assert!(r.install(0, 1, 400, 1_000));
        assert_eq!(r.resident_bytes(), 400);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn residency_quota_caps_each_owner_and_evicts_their_own_tiles_first() {
        // 2000 bytes of TCM, but no owner may pin more than 800.
        let mut r = TcmResidency::with_quota(2_000, 800);
        assert_eq!(r.quota_bytes(), Some(800));
        assert!(r.install(1, 10, 400, 4_000)); // owner 1: 400
        assert!(r.install(1, 11, 400, 1_000)); // owner 1: 800 (at quota)
        assert!(r.install(2, 20, 600, 2_000)); // owner 2 unaffected
        // Owner 1's next install is over quota: its OWN lowest-value tile
        // (11: 2.5 cyc/B vs 10: 10 cyc/B) goes, owner 2 keeps everything.
        let victims = r.install_evicting(1, 12, 300, 9_000).expect("fits after self-evict");
        assert_eq!(victims.len(), 1);
        assert_eq!((victims[0].owner, victims[0].tile), (1, 11));
        assert!(r.is_resident(2, 20), "neighbor never pays for owner 1's quota");
        assert_eq!(r.owner_bytes(1), 700);
        assert_eq!(r.evictions(), 1);
        // A tile larger than the quota never installs, even with room.
        assert!(!r.install(3, 30, 900, 50_000));
        assert!(r.resident_bytes() <= r.capacity_bytes());
    }

    #[test]
    fn residency_quota_eviction_is_deterministic() {
        let run = || {
            let mut r = TcmResidency::with_quota(4_000, 1_000);
            let mut victim_log = Vec::new();
            for (owner, tile, bytes, cycles) in [
                (1u64, 1u32, 500u64, 900u64),
                (1, 2, 400, 4_000),
                (1, 3, 300, 600),
                (2, 4, 800, 3_000),
                (2, 5, 400, 2_000),
                (1, 6, 600, 5_000),
            ] {
                if let Some(vs) = r.install_evicting(owner, tile, bytes, cycles) {
                    victim_log.extend(vs.iter().map(|v| (v.owner, v.tile)));
                }
            }
            let mut tiles: Vec<(u64, u32)> =
                r.entries().iter().map(|e| (e.owner, e.tile)).collect();
            tiles.sort_unstable();
            (tiles, victim_log, r.resident_bytes())
        };
        assert_eq!(run(), run());
        // Every surviving owner respects the quota.
        let mut r = TcmResidency::with_quota(4_000, 1_000);
        for (owner, tile) in [(1u64, 1u32), (1, 2), (1, 3), (2, 4), (1, 5)] {
            r.install(owner, tile, 400, 1_000);
        }
        assert!(r.owner_bytes(1) <= 1_000);
    }

    #[test]
    fn residency_release_owner_frees_without_counting_evictions() {
        let mut r = TcmResidency::new(2_000);
        assert!(r.install(5, 1, 400, 1_000));
        assert!(r.install(5, 2, 300, 2_000));
        assert!(r.install(6, 1, 500, 3_000));
        let released = r.release_owner(5);
        assert_eq!(released.len(), 2);
        assert_eq!(r.evictions(), 0, "voluntary frees are not evictions");
        assert_eq!(r.resident_bytes(), 500);
        assert!(r.is_resident(6, 1));
        assert!(r.release_owner(99).is_empty());
    }

    #[test]
    fn residency_eviction_is_deterministic() {
        // Same operation sequence → same resident set, regardless of how
        // many times we run it (the serving layer's replay bit-identity
        // leans on this).
        let run = || {
            let mut r = TcmResidency::new(2_000);
            for (tile, bytes, cycles) in
                [(1u32, 500u64, 900u64), (2, 700, 4_000), (3, 600, 600), (4, 800, 3_000), (5, 400, 2_000)]
            {
                if !r.touch(7, tile) {
                    r.install(7, tile, bytes, cycles);
                }
            }
            let mut tiles: Vec<u32> = r.entries().iter().map(|e| e.tile).collect();
            tiles.sort_unstable();
            (tiles, r.resident_bytes(), r.evictions())
        };
        assert_eq!(run(), run());
    }
}
