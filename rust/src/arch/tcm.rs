//! Tightly-coupled memory model: non-arbitrated banks with a
//! virtual-to-physical (V2P) translation table (Sec. III-C).
//!
//! The compiler's allocation pass assigns tiles to *virtual* bank ranges;
//! the V2P table remaps virtual banks to physical banks between jobs (in
//! idle mode) so the compute engines always see contiguous data. This
//! module provides the table the coordinator updates at runtime and the
//! conflict checks the tests/simulator use to verify bank exclusivity.

use super::config::NeutronConfig;

/// Identifier of a virtual or physical bank.
pub type Bank = usize;

/// The V2P translation table: `virt → phys`, a bijection over banks.
#[derive(Debug, Clone)]
pub struct V2pTable {
    map: Vec<Bank>,
}

impl V2pTable {
    /// Identity mapping over `banks` banks.
    pub fn identity(banks: usize) -> Self {
        Self { map: (0..banks).collect() }
    }

    pub fn banks(&self) -> usize {
        self.map.len()
    }

    /// Physical bank backing a virtual bank.
    pub fn translate(&self, virt: Bank) -> Bank {
        self.map[virt]
    }

    /// Remap a set of virtual banks to new physical banks (idle-mode V2P
    /// update). Panics if the result is not a bijection — the hardware
    /// table cannot alias two virtual banks to one physical bank.
    pub fn remap(&mut self, updates: &[(Bank, Bank)]) {
        for &(v, p) in updates {
            self.map[v] = p;
        }
        let mut seen = vec![false; self.map.len()];
        for &p in &self.map {
            assert!(!seen[p], "V2P update aliases physical bank {p}");
            seen[p] = true;
        }
    }

    /// Swap the physical backing of two virtual banks (the common update:
    /// making a freshly-written tensor appear contiguous).
    pub fn swap(&mut self, a: Bank, b: Bank) {
        self.map.swap(a, b);
    }
}

/// Occupancy tracker over physical banks for one timestep — used by the
/// simulator to verify the compiler's bank-exclusivity guarantees (a
/// violated claim means a real-hardware bus conflict, so it panics in
/// checked mode rather than silently serializing).
#[derive(Debug, Clone)]
pub struct BankOccupancy {
    /// Owner tag per bank (None = free).
    owners: Vec<Option<u32>>,
}

impl BankOccupancy {
    pub fn new(cfg: &NeutronConfig) -> Self {
        Self { owners: vec![None; cfg.tcm_banks] }
    }

    /// Claim `banks` for `owner` (a tensor/tile id). Returns false if any
    /// bank is already held by a different owner.
    pub fn claim(&mut self, owner: u32, banks: impl IntoIterator<Item = Bank>) -> bool {
        let banks: Vec<Bank> = banks.into_iter().collect();
        if banks
            .iter()
            .any(|&b| self.owners[b].map_or(false, |o| o != owner))
        {
            return false;
        }
        for b in banks {
            self.owners[b] = Some(owner);
        }
        true
    }

    /// Release every bank held by `owner`.
    pub fn release(&mut self, owner: u32) {
        for o in &mut self.owners {
            if *o == Some(owner) {
                *o = None;
            }
        }
    }

    /// Number of free banks.
    pub fn free(&self) -> usize {
        self.owners.iter().filter(|o| o.is_none()).count()
    }

    /// Find `count` contiguous free banks (first-fit), if any.
    pub fn find_contiguous(&self, count: usize) -> Option<Bank> {
        let mut run = 0;
        for (i, o) in self.owners.iter().enumerate() {
            if o.is_none() {
                run += 1;
                if run == count {
                    return Some(i + 1 - count);
                }
            } else {
                run = 0;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::NeutronConfig;

    #[test]
    fn v2p_identity_and_swap() {
        let mut t = V2pTable::identity(8);
        assert_eq!(t.translate(3), 3);
        t.swap(1, 5);
        assert_eq!(t.translate(1), 5);
        assert_eq!(t.translate(5), 1);
    }

    #[test]
    fn v2p_remap_keeps_bijection() {
        let mut t = V2pTable::identity(4);
        t.remap(&[(0, 2), (2, 0)]);
        assert_eq!(t.translate(0), 2);
        assert_eq!(t.translate(2), 0);
    }

    #[test]
    #[should_panic(expected = "aliases")]
    fn v2p_detects_aliasing() {
        let mut t = V2pTable::identity(4);
        t.remap(&[(0, 1)]); // two virtual banks now point at phys 1
    }

    #[test]
    fn occupancy_claims_and_conflicts() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut occ = BankOccupancy::new(&cfg);
        assert!(occ.claim(1, 0..4));
        assert!(!occ.claim(2, 3..6), "bank 3 is taken");
        assert!(occ.claim(1, 3..6), "same owner may extend");
        occ.release(1);
        assert_eq!(occ.free(), cfg.tcm_banks);
    }

    #[test]
    fn contiguous_first_fit() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut occ = BankOccupancy::new(&cfg);
        occ.claim(1, 2..5);
        assert_eq!(occ.find_contiguous(2), Some(0));
        assert_eq!(occ.find_contiguous(5), Some(5));
        occ.claim(2, 0..2);
        assert_eq!(occ.find_contiguous(1), Some(5));
    }
}
