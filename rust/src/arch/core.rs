//! Cycle model of one Neutron compute core (Sec. III-B).
//!
//! The core is M parallel, pipelined dot-product units of vector length N,
//! output-stationary with A accumulators per unit. The model estimates the
//! cycles of one compute job from the layer geometry and the spatial format
//! (depth vs line parallelism), capturing the utilization effects the paper
//! builds its format-selection pass on:
//!
//!   * channel padding: the M units map to output channels — layers with
//!     few channels strand units;
//!   * vector padding: contraction lengths pad up to N (depthwise convs at
//!     K = kh·kw ≪ N are the classic low-utilization case);
//!   * engine padding: the spatially-tiled dimension pads to the engine
//!     count for lockstep execution;
//!   * bus bound: a job can never run faster than its operand/result
//!     streams through the core's three 128-bit buses (the data engine's
//!     2-D register file gives reuse, so only compulsory traffic counts).

use super::config::NeutronConfig;
use crate::ir::{Op, OpKind};

/// Work description of one compute job (one layer tile on one-or-all cores).
#[derive(Debug, Clone, Copy)]
pub struct JobGeometry {
    /// Output tile height (per the whole job, pre-engine-split).
    pub out_h: usize,
    pub out_w: usize,
    pub out_c: usize,
    /// Contraction: input channels (1 for depthwise-style ops).
    pub in_c: usize,
    pub filter_h: usize,
    pub filter_w: usize,
    /// Depthwise-style op (contraction excludes channels).
    pub depthwise: bool,
    /// Bytes/element of activations (1 = int8, 2 = int16: two-cycle MACs).
    pub elem_bytes: usize,
}

impl JobGeometry {
    /// Derive from an IR op producing an (oh, ow, oc) output tile.
    pub fn from_op(op: &Op, out_h: usize, out_w: usize, out_c: usize, in_c: usize) -> Self {
        let (fh, fw, depthwise) = match &op.kind {
            OpKind::Conv2d { geom, .. } => (geom.filter_h, geom.filter_w, false),
            OpKind::DepthwiseConv2d { geom } => (geom.filter_h, geom.filter_w, true),
            OpKind::FullyConnected { .. } | OpKind::MatMul { .. } => (1, 1, false),
            OpKind::Add | OpKind::Mul | OpKind::ScalarAddMul => (1, 1, true),
            OpKind::Pool { size, .. } => (*size, *size, true),
            OpKind::GlobalAvgPool => (out_h.max(1), out_w.max(1), true),
            OpKind::ActivationOnly(_) | OpKind::Softmax => (1, 1, true),
            // Data movement ops have no MAC geometry.
            _ => (1, 1, true),
        };
        Self {
            out_h,
            out_w,
            out_c,
            in_c: if depthwise { 1 } else { in_c },
            filter_h: fh,
            filter_w: fw,
            depthwise,
            elem_bytes: 1,
        }
    }

    /// MACs of the job.
    pub fn macs(&self) -> u64 {
        (self.out_h * self.out_w * self.out_c) as u64
            * (self.filter_h * self.filter_w * self.in_c) as u64
    }
}

/// Spatial format (Sec. IV-A): which output dimension is split across the
/// lockstepped compute engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// Depth parallelism: engines split `outC`; ifmap broadcast.
    Depth,
    /// Line parallelism: engines split `outH`; parameters broadcast.
    Line,
}

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Depth => "depth",
            Format::Line => "line",
        }
    }
}

/// Cycle estimate for one compute job, split into its bounding terms (used
/// by the scheduler's objective and by EXPERIMENTS.md §Perf reporting).
#[derive(Debug, Clone, Copy)]
pub struct ComputeCost {
    /// MAC-array cycles (with all padding effects).
    pub mac_cycles: u64,
    /// Operand/result bus-bound cycles.
    pub bus_cycles: u64,
    /// Fixed job programming overhead.
    pub overhead_cycles: u64,
}

impl ComputeCost {
    /// Total latency of the job: datapath and buses overlap (deep
    /// pipelining, Sec. III-A2), so the job is bound by the slower of the
    /// two plus dispatch overhead.
    pub fn total(&self) -> u64 {
        self.mac_cycles.max(self.bus_cycles) + self.overhead_cycles
    }

    /// Effective utilization of the MAC array in [0, 1] given ideal MACs.
    pub fn utilization(&self, ideal_macs: u64, macs_per_cycle: u64) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        ideal_macs as f64 / (self.total() * macs_per_cycle) as f64
    }
}

/// Estimate compute-job cycles for `geom` under `format` on `cfg`.
///
/// `engines` is the number of lockstepped cores the job runs on (broadcast
/// mode) — 1 when each core runs an independent job.
pub fn compute_cycles(
    cfg: &NeutronConfig,
    geom: &JobGeometry,
    format: Format,
    engines: usize,
) -> ComputeCost {
    let engines = engines.max(1);
    // --- Engine-level split of the tiled dimension (lockstep => ceil). ---
    let (eng_h, eng_c) = match format {
        Format::Depth => (geom.out_h, geom.out_c.div_ceil(engines)),
        Format::Line => (geom.out_h.div_ceil(engines), geom.out_c),
    };

    // --- Per-engine datapath cycles. ---
    let mac_cycles = if geom.depthwise {
        // Depthwise-style: units map to channels, contraction = fh·fw only.
        let unit_steps = eng_c.div_ceil(cfg.m) as u64;
        let k = (geom.filter_h * geom.filter_w) as u64;
        let dot_cycles = k.div_ceil(cfg.n as u64).max(1);
        (eng_h * geom.out_w) as u64 * unit_steps * dot_cycles
    } else {
        // Dense: units map to output channels; contraction = fh·fw·inC,
        // streamed as fh·fw chunks of ceil(inC/N) vector-cycles (HWC rows
        // are contiguous per filter row).
        let unit_steps = eng_c.div_ceil(cfg.m) as u64;
        let dot_cycles =
            (geom.filter_h * geom.filter_w) as u64 * (geom.in_c.div_ceil(cfg.n) as u64);
        (eng_h * geom.out_w) as u64 * unit_steps * dot_cycles
    };
    // 8×16-bit operands take two passes through the 8-bit multipliers.
    let mac_cycles = mac_cycles * geom.elem_bytes as u64;

    // --- Bus bound: compulsory operand + result traffic per engine. ---
    // The data engine's register file and W_C scratchpad give full reuse
    // within the job, so traffic = one read of inputs + params + one write
    // of outputs (per engine, using the padded engine partition).
    let in_h = geom.out_h; // stride folded into tile selection upstream
    let in_bytes_engine = match format {
        // Depth: full ifmap broadcast (shared bus — count once per engine
        // set), params split per engine.
        Format::Depth => {
            let ifmap = (in_h * geom.out_w * geom.in_c.max(1)) as u64;
            let params =
                (eng_c * geom.filter_h * geom.filter_w * geom.in_c.max(1)) as u64;
            ifmap + params
        }
        // Line: ifmap rows split per engine (plus halo), params broadcast.
        Format::Line => {
            let halo = geom.filter_h.saturating_sub(1);
            let ifmap = ((eng_h + halo) * geom.out_w * geom.in_c.max(1)) as u64;
            let params =
                (geom.out_c * geom.filter_h * geom.filter_w * geom.in_c.max(1)) as u64;
            ifmap + params
        }
    };
    let out_bytes_engine = (eng_h.min(geom.out_h) * geom.out_w * eng_c.min(geom.out_c)) as u64;
    let bytes = (in_bytes_engine + out_bytes_engine) * geom.elem_bytes as u64;
    let bus_cycles = bytes.div_ceil(cfg.core_bus_bytes_per_cycle() as u64);

    ComputeCost { mac_cycles, bus_cycles, overhead_cycles: cfg.job_overhead_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NeutronConfig {
        NeutronConfig::flagship_2tops()
    }

    fn dense(out_h: usize, out_w: usize, out_c: usize, in_c: usize, k: usize) -> JobGeometry {
        JobGeometry {
            out_h,
            out_w,
            out_c,
            in_c,
            filter_h: k,
            filter_w: k,
            depthwise: false,
            elem_bytes: 1,
        }
    }

    #[test]
    fn full_utilization_on_big_dense_conv() {
        let c = cfg();
        let g = dense(16, 16, 64, 64, 3);
        let cost = compute_cycles(&c, &g, Format::Depth, 4);
        // Per engine: oc 16 → 1 unit step; K = 9·64 → 9·4 = 36 dot cycles.
        assert_eq!(cost.mac_cycles, 16 * 16 * 36);
        let util = g.macs() as f64 / 4.0 / (cost.mac_cycles * (16 * 16) as u64) as f64;
        assert!(util > 0.99, "util={util}");
    }

    #[test]
    fn depthwise_is_vector_bound() {
        let c = cfg();
        let g = JobGeometry {
            out_h: 16,
            out_w: 16,
            out_c: 64,
            in_c: 1,
            filter_h: 3,
            filter_w: 3,
            depthwise: true,
            elem_bytes: 1,
        };
        let cost = compute_cycles(&c, &g, Format::Depth, 4);
        // 9-long dots pad to one 16-long vector cycle: 9/16 utilization.
        let macs_per_cyc = (c.n * c.m) as u64;
        let util = cost.utilization(g.macs() / 4, macs_per_cyc);
        assert!(util < 0.60, "depthwise util should collapse, got {util}");
    }

    #[test]
    fn shallow_layer_prefers_line_parallelism() {
        let c = cfg();
        // 8 output channels over 4 engines: depth parallelism strands MACs.
        let g = dense(64, 64, 8, 3, 3);
        let depth = compute_cycles(&c, &g, Format::Depth, 4).total();
        let line = compute_cycles(&c, &g, Format::Line, 4).total();
        assert!(
            line < depth,
            "line ({line}) should beat depth ({depth}) on shallow layers"
        );
    }

    #[test]
    fn deep_layer_prefers_depth_parallelism_bus_wise() {
        let c = cfg();
        // Many channels, few lines: depth splits channels across engines.
        let g = dense(4, 4, 512, 512, 1);
        let depth = compute_cycles(&c, &g, Format::Depth, 4);
        let line = compute_cycles(&c, &g, Format::Line, 4);
        // Line parallelism must broadcast ALL params to each engine: its
        // bus traffic is ~4× higher here.
        assert!(depth.bus_cycles < line.bus_cycles);
        // And with only 4 lines, line parallelism pads rows per engine.
        assert!(depth.total() <= line.total());
    }

    #[test]
    fn int16_doubles_mac_cycles() {
        let c = cfg();
        let g8 = dense(8, 8, 32, 32, 3);
        let g16 = JobGeometry { elem_bytes: 2, ..g8 };
        let c8 = compute_cycles(&c, &g8, Format::Depth, 1);
        let c16 = compute_cycles(&c, &g16, Format::Depth, 1);
        assert_eq!(c16.mac_cycles, 2 * c8.mac_cycles);
    }

    #[test]
    fn overhead_included_in_total() {
        let c = cfg();
        let g = dense(1, 1, 1, 1, 1);
        let cost = compute_cycles(&c, &g, Format::Depth, 1);
        assert!(cost.total() >= c.job_overhead_cycles);
    }
}
