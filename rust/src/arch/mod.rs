//! Architecture model of the eIQ Neutron NPU subsystem (Sec. III): core
//! dot-product-array cycle model, TCM banks + V2P table, DMA latency model,
//! and the subsystem configuration (N, M, A, W_C, cores, TCM, DDR).

pub mod config;
pub mod core;
pub mod dma;
pub mod tcm;

pub use config::NeutronConfig;
pub use core::{compute_cycles, ComputeCost, Format, JobGeometry};
pub use dma::{DdrTraffic, Transfer, TransferKind};
pub use tcm::{Bank, BankOccupancy, ResidencyEntry, TcmResidency, V2pTable};
