//! Architecture configuration of the Neutron NPU subsystem (Sec. III).

/// Parameters of one Neutron compute core and the surrounding subsystem.
///
/// The paper's flagship-MPU instance: `N = M = 16`, `A = 2M`,
/// `W_C = 8 KiB`, four cores at 1 GHz (2 TOPS), 1 MiB TCM, 12 GB/s DDR,
/// three 128-bit buses per core.
#[derive(Debug, Clone)]
pub struct NeutronConfig {
    /// Dot-product vector length (elements per unit per cycle).
    pub n: usize,
    /// Parallel dot-product units per core.
    pub m: usize,
    /// Accumulators per dot-product unit (output-stationary depth).
    pub a: usize,
    /// Weight-cache (scratchpad) bytes per core, `W_C`.
    pub wc_bytes: usize,
    /// Number of compute cores.
    pub cores: usize,
    /// Core clock in GHz.
    pub freq_ghz: f64,
    /// Total TCM capacity in bytes.
    pub tcm_bytes: usize,
    /// Number of (non-arbitrated) TCM banks — `C` in Eq. (7).
    pub tcm_banks: usize,
    /// Off-chip (DDR) bandwidth in GB/s.
    pub ddr_gbps: f64,
    /// Bus word width in bytes (128-bit buses).
    pub bus_bytes: usize,
    /// Operand/result buses per core.
    pub buses_per_core: usize,
    /// Fixed controller/firmware overhead per job dispatch, in cycles
    /// (RISC-V programming of a compute or DMA job; next-task programming
    /// overlaps with execution, so this is small).
    pub job_overhead_cycles: u64,
}

impl NeutronConfig {
    /// The 2-TOPS flagship-MPU instance evaluated in the paper.
    pub fn flagship_2tops() -> Self {
        Self {
            n: 16,
            m: 16,
            a: 32,
            wc_bytes: 8 * 1024,
            cores: 4,
            freq_ghz: 1.0,
            tcm_bytes: 1 << 20,
            tcm_banks: 32,
            ddr_gbps: 12.0,
            bus_bytes: 16,
            buses_per_core: 3,
            job_overhead_cycles: 256,
        }
    }

    /// A single-core 0.5-TOPS MCU-class instance (used by scaling tests).
    pub fn mcu_half_tops() -> Self {
        Self {
            cores: 1,
            tcm_bytes: 512 * 1024,
            tcm_banks: 16,
            ddr_gbps: 6.0,
            ..Self::flagship_2tops()
        }
    }

    /// Peak TOPS = 2·N·M·cores·f / 1e12.
    pub fn peak_tops(&self) -> f64 {
        2.0 * (self.n * self.m * self.cores) as f64 * self.freq_ghz * 1e9 / 1e12
    }

    /// Bytes one TCM bank holds.
    pub fn bank_bytes(&self) -> usize {
        self.tcm_bytes / self.tcm_banks
    }

    /// DDR bytes per core-clock cycle.
    pub fn ddr_bytes_per_cycle(&self) -> f64 {
        self.ddr_gbps / self.freq_ghz
    }

    /// Aggregate TCM bandwidth available to one core's operand buses,
    /// bytes/cycle (each bus moves one word per cycle).
    pub fn core_bus_bytes_per_cycle(&self) -> usize {
        self.bus_bytes * self.buses_per_core
    }

    /// Convert cycles to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_ghz * 1e9) / 1e-3
    }

    /// Banks needed to hold `bytes` (tiles occupy whole banks — bank
    /// exclusivity is the unit of the CP memory constraints).
    pub fn banks_for(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.bank_bytes()).max(1)
    }
}

impl Default for NeutronConfig {
    fn default() -> Self {
        Self::flagship_2tops()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flagship_is_2_tops() {
        let c = NeutronConfig::flagship_2tops();
        assert!((c.peak_tops() - 2.048).abs() < 0.05);
        assert_eq!(c.bank_bytes(), 32 * 1024);
    }

    #[test]
    fn ddr_bytes_per_cycle() {
        let c = NeutronConfig::flagship_2tops();
        assert!((c.ddr_bytes_per_cycle() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn cycles_to_ms_at_1ghz() {
        let c = NeutronConfig::flagship_2tops();
        assert!((c.cycles_to_ms(1_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn banks_round_up() {
        let c = NeutronConfig::flagship_2tops();
        assert_eq!(c.banks_for(1), 1);
        assert_eq!(c.banks_for(32 * 1024), 1);
        assert_eq!(c.banks_for(32 * 1024 + 1), 2);
    }
}
