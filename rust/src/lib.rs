//! # eIQ Neutron reproduction
//!
//! Production-quality reproduction of *"eIQ Neutron: Redefining Edge-AI
//! Inference with Integrated NPU and Compiler Innovations"* (Bamberg et al.,
//! 2025): a near-memory-compute NPU architecture model, a constraint-
//! programming compiler mid-end (format selection, temporal tiling + layer
//! fusion, DAE scheduling, memory allocation), a tick-based decoupled
//! access-execute simulator, baseline NPU models, a PJRT runtime that
//! executes AOT-lowered JAX/Pallas kernels for numerics, and a
//! multi-tenant serving layer (compile cache + overload-aware
//! virtual-clock scheduler over N simulated NPU instances) with a trace
//! capture/replay + timing-model calibration subsystem on top.
//!
//! See `README.md` for the architecture map and `docs/serving.md` for
//! the serving layer's contract.

pub mod arch;
pub mod baselines;
pub mod compiler;
pub mod coordinator;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod cp;
pub mod ir;
pub mod util;
pub mod zoo;
