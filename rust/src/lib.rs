//! # eIQ Neutron reproduction
//!
//! Production-quality reproduction of *"eIQ Neutron: Redefining Edge-AI
//! Inference with Integrated NPU and Compiler Innovations"* (Bamberg et al.,
//! 2025): a near-memory-compute NPU architecture model, a constraint-
//! programming compiler mid-end (format selection, temporal tiling + layer
//! fusion, DAE scheduling, memory allocation), a tick-based decoupled
//! access-execute simulator, baseline NPU models, a PJRT runtime that
//! executes AOT-lowered JAX/Pallas kernels for numerics, and a
//! multi-tenant serving layer (compile cache + overload-aware
//! virtual-clock scheduler over N simulated NPU instances) with a trace
//! capture/replay + timing-model calibration subsystem on top. Energy is
//! a first-class metric: `energy/` prices every tick into joules
//! (compute / DMA / idle, exactly conserved), fits an energy calibration
//! through the same trace loop, and drives energy-aware scheduling
//! (race-to-idle vs stretch, per-class joule budgets).
//!
//! See `README.md` for the architecture map and `docs/serving.md` for
//! the serving layer's contract.

pub mod arch;
pub mod baselines;
pub mod compiler;
pub mod coordinator;
pub mod energy;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod cp;
pub mod ir;
pub mod util;
pub mod zoo;
