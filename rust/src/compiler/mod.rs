//! Compiler mid-end (Sec. IV): format selection, temporal tiling + layer
//! fusion (CP, Eq. 9–12), DAE scheduling (CP, Eq. 1–8), and memory
//! allocation (CP, Sec. IV-D), with the problem partitioning that gives the
//! compile-time/inference-time trade-off of Table II.

pub mod allocation;
pub mod cost;
pub mod format;
pub mod pipeline;
pub mod scheduling;
pub mod tiling;

pub use allocation::{allocate, allocate_with, allocate_with_stats, Allocation, Placement};
pub use cost::{
    calibrated_layer_latency_cycles, dispatch_cost, layer_latency_cycles, ContextCurve,
    CostCalibration, CostModel, DispatchCost, OpProfile,
};
pub use format::{select_formats, select_formats_with, FormatPlan};
pub use pipeline::{compile, compile_with_stats, Compiled, CompileOptions};
pub use scheduling::{
    schedule, schedule_with, schedule_with_stats, Schedule, ScheduledTransfer, SchedulingOptions,
    Tick,
};
pub use tiling::{
    tile_graph, tile_graph_with, tile_graph_with_stats, ComputeStep, Tile, TileId, TiledProgram,
    TilingOptions,
};
