//! Memory allocation (Sec. IV-D): assign each tile a contiguous *virtual*
//! bank range in TCM and derive the physical mapping + V2P updates.
//!
//! Constraints from the paper:
//!   a) virtual-space contiguity: tiles of one tensor sit sequentially in
//!      virtual memory (consumers' receptive fields may span tiles);
//!   b) physical-space preservation: a tile keeps its physical banks for
//!      its whole lifetime;
//!   c) reuse optimization: output tensors placed before inputs (correct
//!      distance) so consumed data can be overwritten;
//!   d) bank exclusivity: tensors used in the same timestep never share a
//!      bank.
//!
//! Formulated as a CP per partition (start-bank integer per tensor
//! allocation interval, pairwise disjunctions over concurrently-live
//! tensors); a first-fit fallback guarantees progress if the solver's
//! budget expires — the scheduling constraints (Eq. 7) proved capacity is
//! sufficient, so first-fit over whole banks always succeeds.
//!
//! Allocation never queries cycle costs directly: its inputs are tile
//! lifetimes derived from the schedule, which the calibrated cost facade
//! (`compiler::CostModel`) already priced. With an identity calibration
//! the schedule — and therefore this pass's placements — is bit-identical
//! to the uncalibrated compiler's.

use std::collections::HashMap;

use super::scheduling::Schedule;
use super::tiling::{TiledProgram, TileId};
use crate::arch::{NeutronConfig, V2pTable};
use crate::cp::{Cmp, CpModel, LinExpr, SearchConfig, SolveStats, Status};
use crate::ir::TensorId;

/// Per-tile placement: virtual bank interval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub first_bank: usize,
    pub banks: usize,
}

impl Placement {
    pub fn range(&self) -> std::ops::Range<usize> {
        self.first_bank..self.first_bank + self.banks
    }

    pub fn overlaps(&self, other: &Placement) -> bool {
        self.first_bank < other.first_bank + other.banks
            && other.first_bank < self.first_bank + self.banks
    }
}

/// Allocation result: placements + the V2P update trace the coordinator
/// replays at runtime.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Allocation {
    pub placements: HashMap<TileId, Placement>,
    /// (tick, virtual bank, physical bank) updates in issue order.
    pub v2p_updates: Vec<(usize, usize, usize)>,
    /// CP solve statistics (ms, subproblems).
    pub solve_ms: u64,
    pub subproblems: usize,
}

/// Lifetime interval of a tile in ticks (inclusive).
fn tile_lifetimes(prog: &TiledProgram, sched: &Schedule) -> HashMap<TileId, (usize, usize)> {
    let mut lt: HashMap<TileId, (usize, usize)> = HashMap::new();
    let mut touch = |t: TileId, tick: usize, lt: &mut HashMap<TileId, (usize, usize)>| {
        let e = lt.entry(t).or_insert((tick, tick));
        e.0 = e.0.min(tick);
        e.1 = e.1.max(tick);
    };
    for (ti, tick) in sched.ticks.iter().enumerate() {
        if let Some(si) = tick.compute {
            let s = &prog.steps[si];
            touch(s.out_tile, ti, &mut lt);
            for &t in &s.in_tiles {
                touch(t, ti, &mut lt);
            }
            if let Some(p) = s.param_tile {
                touch(p, ti, &mut lt);
            }
        }
        for tr in &tick.transfers {
            touch(tr.tile, ti, &mut lt);
        }
    }
    lt
}

/// Allocate TCM banks for every tile in the schedule (cold solve).
pub fn allocate(
    prog: &TiledProgram,
    sched: &Schedule,
    cfg: &NeutronConfig,
    solver_cfg: &SearchConfig,
) -> Allocation {
    allocate_with(prog, sched, cfg, solver_cfg, None)
}

/// Allocate TCM banks for every tile in the schedule, optionally seeding
/// each cluster CP from a prior [`Allocation`] of the same program (warm
/// start). A stale prior — missing tiles, shifted lifetimes, overlapping
/// placements — fails the solver's hint validation and the cluster falls
/// back to a cold solve; warm-starting never changes feasibility.
pub fn allocate_with(
    prog: &TiledProgram,
    sched: &Schedule,
    cfg: &NeutronConfig,
    solver_cfg: &SearchConfig,
    warm: Option<&Allocation>,
) -> Allocation {
    allocate_with_stats(prog, sched, cfg, solver_cfg, warm).0
}

/// Like [`allocate_with`], additionally returning the merged [`SolveStats`]
/// of every cluster CP solve (propagation-engine telemetry — never part of
/// the allocation itself, so artifact bytes and plan equality are
/// unaffected).
pub fn allocate_with_stats(
    prog: &TiledProgram,
    sched: &Schedule,
    cfg: &NeutronConfig,
    solver_cfg: &SearchConfig,
    warm: Option<&Allocation>,
) -> (Allocation, SolveStats) {
    let lifetimes = tile_lifetimes(prog, sched);
    let mut tiles: Vec<TileId> = lifetimes.keys().copied().collect();
    tiles.sort();

    // Group sibling tiles (same tensor) — constraint (a) makes them one
    // contiguous virtual allocation while they are CO-RESIDENT. Temporal
    // tiles whose lifetimes do not overlap (the tensor streams through
    // TCM slice by slice) go into separate groups: only co-alive
    // neighbours (e.g. halo pairs) need contiguity.
    let mut by_tensor: HashMap<TensorId, Vec<TileId>> = HashMap::new();
    for &t in &tiles {
        by_tensor.entry(prog.tile(t).tensor).or_default().push(t);
    }
    let mut group_list: Vec<(TensorId, Vec<TileId>, (usize, usize), usize)> = Vec::new();
    let mut tensors: Vec<TensorId> = by_tensor.keys().copied().collect();
    tensors.sort();
    for tensor in tensors {
        let mut ts = by_tensor.remove(&tensor).unwrap();
        ts.sort_by_key(|&t| prog.tile(t).part.0);
        // Split into runs of lifetime-overlapping siblings.
        let mut run: Vec<TileId> = Vec::new();
        let mut run_end = 0usize;
        for t in ts {
            let (lo, hi) = lifetimes[&t];
            if run.is_empty() || lo <= run_end {
                run_end = run_end.max(hi);
                run.push(t);
            } else {
                push_group(prog, &lifetimes, &mut group_list, tensor, std::mem::take(&mut run));
                run.push(t);
                run_end = hi;
            }
        }
        if !run.is_empty() {
            push_group(prog, &lifetimes, &mut group_list, tensor, run);
        }
    }

    fn push_group(
        prog: &TiledProgram,
        lifetimes: &HashMap<TileId, (usize, usize)>,
        out: &mut Vec<(TensorId, Vec<TileId>, (usize, usize), usize)>,
        tensor: TensorId,
        ts: Vec<TileId>,
    ) {
        let lo = ts.iter().map(|t| lifetimes[t].0).min().unwrap();
        let hi = ts.iter().map(|t| lifetimes[t].1).max().unwrap();
        let banks: usize = ts.iter().map(|&t| prog.tile(t).banks).sum();
        out.push((tensor, ts, (lo, hi), banks));
    }

    // Partition groups into overlapping-lifetime clusters; solve each as a
    // small CP (Sec. IV-D: "decomposed into smaller subproblems").
    let mut alloc = Allocation::default();
    let mut order: Vec<usize> = (0..group_list.len()).collect();
    order.sort_by_key(|&i| group_list[i].2 .0);
    let mut cluster: Vec<usize> = Vec::new();
    let mut cluster_end = 0usize;
    let mut clusters: Vec<Vec<usize>> = Vec::new();
    for &gi in &order {
        let (_, _, (lo, hi), _) = &group_list[gi];
        if cluster.is_empty() || *lo <= cluster_end {
            cluster_end = cluster_end.max(*hi);
            cluster.push(gi);
        } else {
            clusters.push(std::mem::take(&mut cluster));
            cluster.push(gi);
            cluster_end = *hi;
        }
        // Cap cluster size to keep the CP small.
        if cluster.len() >= 10 {
            clusters.push(std::mem::take(&mut cluster));
            cluster_end = 0;
        }
    }
    if !cluster.is_empty() {
        clusters.push(cluster);
    }

    let mut cp_stats = SolveStats::default();
    for cl in &clusters {
        alloc.subproblems += 1;
        let solved =
            solve_cluster(prog, &group_list, cl, cfg, solver_cfg, warm, &mut alloc, &mut cp_stats);
        if !solved {
            first_fit_cluster(prog, &group_list, cl, cfg, &mut alloc);
        }
    }

    // Derive V2P updates: whenever a new group begins life on banks another
    // (now-dead) group used, remap so the engine view stays contiguous.
    // With whole-bank placements an identity-per-interval map suffices;
    // emit one update per group start for the coordinator to replay.
    let mut v2p = V2pTable::identity(cfg.tcm_banks);
    for &gi in order.iter() {
        let (_, ts, (lo, _), _) = &group_list[gi];
        for t in ts {
            if let Some(p) = alloc.placements.get(t) {
                for vb in p.range() {
                    let pb = v2p.translate(vb);
                    alloc.v2p_updates.push((*lo, vb, pb));
                }
            }
        }
        let _ = &mut v2p;
    }
    (alloc, cp_stats)
}

/// CP model for one cluster: start-bank integers + pairwise no-overlap for
/// lifetime-overlapping groups; objective prefers low banks (reuse, (c)).
#[allow(clippy::too_many_arguments)]
fn solve_cluster(
    prog: &TiledProgram,
    groups: &[(TensorId, Vec<TileId>, (usize, usize), usize)],
    cluster: &[usize],
    cfg: &NeutronConfig,
    solver_cfg: &SearchConfig,
    warm: Option<&Allocation>,
    alloc: &mut Allocation,
    cp_stats: &mut SolveStats,
) -> bool {
    let c = cfg.tcm_banks as i64;
    let mut m = CpModel::new();
    let mut starts = HashMap::new();
    for &gi in cluster {
        let (_, _, _, banks) = &groups[gi];
        if *banks as i64 > c {
            return false; // oversized tensor: only first-fit's split handles it
        }
        let v = m.int_var(0, c - *banks as i64, format!("start_{gi}"));
        starts.insert(gi, v);
    }
    // Pairwise no-overlap where lifetimes intersect (constraint (d)):
    // s_a + banks_a ≤ s_b  OR  s_b + banks_b ≤ s_a, via an order boolean.
    let mut order_bools: Vec<(usize, usize, crate::cp::Var)> = Vec::new();
    for (i, &ga) in cluster.iter().enumerate() {
        for &gb in cluster.iter().skip(i + 1) {
            let (_, _, (alo, ahi), abanks) = &groups[ga];
            let (_, _, (blo, bhi), bbanks) = &groups[gb];
            if *ahi < *blo || *bhi < *alo {
                continue; // disjoint lifetimes may share banks
            }
            let before = m.bool_var(format!("ord_{ga}_{gb}"));
            order_bools.push((ga, gb, before));
            // before=1 ⇒ s_a + banks_a ≤ s_b :  s_a - s_b + M·before ≤ M - banks_a
            let big = c;
            m.add(
                LinExpr::new()
                    .add(1, starts[&ga])
                    .add(-1, starts[&gb])
                    .add(big, before),
                Cmp::Le,
                big - *abanks as i64,
            );
            // before=0 ⇒ s_b + banks_b ≤ s_a : s_b - s_a - M·before ≤ -banks_b
            m.add(
                LinExpr::new()
                    .add(1, starts[&gb])
                    .add(-1, starts[&ga])
                    .add(-big, before),
                Cmp::Le,
                -(*bbanks as i64),
            );
        }
    }
    // Objective: pack low (enables output-before-input overwriting).
    let mut obj = LinExpr::new();
    for &gi in cluster {
        obj.push(1, starts[&gi]);
    }
    m.minimize(obj);

    // Warm start: seed each group's start bank from the prior allocation
    // (the group's first tile) and derive the order booleans consistently.
    // Any inconsistency (overlapping priors, out-of-range starts) makes
    // the hint violate the model and the solver drops it.
    let hint: Option<Vec<i64>> = warm.and_then(|prev| {
        let mut h = vec![0i64; m.num_vars()];
        for &gi in cluster {
            let (_, ts, _, _) = &groups[gi];
            let p = prev.placements.get(ts.first()?)?;
            h[starts[&gi].index()] = p.first_bank as i64;
        }
        for &(ga, gb, before) in &order_bools {
            let sa = h[starts[&ga].index()];
            let sb = h[starts[&gb].index()];
            let abanks = groups[ga].3 as i64;
            h[before.index()] = i64::from(sa + abanks <= sb);
        }
        Some(h)
    });
    let cfg_with_hint = SearchConfig {
        hint: hint.or_else(|| solver_cfg.hint.clone()),
        ..solver_cfg.clone()
    };
    let sol = crate::cp::solve(&m, cfg_with_hint);
    cp_stats.merge(&sol.stats);
    if !matches!(sol.status, Status::Optimal | Status::Feasible) {
        return false;
    }
    alloc.solve_ms += sol.solve_ms;
    for &gi in cluster {
        let (_, ts, _, _) = &groups[gi];
        let mut bank = match sol.value(starts[&gi]) {
            Ok(b) => b as usize,
            Err(_) => return false,
        };
        for &t in ts {
            let banks = prog.tile(t).banks;
            alloc.placements.insert(t, Placement { first_bank: bank, banks });
            bank += banks;
        }
    }
    true
}

/// Greedy fallback: first-fit per group in lifetime order. The schedule's
/// capacity constraints guarantee a fit exists at whole-bank granularity
/// *per tick*; when fragmentation blocks a contiguous run, V2P remapping
/// makes any free set contiguous in the virtual view, so we allocate the
/// lowest free banks (possibly discontiguous physically).
fn first_fit_cluster(
    prog: &TiledProgram,
    groups: &[(TensorId, Vec<TileId>, (usize, usize), usize)],
    cluster: &[usize],
    cfg: &NeutronConfig,
    alloc: &mut Allocation,
) {
    // Interval-based free tracking per bank.
    let mut busy: Vec<Vec<(usize, usize)>> = vec![Vec::new(); cfg.tcm_banks];
    let is_free = |busy: &Vec<Vec<(usize, usize)>>, b: usize, lo: usize, hi: usize| {
        busy[b].iter().all(|&(l, h)| hi < l || h < lo)
    };
    let mut order: Vec<usize> = cluster.to_vec();
    order.sort_by_key(|&gi| groups[gi].2 .0);
    for gi in order {
        let (_, ts, (lo, hi), banks) = &groups[gi];
        // Collect the lowest `banks` free banks over [lo, hi].
        let mut chosen = Vec::new();
        for b in 0..cfg.tcm_banks {
            if is_free(&busy, b, *lo, *hi) {
                chosen.push(b);
                if chosen.len() == *banks {
                    break;
                }
            }
        }
        // Oversized or over-committed: reuse high banks round-robin (the
        // tile streams through TCM — the schedule priced this as spills).
        while chosen.len() < *banks {
            chosen.push(cfg.tcm_banks - 1 - (chosen.len() % cfg.tcm_banks));
        }
        for &b in chosen.iter().take(*banks.min(&cfg.tcm_banks)) {
            busy[b].push((*lo, *hi));
        }
        let mut idx = 0;
        for &t in ts {
            let tb = prog.tile(t).banks;
            let first = chosen.get(idx).copied().unwrap_or(0).min(cfg.tcm_banks - 1);
            // Clamp so the virtual interval stays inside the bank space.
            let tb = tb.min(cfg.tcm_banks - first);
            alloc.placements.insert(t, Placement { first_bank: first, banks: tb });
            idx += tb;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::format::select_formats;
    use crate::compiler::scheduling::{schedule, SchedulingOptions};
    use crate::compiler::tiling::{tile_graph, TilingOptions};
    use crate::zoo;

    fn run(g: &crate::ir::Graph) -> (TiledProgram, Schedule, Allocation) {
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(g, &cfg);
        let prog = tile_graph(g, &plan, &cfg, &TilingOptions::default());
        let s = schedule(&prog, &cfg, &SchedulingOptions::default());
        let a = allocate(&prog, &s, &cfg, &SearchConfig { time_limit_ms: Some(500), ..Default::default() });
        (prog, s, a)
    }

    #[test]
    fn every_live_tile_gets_a_placement() {
        let g = zoo::mobilenet::mobilenet_v2();
        let (prog, s, a) = run(&g);
        let lts = tile_lifetimes(&prog, &s);
        for t in lts.keys() {
            assert!(a.placements.contains_key(t), "tile {t:?} unplaced");
        }
    }

    #[test]
    fn placements_fit_in_tcm() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let (_, _, a) = run(&g);
        for p in a.placements.values() {
            assert!(p.first_bank + p.banks <= cfg.tcm_banks + p.banks, "{p:?}");
            assert!(p.first_bank < cfg.tcm_banks);
        }
    }

    #[test]
    fn sibling_tiles_are_virtually_contiguous() {
        let g = zoo::yolo::yolov8n_det();
        let (prog, _, a) = run(&g);
        // For tensors split into multiple tiles placed by the CP path,
        // consecutive parts occupy consecutive virtual banks.
        let mut by_tensor: HashMap<crate::ir::TensorId, Vec<&crate::compiler::tiling::Tile>> =
            HashMap::new();
        for t in &prog.tiles {
            by_tensor.entry(t.tensor).or_default().push(t);
        }
        let mut checked = 0;
        for (_, mut ts) in by_tensor {
            if ts.len() < 2 {
                continue;
            }
            ts.sort_by_key(|t| t.part.0);
            let placements: Vec<_> = ts.iter().filter_map(|t| a.placements.get(&t.id)).collect();
            if placements.len() != ts.len() {
                continue;
            }
            let contiguous = placements
                .windows(2)
                .all(|w| w[0].first_bank + w[0].banks == w[1].first_bank);
            if contiguous {
                checked += 1;
            }
        }
        assert!(checked > 0, "no contiguous sibling groups found");
    }

    #[test]
    fn overlap_check_works() {
        let a = Placement { first_bank: 0, banks: 4 };
        let b = Placement { first_bank: 4, banks: 2 };
        let c = Placement { first_bank: 3, banks: 2 };
        assert!(!a.overlaps(&b));
        assert!(a.overlaps(&c));
        assert!(c.overlaps(&b));
    }
}
