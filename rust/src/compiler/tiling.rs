//! Temporal tiling + layer fusion (Sec. IV-C).
//!
//! Feature maps that exceed TCM are split into H-tiles processed at
//! different times. Tile sizes are chosen by a CP (Eq. 9–12): per tensor,
//! one boolean `LS_{k,i}` per size option (two options, per the paper:
//! "we consider only two tile-size options per layer"), a single-level
//! memory model, and the objective `min Σ_t (MemTh_t − C)` — the volume of
//! data that must spill off-chip during scheduling.
//!
//! Layer fusion falls out of the tile computation order: inside a fusion
//! region, tiles are emitted depth-first across layers (a consumer tile is
//! computed as soon as its input rows exist) rather than layer-by-layer,
//! which shrinks peak residency (Fig. 6). Regions are limited to the graph
//! sections whose activations cannot be held on-chip (Sec. IV-C
//! "Scalability"); elsewhere layer-by-layer order is kept.

use std::collections::HashMap;

use super::cost::{CostModel, OpProfile};
use super::format::FormatPlan;
use crate::arch::{Format, NeutronConfig};
use crate::cp::{CpModel, LinExpr, SearchConfig, SolveStats, Status};
use crate::ir::{Graph, OpId, TensorId, TensorKind};

/// Identifier of a tile in the tiled program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileId(pub u32);

impl TileId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One tile: a horizontal slice (or the whole) of a tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tile {
    pub id: TileId,
    pub tensor: TensorId,
    /// Slice index and count within the tensor (0/1 = untiled).
    pub part: (usize, usize),
    /// Output rows this tile covers (activations; params use 0).
    pub rows: usize,
    /// Payload bytes (C-padded).
    pub bytes: u64,
    /// TCM banks this tile occupies.
    pub banks: usize,
    /// Starts in DRAM (parameters + graph inputs) vs produced on-chip.
    pub starts_in_dram: bool,
    /// Must end in DRAM (graph outputs).
    pub is_graph_output: bool,
}

/// One compute step: produces one output tile of one op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ComputeStep {
    pub op: OpId,
    pub out_tile: TileId,
    /// Activation input tiles (with halos resolved).
    pub in_tiles: Vec<TileId>,
    /// Parameter tile, if the op has weights.
    pub param_tile: Option<TileId>,
    /// Format the job runs in.
    pub format: Format,
    /// Estimated compute cycles of this step.
    pub cycles: u64,
    /// Needs line-format expansion of inputs (filter_h > 1 under Line).
    pub needs_line_expand: bool,
}

/// The tiled program: tiles + compute steps in execution order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TiledProgram {
    pub tiles: Vec<Tile>,
    pub steps: Vec<ComputeStep>,
    /// Peak TCM demand (banks) per step under the chosen order, assuming
    /// nothing is spilled — what the scheduler has to fit into C.
    pub residency_banks: Vec<usize>,
}

impl TiledProgram {
    pub fn tile(&self, id: TileId) -> &Tile {
        &self.tiles[id.index()]
    }

    /// Total compute cycles (lower bound on latency).
    pub fn total_compute_cycles(&self) -> u64 {
        self.steps.iter().map(|s| s.cycles).sum()
    }
}

/// Options steering the tiling pass (Table II knobs).
#[derive(Debug, Clone)]
pub struct TilingOptions {
    /// Partition the fusion/tiling CP into per-region subproblems
    /// ("Only optimizations" row of Table II). Off = one monolithic CP.
    pub partition: bool,
    /// CP solver budget per subproblem.
    pub solver: SearchConfig,
    /// Warm start: split counts per op from a prior compile of the same
    /// graph (extracted from a cached [`TiledProgram`]). Seeds each region
    /// CP with the prior choice as its initial incumbent, so the anytime
    /// search can only match or improve on the previous compile. A stale
    /// map (missing ops, out-of-range splits) degrades to a cold solve.
    pub warm_splits: Option<HashMap<OpId, usize>>,
}

impl Default for TilingOptions {
    fn default() -> Self {
        Self {
            partition: true,
            solver: SearchConfig::default(),
            warm_splits: None,
        }
    }
}

/// Internal: per-op tiling candidate (the two LS options).
#[derive(Debug, Clone, Copy)]
struct SizeOption {
    splits: usize,
}

/// Run temporal tiling + fusion under the raw analytic cost model
/// (identity calibration). See [`tile_graph_with`].
pub fn tile_graph(
    graph: &Graph,
    plan: &FormatPlan,
    cfg: &NeutronConfig,
    opts: &TilingOptions,
) -> TiledProgram {
    tile_graph_with(graph, plan, &CostModel::uncalibrated(cfg), opts)
}

/// Run temporal tiling + fusion over the graph, pricing every step's
/// cycle estimate through the calibrated cost facade (the estimates feed
/// the scheduling objective and, through the emitted job program, the
/// simulator's tick timing).
pub fn tile_graph_with(
    graph: &Graph,
    plan: &FormatPlan,
    cost: &CostModel,
    opts: &TilingOptions,
) -> TiledProgram {
    tile_graph_with_stats(graph, plan, cost, opts).0
}

/// Like [`tile_graph_with`], additionally returning the merged
/// [`SolveStats`] of every region CP solve (propagation-engine telemetry —
/// never part of the tiled program, so artifact bytes are unaffected).
pub fn tile_graph_with_stats(
    graph: &Graph,
    plan: &FormatPlan,
    cost: &CostModel,
    opts: &TilingOptions,
) -> (TiledProgram, SolveStats) {
    let cfg = cost.cfg();
    let order = graph.topo_order();
    let profiles: HashMap<OpId, OpProfile> = order
        .iter()
        .map(|&oid| (oid, OpProfile::of(graph, graph.op(oid), cfg)))
        .collect();

    // --- Identify fusion regions: maximal runs of ops whose combined
    // in+out activation footprint exceeds the TCM budget. ---
    let budget = cfg.tcm_bytes as u64;
    let mut regions: Vec<Vec<OpId>> = Vec::new();
    let mut current: Vec<OpId> = Vec::new();
    for &oid in &order {
        let p = &profiles[&oid];
        let hot = p.input_bytes + p.output_bytes + p.param_bytes > budget / 2;
        if hot {
            current.push(oid);
        } else if !current.is_empty() {
            regions.push(std::mem::take(&mut current));
        }
    }
    if !current.is_empty() {
        regions.push(current);
    }
    let in_region: HashMap<OpId, usize> = regions
        .iter()
        .enumerate()
        .flat_map(|(ri, ops)| ops.iter().map(move |&o| (o, ri)))
        .collect();

    // --- Decide split counts per op output via the CP (per region when
    // partitioned; one model over all regions otherwise). ---
    let mut splits: HashMap<OpId, usize> = HashMap::new();
    for &oid in &order {
        splits.insert(oid, 1);
    }
    let region_groups: Vec<Vec<OpId>> = if opts.partition {
        regions.clone()
    } else if regions.is_empty() {
        Vec::new()
    } else {
        vec![regions.iter().flatten().copied().collect()]
    };
    let mut cp_stats = SolveStats::default();
    for region in &region_groups {
        let (chosen, sstats) = solve_region_sizes(
            graph,
            &profiles,
            region,
            cfg,
            &opts.solver,
            opts.warm_splits.as_ref(),
        );
        cp_stats.merge(&sstats);
        for (oid, s) in chosen {
            splits.insert(oid, s);
        }
    }

    // --- Materialize tiles. ---
    let mut prog = TiledProgram::default();
    let mut tensor_tiles: HashMap<TensorId, Vec<TileId>> = HashMap::new();

    let mut add_tile = |prog: &mut TiledProgram,
                        tensor: TensorId,
                        part: (usize, usize),
                        rows: usize,
                        bytes: u64,
                        starts_in_dram: bool,
                        is_graph_output: bool|
     -> TileId {
        let id = TileId(prog.tiles.len() as u32);
        prog.tiles.push(Tile {
            id,
            tensor,
            part,
            rows,
            bytes,
            banks: cfg.banks_for(bytes as usize),
            starts_in_dram,
            is_graph_output,
        });
        id
    };

    // Graph inputs: tiles resident in DRAM, split like activations so the
    // consumers of large inputs (640×640 detection images) fetch slices.
    for &t in &graph.inputs {
        let info = graph.tensor(t);
        let total = info.padded_size_bytes(cfg.bus_bytes);
        let k = total.div_ceil(cfg.tcm_bytes / 4).max(1).min(info.shape.h().max(1));
        let ids: Vec<TileId> = (0..k)
            .map(|s| {
                let rows = info.shape.h() / k + usize::from(s < info.shape.h() % k);
                add_tile(
                    &mut prog,
                    t,
                    (s, k),
                    rows,
                    (total / k).max(cfg.bus_bytes) as u64,
                    true,
                    false,
                )
            })
            .collect();
        tensor_tiles.insert(t, ids);
    }

    // Per op in order: parameter tile + output tiles + compute steps.
    // Fusion = depth-first emission inside a region: steps of consecutive
    // ops interleave per-tile; outside regions, layer-by-layer.
    #[derive(Clone)]
    struct PendingStep {
        op: OpId,
        out_tile: TileId,
        in_tiles: Vec<TileId>,
        param_tile: Option<TileId>,
        format: Format,
        cycles: u64,
        needs_line_expand: bool,
        region: Option<usize>,
    }
    let mut pending: Vec<PendingStep> = Vec::new();

    for &oid in &order {
        let op = graph.op(oid);
        let p = &profiles[&oid];
        let fmt = plan.format_of(oid);
        // CP-chosen split count, raised to the minimum that makes every
        // tile fit comfortably in TCM (≤ 1/4 of capacity, leaving room for
        // double-buffering and co-resident inputs).
        let out_bytes_full = graph.tensor(op.output).padded_size_bytes(cfg.bus_bytes);
        let required = out_bytes_full.div_ceil(cfg.tcm_bytes / 4).max(1);
        let n_splits = splits[&oid].max(required).max(1).min(p.out_h.max(1));
        let out_info = graph.tensor(op.output);
        let total_bytes = out_info.padded_size_bytes(cfg.bus_bytes) as u64;
        let is_out = graph.outputs.contains(&op.output);

        let param_tile = op.params.map(|pt| {
            let bytes = graph.tensor(pt).size_bytes() as u64;
            let id = add_tile(&mut prog, pt, (0, 1), 0, bytes, true, false);
            // Oversized parameter sets are streamed per-set (Sec. III-B:
            // "if parameters exceed W_C ... the remaining parameters are
            // streamed"): full fetch cost, but bounded TCM residency.
            let cap = (cfg.tcm_banks / 4).max(1);
            let t = &mut prog.tiles[id.index()];
            t.banks = t.banks.min(cap);
            tensor_tiles.insert(pt, vec![id]);
            id
        });

        let mut out_tiles = Vec::new();
        for s in 0..n_splits {
            let rows = p.out_h / n_splits + usize::from(s < p.out_h % n_splits);
            let bytes = (total_bytes * rows.max(1) as u64
                / p.out_h.max(1) as u64)
                .max(cfg.bus_bytes as u64);
            let tid = add_tile(&mut prog, op.output, (s, n_splits), rows, bytes, false, is_out);
            out_tiles.push(tid);

            // Input tiles: the slices of each activation input overlapping
            // this output slice's receptive field.
            let mut in_tiles = Vec::new();
            for &inp in &op.inputs {
                if let Some(tids) = tensor_tiles.get(&inp) {
                    let k = tids.len();
                    if k == 1 {
                        in_tiles.push(tids[0]);
                    } else {
                        // Matching slice + halo neighbour (stride-aware
                        // receptive fields never span more than the
                        // adjacent slice for our split granularity).
                        let idx = s * k / n_splits;
                        in_tiles.push(tids[idx.min(k - 1)]);
                        if p.filter_h > 1 && idx + 1 < k {
                            in_tiles.push(tids[idx + 1]);
                        }
                    }
                }
            }
            let cycles = if p.is_compute {
                cost.step_cycles(op, p, rows.max(1), fmt)
            } else {
                cost.data_step_cycles(op, bytes)
            };
            pending.push(PendingStep {
                op: oid,
                out_tile: tid,
                in_tiles,
                param_tile,
                format: fmt,
                cycles,
                needs_line_expand: fmt == Format::Line && p.filter_h > 1,
                region: in_region.get(&oid).copied(),
            });
        }
        tensor_tiles.insert(op.output, out_tiles);
    }

    // Order steps: fused regions interleave tiles depth-first (tile s of
    // every op in the region before tile s+1 of any), other ops stay in
    // layer order. Inside a region the desired priority is (tile index,
    // op); a ready-queue emission preserves data dependencies (a halo
    // consumer needs tile s+1 of its producer before its own tile s can
    // run, so a plain sort would be unsafe).
    let mut produced: Vec<bool> = prog.tiles.iter().map(|t| t.starts_in_dram).collect();
    let mut steps: Vec<PendingStep> = Vec::new();
    let mut i = 0;
    while i < pending.len() {
        match pending[i].region {
            None => {
                produced[pending[i].out_tile.index()] = true;
                steps.push(pending[i].clone());
                i += 1;
            }
            Some(r) => {
                let mut j = i;
                while j < pending.len() && pending[j].region == Some(r) {
                    j += 1;
                }
                let mut chunk: Vec<PendingStep> = pending[i..j].to_vec();
                chunk.sort_by_key(|s| {
                    let t = prog.tiles[s.out_tile.index()].part.0;
                    (t, s.op)
                });
                // Ready-queue emission in priority order.
                while !chunk.is_empty() {
                    let pos = chunk
                        .iter()
                        .position(|s| s.in_tiles.iter().all(|t| produced[t.index()]))
                        .unwrap_or(0); // cycle-free graphs always progress
                    let s = chunk.remove(pos);
                    produced[s.out_tile.index()] = true;
                    steps.push(s);
                }
                i = j;
            }
        }
    }

    for s in steps {
        prog.steps.push(ComputeStep {
            op: s.op,
            out_tile: s.out_tile,
            in_tiles: s.in_tiles,
            param_tile: s.param_tile,
            format: s.format,
            cycles: s.cycles,
            needs_line_expand: s.needs_line_expand,
        });
    }

    // Residency estimate per step: live tiles = produced-but-not-yet-fully-
    // consumed activations + inputs/params of the current step.
    prog.residency_banks = compute_residency(&prog);
    (prog, cp_stats)
}

/// The fusion/tiling CP for one region (Eq. 9–12): choose LS option per op
/// output to minimize Σ_t max(0, demand_t − C') where C' is the activation
/// budget in banks.
fn solve_region_sizes(
    graph: &Graph,
    profiles: &HashMap<OpId, OpProfile>,
    region: &[OpId],
    cfg: &NeutronConfig,
    solver_cfg: &SearchConfig,
    warm_splits: Option<&HashMap<OpId, usize>>,
) -> (Vec<(OpId, usize)>, SolveStats) {
    if region.is_empty() {
        return (Vec::new(), SolveStats::default());
    }
    let options: [SizeOption; 2] = [SizeOption { splits: 2 }, SizeOption { splits: 4 }];
    let c_banks = cfg.tcm_banks as i64;

    // Warm start: map each op's prior split count onto the nearest current
    // LS option (exact match preferred; larger priors round up). The hint
    // is completed into a full assignment below and validated by the
    // solver, so any mismatch simply falls back to a cold search.
    let warm_choice: Option<Vec<usize>> = warm_splits.map(|w| {
        region
            .iter()
            .map(|oid| {
                let prior = w.get(oid).copied().unwrap_or(options[0].splits);
                options
                    .iter()
                    .position(|o| o.splits == prior)
                    .unwrap_or(if prior > options[0].splits { options.len() - 1 } else { 0 })
            })
            .collect()
    });

    let mut m = CpModel::new();
    // LS_{k,i}: one bool per option per op (Eq. 10: exactly one selected).
    let mut ls: HashMap<OpId, Vec<crate::cp::Var>> = HashMap::new();
    for &oid in region {
        let vars: Vec<_> = options
            .iter()
            .enumerate()
            .map(|(k, _)| m.bool_var(format!("LS_{k}_{oid:?}")))
            .collect();
        m.add_exactly_one(vars.clone());
        ls.insert(oid, vars);
    }
    // Hint prefix: the LS booleans under the warm choice, matching var
    // creation order (all LS vars first, then one MemTh per timestep).
    let mut hint: Option<Vec<i64>> = warm_choice.as_ref().map(|choice| {
        let mut h = Vec::with_capacity(region.len() * (options.len() + 1));
        for &k in choice {
            for i in 0..options.len() {
                h.push(i64::from(i == k));
            }
        }
        h
    });
    // Timesteps = ops in region order (single-level memory model drops the
    // 3× factor, Sec. IV-C "Scalability"). MemTh_t ≥ Σ live tile banks.
    // Under option k, op i's live output occupies banks(i)/splits_k while
    // being produced tile-by-tile and its input likewise: the per-step
    // demand is (out_banks + in_banks + param_banks) scaled by the
    // selected option of the producing/consuming ops.
    let t_count = region.len();
    let mut obj = LinExpr::new();
    for t in 0..t_count {
        let oid = region[t];
        let p = &profiles[&oid];
        let memth = m.int_var(0, 4 * c_banks, format!("MemTh_{t}"));
        // demand(t) = Σ_k LS_k,op · (banks of working set under option k)
        let mut demand = LinExpr::new();
        let mut chosen_demand = 0i64;
        for (k, opt) in options.iter().enumerate() {
            let out_banks = cfg.banks_for(
                (p.output_bytes as usize / opt.splits).max(cfg.bus_bytes),
            ) as i64;
            let in_banks =
                cfg.banks_for((p.input_bytes as usize / opt.splits).max(cfg.bus_bytes)) as i64;
            let par_banks = cfg.banks_for(p.param_bytes.max(1) as usize) as i64;
            demand.push(out_banks + in_banks + par_banks, ls[&oid][k]);
            if warm_choice.as_ref().is_some_and(|c| c[t] == k) {
                chosen_demand = out_banks + in_banks + par_banks;
            }
        }
        if let Some(h) = hint.as_mut() {
            // MemTh_t tight at the chosen demand; an over-capacity region
            // makes the hint (and the model) infeasible and the hint is
            // dropped by validation.
            h.push(chosen_demand.min(4 * c_banks));
        }
        // Neighbour overlap: the previous op's output stays live while this
        // op consumes it — included above via input_bytes.
        // Eq. 9: demand ≤ MemTh_t.
        let mut con = demand.clone();
        con.push(-1, memth);
        m.add_le(con, 0);
        // Objective term: MemTh_t − C (only the excess matters, but the
        // constant shift is uniform so plain MemTh_t minimization is
        // equivalent; Eq. 12).
        obj.push(1, memth);
        let _ = graph;
    }
    m.minimize(obj);
    let cfg_with_hint = SearchConfig {
        hint: hint.or_else(|| solver_cfg.hint.clone()),
        ..solver_cfg.clone()
    };
    let sol = crate::cp::solve(&m, cfg_with_hint);
    let mut out = Vec::new();
    if matches!(sol.status, Status::Optimal | Status::Feasible) {
        for &oid in region {
            let vars = &ls[&oid];
            let k = (0..options.len())
                .find(|&k| sol.value(vars[k]) == Ok(1))
                .unwrap_or(0);
            out.push((oid, options[k].splits));
        }
    } else {
        // Budget exhausted without a solution: fall back to max splits.
        for &oid in region {
            out.push((oid, options.last().unwrap().splits));
        }
    }
    (out, sol.stats)
}

/// Per-step bank residency assuming no spills: inputs+params+output of the
/// step plus tiles still awaiting a later consumer.
fn compute_residency(prog: &TiledProgram) -> Vec<usize> {
    // Last step using each tile.
    let mut last_use: HashMap<TileId, usize> = HashMap::new();
    for (si, s) in prog.steps.iter().enumerate() {
        last_use.insert(s.out_tile, si);
        for &t in &s.in_tiles {
            last_use.insert(t, si);
        }
        if let Some(pt) = s.param_tile {
            last_use.insert(pt, si);
        }
    }
    let mut first_use: HashMap<TileId, usize> = HashMap::new();
    for (si, s) in prog.steps.iter().enumerate().rev() {
        first_use.insert(s.out_tile, si);
        for &t in &s.in_tiles {
            first_use.insert(t, si);
        }
        if let Some(pt) = s.param_tile {
            first_use.insert(pt, si);
        }
    }
    (0..prog.steps.len())
        .map(|si| {
            prog.tiles
                .iter()
                .filter(|t| {
                    first_use.get(&t.id).is_some_and(|&f| f <= si)
                        && last_use.get(&t.id).is_some_and(|&l| l >= si)
                })
                .map(|t| t.banks)
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::format::select_formats;
    use crate::zoo;

    fn tile_model(g: &Graph) -> TiledProgram {
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(g, &cfg);
        tile_graph(g, &plan, &cfg, &TilingOptions::default())
    }

    #[test]
    fn small_model_stays_untiled_where_it_fits() {
        let g = zoo::mobilenet::mobilenet_v2();
        let prog = tile_model(&g);
        assert!(!prog.steps.is_empty());
        // Late layers (7×7 maps) must be single-tile.
        let last = prog.steps.last().unwrap();
        let t = prog.tile(last.out_tile);
        assert_eq!(t.part.1, 1, "classifier output should be untiled");
    }

    #[test]
    fn high_resolution_model_gets_tiled() {
        let g = zoo::yolo::yolov8n_det();
        let prog = tile_model(&g);
        let tiled = prog.tiles.iter().filter(|t| t.part.1 > 1).count();
        assert!(tiled > 0, "YOLOv8 @640 must be temporally tiled");
    }

    #[test]
    fn every_step_has_resident_inputs_already_produced() {
        let g = zoo::mobilenet::mobilenet_v1();
        let prog = tile_model(&g);
        let mut produced: Vec<bool> = vec![false; prog.tiles.len()];
        for t in &prog.tiles {
            if t.starts_in_dram {
                produced[t.id.index()] = true;
            }
        }
        for s in &prog.steps {
            for &t in &s.in_tiles {
                assert!(produced[t.index()], "step {:?} uses unproduced tile", s.op);
            }
            produced[s.out_tile.index()] = true;
        }
    }

    #[test]
    fn fusion_interleaves_tiles_in_hot_regions() {
        let g = zoo::yolo::yolov8n_det();
        let prog = tile_model(&g);
        // Find two consecutive steps from different ops with the same
        // part index > context — evidence of interleaving.
        let interleaved = prog.steps.windows(2).any(|w| {
            w[0].op != w[1].op
                && prog.tile(w[0].out_tile).part.1 > 1
                && prog.tile(w[1].out_tile).part.1 > 1
                && prog.tile(w[0].out_tile).part.0 == prog.tile(w[1].out_tile).part.0
        });
        assert!(interleaved, "fused regions should interleave layer tiles");
    }

    #[test]
    fn uniform_calibration_scales_step_cycles_exactly() {
        use crate::compiler::cost::{CostCalibration, CostModel};
        use crate::ir::OpClass;
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(&g, &cfg);
        // Node-limited solving so both runs make identical CP decisions.
        let solver = SearchConfig {
            node_limit: Some(200_000),
            time_limit_ms: None,
            ..Default::default()
        };
        let opts = TilingOptions { partition: true, solver, ..Default::default() };
        let raw = tile_graph(&g, &plan, &cfg, &opts);
        // Scale every class by the same factor: the format plan and the
        // tiling structure (splits depend only on bytes) are unchanged,
        // so each step's cycle estimate doubles exactly.
        let cal = CostCalibration::from_scales(
            &OpClass::all().map(|c| (c, 2.0)),
        );
        let scaled = tile_graph_with(&g, &plan, &CostModel::new(&cfg, cal), &opts);
        assert_eq!(raw.steps.len(), scaled.steps.len());
        for (a, b) in raw.steps.iter().zip(&scaled.steps) {
            assert_eq!((a.op, a.out_tile), (b.op, b.out_tile));
            assert_eq!(b.cycles, 2 * a.cycles, "op {:?}", a.op);
        }
        assert_eq!(scaled.total_compute_cycles(), 2 * raw.total_compute_cycles());
    }

    #[test]
    fn residency_computed_for_every_step() {
        let g = zoo::mobilenet::mobilenet_v2();
        let prog = tile_model(&g);
        assert_eq!(prog.residency_banks.len(), prog.steps.len());
        assert!(prog.residency_banks.iter().all(|&b| b > 0));
    }
}
