//! Layer-level latency estimation used by format selection and the
//! scheduling objective.
//!
//! Bridges the IR to the architecture cycle model: for an op (or an H-tile
//! of an op) under a given spatial format, estimate compute cycles, the
//! DMA cost of its operand/result movement, and the pre-compute TCM-to-TCM
//! copies line parallelism needs (Sec. IV-A).

use crate::arch::{compute_cycles, ComputeCost, Format, JobGeometry, NeutronConfig, Transfer, TransferKind};
use crate::ir::{Graph, Op, OpClass, OpKind};

/// Static per-op facts the compiler passes share.
#[derive(Debug, Clone)]
pub struct OpProfile {
    /// Output geometry (full layer, before temporal tiling).
    pub out_h: usize,
    pub out_w: usize,
    pub out_c: usize,
    pub in_c: usize,
    /// Filter height (drives line-parallel halos).
    pub filter_h: usize,
    pub stride_h: usize,
    /// Parameter bytes (weights + bias) fetched from DRAM.
    pub param_bytes: u64,
    /// Input activation bytes (sum over activation inputs, padded C).
    pub input_bytes: u64,
    /// Output activation bytes (padded C).
    pub output_bytes: u64,
    /// Runs on the dot-product array (vs pure data movement).
    pub is_compute: bool,
    pub depthwise: bool,
}

impl OpProfile {
    /// Extract from the graph.
    pub fn of(graph: &Graph, op: &Op, cfg: &NeutronConfig) -> Self {
        let out = graph.tensor(op.output);
        let (out_h, out_w, out_c) = (out.shape.h(), out.shape.w(), out.shape.c());
        let in_c = op
            .inputs
            .first()
            .map(|&t| graph.tensor(t).shape.c())
            .unwrap_or(1);
        let (filter_h, stride_h) = match &op.kind {
            OpKind::Conv2d { geom, .. } | OpKind::DepthwiseConv2d { geom } => {
                (geom.filter_h, geom.stride_h)
            }
            OpKind::Pool { size, stride, .. } => (*size, *stride),
            _ => (1, 1),
        };
        let param_bytes = op
            .params
            .map(|p| graph.tensor(p).size_bytes() as u64)
            .unwrap_or(0);
        let input_bytes: u64 = op
            .inputs
            .iter()
            .map(|&t| graph.tensor(t).padded_size_bytes(cfg.bus_bytes) as u64)
            .sum();
        let output_bytes = out.padded_size_bytes(cfg.bus_bytes) as u64;
        Self {
            out_h,
            out_w,
            out_c,
            in_c,
            filter_h,
            stride_h,
            param_bytes,
            input_bytes,
            output_bytes,
            is_compute: op.is_compute(),
            depthwise: op.is_depthwise_style(),
        }
    }

    /// Compute-job cost of an H-slice of this op (`rows` output rows) under
    /// `format`, lockstepped across all cores.
    pub fn tile_compute_cost(
        &self,
        graph_op: &Op,
        rows: usize,
        cfg: &NeutronConfig,
        format: Format,
    ) -> ComputeCost {
        let geom = JobGeometry::from_op(graph_op, rows, self.out_w, self.out_c, self.in_c);
        compute_cycles(cfg, &geom, format, cfg.cores)
    }

    /// Bytes of the pre-compute TCM-to-TCM halo copy line parallelism
    /// requires when the kernel height exceeds one (Sec. IV-A): the input
    /// windows of adjacent engines overlap by `filter_h - 1` rows, and the
    /// overlapping rows must be duplicated into each engine's banks.
    pub fn line_halo_bytes(&self, rows: usize, cfg: &NeutronConfig) -> u64 {
        if self.filter_h <= 1 {
            return 0;
        }
        let halo_rows = (self.filter_h - 1) * (cfg.cores - 1);
        let row_bytes = self.out_w * self.in_c.max(1);
        (halo_rows.min(rows * self.stride_h) * row_bytes) as u64
    }

    /// DMA transfer for fetching this op's parameters.
    pub fn param_fetch(&self) -> Transfer {
        Transfer::new(TransferKind::Fetch, self.param_bytes)
    }
}

/// Latency estimate for a whole layer executed in isolation: compute plus
/// exposed parameter fetch (inputs assumed resident — the scheduler refines
/// this; format selection only needs a consistent relative measure).
pub fn layer_latency_cycles(
    graph: &Graph,
    op: &Op,
    cfg: &NeutronConfig,
    format: Format,
) -> u64 {
    let p = OpProfile::of(graph, op, cfg);
    if !p.is_compute {
        // Pure data movement: TCM-to-TCM rearrangement cost.
        return Transfer::new(TransferKind::LCopy, p.output_bytes).cycles(cfg);
    }
    let compute = p.tile_compute_cost(op, p.out_h, cfg, format).total();
    let halo = if format == Format::Line {
        Transfer::new(TransferKind::LCopy, p.line_halo_bytes(p.out_h, cfg)).cycles(cfg)
    } else {
        0
    };
    compute + halo
}

/// Per-op-class linear correction of the analytic cost model, fitted by
/// the calibration pass (`trace/validate.rs`) from predicted-vs-observed
/// per-op cycles. A class's corrected estimate is `scale · predicted`;
/// [`CostCalibration::identity`] leaves every class untouched, so carrying
/// a calibration is always optional.
#[derive(Debug, Clone, PartialEq)]
pub struct CostCalibration {
    scales: Vec<(OpClass, f64)>,
}

impl Default for CostCalibration {
    fn default() -> Self {
        Self::identity()
    }
}

impl CostCalibration {
    /// The no-op calibration: every class scale is 1.0.
    pub fn identity() -> Self {
        Self { scales: Vec::new() }
    }

    /// Build from explicit `(class, scale)` pairs (later entries win).
    /// Non-finite or non-positive scales are rejected: a degenerate fit
    /// must never silently zero out a cost estimate.
    pub fn from_scales(scales: &[(OpClass, f64)]) -> Self {
        for &(class, s) in scales {
            assert!(
                s.is_finite() && s > 0.0,
                "calibration scale for {class:?} must be finite and positive, got {s}"
            );
        }
        Self { scales: scales.to_vec() }
    }

    /// Correction factor for one class (1.0 when unfitted).
    pub fn scale_for(&self, class: OpClass) -> f64 {
        self.scales
            .iter()
            .rev()
            .find(|(c, _)| *c == class)
            .map(|&(_, s)| s)
            .unwrap_or(1.0)
    }

    /// Apply the class correction to a predicted cycle count (rounded to
    /// the nearest cycle, floored at 1 for non-zero predictions so a
    /// correction can never erase an op entirely).
    pub fn apply(&self, class: OpClass, predicted_cycles: u64) -> u64 {
        if predicted_cycles == 0 {
            return 0;
        }
        let corrected = (predicted_cycles as f64 * self.scale_for(class)).round() as u64;
        corrected.max(1)
    }

    /// True when no class carries a correction.
    pub fn is_identity(&self) -> bool {
        self.scales.is_empty()
    }

    /// The fitted `(class, scale)` pairs, in insertion order.
    pub fn scales(&self) -> &[(OpClass, f64)] {
        &self.scales
    }
}

/// [`layer_latency_cycles`] with the per-op-class calibration applied —
/// the opt-in corrected cost model.
pub fn calibrated_layer_latency_cycles(
    graph: &Graph,
    op: &Op,
    cfg: &NeutronConfig,
    format: Format,
    calibration: &CostCalibration,
) -> u64 {
    calibration.apply(op.class(), layer_latency_cycles(graph, op, cfg, format))
}

/// Cost of switching the stored format of a tensor between two ops (the
/// "extra operators in the library" for format conversion, Sec. IV-A): a
/// full TCM-to-TCM rewrite of the tensor.
pub fn format_switch_cycles(bytes: u64, cfg: &NeutronConfig) -> u64 {
    Transfer::new(TransferKind::LCopy, bytes).cycles(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Activation, ConvGeometry, GraphBuilder, Padding};

    fn graph_with_conv(h: usize, c_in: usize, c_out: usize, k: usize) -> Graph {
        let mut b = GraphBuilder::with_input("t", h, h, c_in);
        b.conv("c", c_out, ConvGeometry::square(k, 1, Padding::Same), Activation::Relu);
        b.finish()
    }

    #[test]
    fn profile_extracts_geometry() {
        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let p = OpProfile::of(&g, op, &cfg);
        assert_eq!((p.out_h, p.out_w, p.out_c, p.in_c), (32, 32, 64, 16));
        assert_eq!(p.filter_h, 3);
        assert_eq!(p.param_bytes, 64 * 3 * 3 * 16);
        assert!(p.is_compute);
    }

    #[test]
    fn halo_zero_for_1x1() {
        let g = graph_with_conv(32, 16, 64, 1);
        let cfg = NeutronConfig::flagship_2tops();
        let p = OpProfile::of(&g, &g.ops[0], &cfg);
        assert_eq!(p.line_halo_bytes(32, &cfg), 0);
    }

    #[test]
    fn halo_grows_with_kernel_and_cores() {
        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let p = OpProfile::of(&g, &g.ops[0], &cfg);
        // (3-1)·(4-1) = 6 rows of 32·16 bytes
        assert_eq!(p.line_halo_bytes(32, &cfg), 6 * 32 * 16);
    }

    #[test]
    fn line_beats_depth_for_shallow_wide_layer() {
        // Stem-like layer: 3 input channels, 16 outputs, big resolution.
        let g = graph_with_conv(112, 3, 16, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let line = layer_latency_cycles(&g, op, &cfg, Format::Line);
        let depth = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        assert!(line < depth, "line={line} depth={depth}");
    }

    #[test]
    fn calibration_identity_and_scaling() {
        use crate::ir::OpClass;
        let id = CostCalibration::identity();
        assert!(id.is_identity());
        assert_eq!(id.scale_for(OpClass::Conv), 1.0);
        assert_eq!(id.apply(OpClass::Conv, 1_000), 1_000);
        assert_eq!(id.apply(OpClass::Conv, 0), 0);

        let cal = CostCalibration::from_scales(&[(OpClass::Conv, 1.5), (OpClass::Pool, 0.5)]);
        assert!(!cal.is_identity());
        assert_eq!(cal.apply(OpClass::Conv, 1_000), 1_500);
        assert_eq!(cal.apply(OpClass::Pool, 1_000), 500);
        // Unfitted classes pass through; tiny predictions never vanish.
        assert_eq!(cal.apply(OpClass::Matmul, 777), 777);
        assert_eq!(cal.apply(OpClass::Pool, 1), 1);

        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let raw = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        let corrected = calibrated_layer_latency_cycles(&g, op, &cfg, Format::Depth, &cal);
        assert_eq!(corrected, (raw as f64 * 1.5).round() as u64);
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn degenerate_calibration_scale_is_rejected() {
        CostCalibration::from_scales(&[(crate::ir::OpClass::Conv, 0.0)]);
    }

    #[test]
    fn depth_beats_line_for_deep_narrow_layer() {
        let g = graph_with_conv(7, 512, 512, 1);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let line = layer_latency_cycles(&g, op, &cfg, Format::Line);
        let depth = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        assert!(depth < line, "line={line} depth={depth}");
    }
}
