//! Layer-level latency estimation used by format selection and the
//! scheduling objective.
//!
//! Bridges the IR to the architecture cycle model: for an op (or an H-tile
//! of an op) under a given spatial format, estimate compute cycles, the
//! DMA cost of its operand/result movement, and the pre-compute TCM-to-TCM
//! copies line parallelism needs (Sec. IV-A).

use crate::arch::{compute_cycles, ComputeCost, Format, JobGeometry, NeutronConfig, Transfer, TransferKind};
use crate::ir::{Graph, Op, OpClass, OpKind};

/// Static per-op facts the compiler passes share.
#[derive(Debug, Clone)]
pub struct OpProfile {
    /// Output geometry (full layer, before temporal tiling).
    pub out_h: usize,
    pub out_w: usize,
    pub out_c: usize,
    pub in_c: usize,
    /// Filter height (drives line-parallel halos).
    pub filter_h: usize,
    pub stride_h: usize,
    /// Parameter bytes (weights + bias) fetched from DRAM.
    pub param_bytes: u64,
    /// Input activation bytes (sum over activation inputs, padded C).
    pub input_bytes: u64,
    /// Output activation bytes (padded C).
    pub output_bytes: u64,
    /// Runs on the dot-product array (vs pure data movement).
    pub is_compute: bool,
    pub depthwise: bool,
}

impl OpProfile {
    /// Extract from the graph.
    pub fn of(graph: &Graph, op: &Op, cfg: &NeutronConfig) -> Self {
        let out = graph.tensor(op.output);
        let (out_h, out_w, out_c) = (out.shape.h(), out.shape.w(), out.shape.c());
        let in_c = op
            .inputs
            .first()
            .map(|&t| graph.tensor(t).shape.c())
            .unwrap_or(1);
        let (filter_h, stride_h) = match &op.kind {
            OpKind::Conv2d { geom, .. } | OpKind::DepthwiseConv2d { geom } => {
                (geom.filter_h, geom.stride_h)
            }
            OpKind::Pool { size, stride, .. } => (*size, *stride),
            _ => (1, 1),
        };
        let param_bytes = op
            .params
            .map(|p| graph.tensor(p).size_bytes() as u64)
            .unwrap_or(0);
        let input_bytes: u64 = op
            .inputs
            .iter()
            .map(|&t| graph.tensor(t).padded_size_bytes(cfg.bus_bytes) as u64)
            .sum();
        let output_bytes = out.padded_size_bytes(cfg.bus_bytes) as u64;
        Self {
            out_h,
            out_w,
            out_c,
            in_c,
            filter_h,
            stride_h,
            param_bytes,
            input_bytes,
            output_bytes,
            is_compute: op.is_compute(),
            depthwise: op.is_depthwise_style(),
        }
    }

    /// Compute-job cost of an H-slice of this op (`rows` output rows) under
    /// `format`, lockstepped across all cores.
    pub fn tile_compute_cost(
        &self,
        graph_op: &Op,
        rows: usize,
        cfg: &NeutronConfig,
        format: Format,
    ) -> ComputeCost {
        let geom = JobGeometry::from_op(graph_op, rows, self.out_w, self.out_c, self.in_c);
        compute_cycles(cfg, &geom, format, cfg.cores)
    }

    /// Bytes of the pre-compute TCM-to-TCM halo copy line parallelism
    /// requires when the kernel height exceeds one (Sec. IV-A): the input
    /// windows of adjacent engines overlap by `filter_h - 1` rows, and the
    /// overlapping rows must be duplicated into each engine's banks.
    pub fn line_halo_bytes(&self, rows: usize, cfg: &NeutronConfig) -> u64 {
        if self.filter_h <= 1 {
            return 0;
        }
        let halo_rows = (self.filter_h - 1) * (cfg.cores - 1);
        let row_bytes = self.out_w * self.in_c.max(1);
        (halo_rows.min(rows * self.stride_h) * row_bytes) as u64
    }

    /// DMA transfer for fetching this op's parameters.
    pub fn param_fetch(&self) -> Transfer {
        Transfer::new(TransferKind::Fetch, self.param_bytes)
    }
}

/// Latency estimate for a whole layer executed in isolation: compute plus
/// exposed parameter fetch (inputs assumed resident — the scheduler refines
/// this; format selection only needs a consistent relative measure).
pub fn layer_latency_cycles(
    graph: &Graph,
    op: &Op,
    cfg: &NeutronConfig,
    format: Format,
) -> u64 {
    let p = OpProfile::of(graph, op, cfg);
    if !p.is_compute {
        // Pure data movement: TCM-to-TCM rearrangement cost.
        return Transfer::new(TransferKind::LCopy, p.output_bytes).cycles(cfg);
    }
    let compute = p.tile_compute_cost(op, p.out_h, cfg, format).total();
    let halo = if format == Format::Line {
        Transfer::new(TransferKind::LCopy, p.line_halo_bytes(p.out_h, cfg)).cycles(cfg)
    } else {
        0
    };
    compute + halo
}

/// Per-op-class linear correction of the analytic cost model, fitted by
/// the calibration pass (`trace/validate.rs`) from predicted-vs-observed
/// per-op cycles. A class's corrected estimate is `scale · predicted`;
/// [`CostCalibration::identity`] leaves every class untouched, so carrying
/// a calibration is always optional.
#[derive(Debug, Clone, PartialEq)]
pub struct CostCalibration {
    scales: Vec<(OpClass, f64)>,
}

impl Default for CostCalibration {
    fn default() -> Self {
        Self::identity()
    }
}

impl CostCalibration {
    /// Smallest scale a fit is allowed to carry: a class correction below
    /// this would claim the analytic model over-predicts by more than 4×,
    /// which no healthy trace produces — it is a degenerate fit.
    pub const MIN_SCALE: f64 = 0.25;

    /// Largest scale a fit is allowed to carry (see [`Self::MIN_SCALE`]).
    pub const MAX_SCALE: f64 = 4.0;

    /// Clamp a fitted scale into `[MIN_SCALE, MAX_SCALE]` so a degenerate
    /// trace (a handful of joined ops, pathological DMA exposure) can
    /// never poison compilation with a wild correction.
    pub fn clamp_scale(scale: f64) -> f64 {
        scale.clamp(Self::MIN_SCALE, Self::MAX_SCALE)
    }

    /// The no-op calibration: every class scale is 1.0.
    pub fn identity() -> Self {
        Self { scales: Vec::new() }
    }

    /// Build from explicit `(class, scale)` pairs (later entries win).
    /// Non-finite or non-positive scales are rejected: a degenerate fit
    /// must never silently zero out a cost estimate.
    pub fn from_scales(scales: &[(OpClass, f64)]) -> Self {
        for &(class, s) in scales {
            assert!(
                s.is_finite() && s > 0.0,
                "calibration scale for {class:?} must be finite and positive, got {s}"
            );
        }
        Self { scales: scales.to_vec() }
    }

    /// Correction factor for one class (1.0 when unfitted).
    pub fn scale_for(&self, class: OpClass) -> f64 {
        self.scales
            .iter()
            .rev()
            .find(|(c, _)| *c == class)
            .map(|&(_, s)| s)
            .unwrap_or(1.0)
    }

    /// Apply the class correction to a predicted cycle count (rounded to
    /// the nearest cycle, floored at 1 for non-zero predictions so a
    /// correction can never erase an op entirely). A scale of exactly 1.0
    /// passes the prediction through untouched — never via `f64` — so an
    /// identity calibration is bit-transparent even for cycle counts
    /// beyond `f64`'s integer range.
    pub fn apply(&self, class: OpClass, predicted_cycles: u64) -> u64 {
        if predicted_cycles == 0 {
            return 0;
        }
        let scale = self.scale_for(class);
        if scale == 1.0 {
            return predicted_cycles;
        }
        let corrected = (predicted_cycles as f64 * scale).round() as u64;
        corrected.max(1)
    }

    /// True when no class carries an *effective* correction: no entries,
    /// or every entry's scale is exactly 1.0 (an explicit 1.0 prices
    /// identically to an absent one — see [`CostCalibration::apply`] —
    /// so it must not count as a correction anywhere identity matters,
    /// e.g. the replay faithfulness check).
    pub fn is_identity(&self) -> bool {
        self.scales.iter().all(|&(_, s)| s == 1.0)
    }

    /// The fitted `(class, scale)` pairs, in insertion order.
    pub fn scales(&self) -> &[(OpClass, f64)] {
        &self.scales
    }
}

/// [`layer_latency_cycles`] with the per-op-class calibration applied —
/// the opt-in corrected cost model.
pub fn calibrated_layer_latency_cycles(
    graph: &Graph,
    op: &Op,
    cfg: &NeutronConfig,
    format: Format,
    calibration: &CostCalibration,
) -> u64 {
    calibration.apply(op.class(), layer_latency_cycles(graph, op, cfg, format))
}

/// Cost of switching the stored format of a tensor between two ops (the
/// "extra operators in the library" for format conversion, Sec. IV-A): a
/// full TCM-to-TCM rewrite of the tensor.
pub fn format_switch_cycles(bytes: u64, cfg: &NeutronConfig) -> u64 {
    Transfer::new(TransferKind::LCopy, bytes).cycles(cfg)
}

/// The calibrated cost facade every mid-end pass queries.
///
/// One `CostModel` = one architecture config + one [`CostCalibration`].
/// Format selection, the tiling pass's per-step cycle estimates, the
/// scheduling CP's transfer costs and (through the emitted job cycles)
/// `Compiled::inference_ms`, the simulator's tick timing and the serving
/// layer's `marginal_service_cycles` all derive from queries answered
/// here, so every consumer of a compiled artifact agrees on a single
/// calibrated model. With [`CostModel::uncalibrated`] every query is
/// bit-identical to the raw analytic model.
///
/// What the per-class correction touches: compute-op latencies
/// ([`CostModel::layer_cycles`], [`CostModel::step_cycles`]) and
/// data-movement-op costs ([`CostModel::data_step_cycles`],
/// [`CostModel::format_switch_cycles`] — both are TCM rewrites, scaled
/// under [`OpClass::DataMovement`]). Raw DMA transfer pricing
/// ([`CostModel::transfer_cycles`]) is *not* class-corrected: the
/// calibration classes describe operators, not the DMA engine, and the
/// fit's observations already include exposed transfer time.
#[derive(Debug, Clone)]
pub struct CostModel<'a> {
    cfg: &'a NeutronConfig,
    calibration: CostCalibration,
}

impl<'a> CostModel<'a> {
    /// Facade over `cfg` applying `calibration` to every op-cost query.
    pub fn new(cfg: &'a NeutronConfig, calibration: CostCalibration) -> Self {
        Self { cfg, calibration }
    }

    /// The raw analytic model (identity calibration) — the pre-refactor
    /// behavior, bit for bit.
    pub fn uncalibrated(cfg: &'a NeutronConfig) -> Self {
        Self::new(cfg, CostCalibration::identity())
    }

    /// The architecture config the facade prices against.
    pub fn cfg(&self) -> &NeutronConfig {
        self.cfg
    }

    /// The calibration this facade applies.
    pub fn calibration(&self) -> &CostCalibration {
        &self.calibration
    }

    /// Calibrated whole-layer latency (the format-selection measure).
    pub fn layer_cycles(&self, graph: &Graph, op: &Op, format: Format) -> u64 {
        calibrated_layer_latency_cycles(graph, op, self.cfg, format, &self.calibration)
    }

    /// Calibrated compute cost of one H-tile of `op` (`rows` output rows)
    /// — the tick compute latency the scheduler optimizes against.
    pub fn step_cycles(&self, op: &Op, profile: &OpProfile, rows: usize, format: Format) -> u64 {
        self.calibration
            .apply(op.class(), profile.tile_compute_cost(op, rows, self.cfg, format).total())
    }

    /// Calibrated cost of a pure-data-movement step (`op` is not a
    /// compute op; the step rewrites `bytes` TCM-to-TCM).
    pub fn data_step_cycles(&self, op: &Op, bytes: u64) -> u64 {
        self.calibration
            .apply(op.class(), Transfer::new(TransferKind::LCopy, bytes).cycles(self.cfg))
    }

    /// Calibrated format-conversion cost (scaled as data movement — the
    /// conversion is a full TCM rewrite, the same work the
    /// [`OpClass::DataMovement`] fit observes).
    pub fn format_switch_cycles(&self, bytes: u64) -> u64 {
        self.calibration
            .apply(OpClass::DataMovement, format_switch_cycles(bytes, self.cfg))
    }

    /// Raw DMA transfer pricing (never class-corrected — see the type
    /// docs).
    pub fn transfer_cycles(&self, kind: TransferKind, bytes: u64) -> u64 {
        Transfer::new(kind, bytes).cycles(self.cfg)
    }

    /// Warm-vs-cold dispatch price of a compiled artifact — see
    /// [`dispatch_cost`]. Exposed on the facade so schedulers price warm
    /// placement with the same calibrated model that priced the compile
    /// (the artifact's tick cycles already carry its calibration).
    pub fn dispatch_cost(&self, compiled: &crate::compiler::Compiled) -> DispatchCost {
        dispatch_cost(compiled)
    }

    /// Predicted decode-step cost at a KV length, through a fitted
    /// [`ContextCurve`]. On the facade so consumers price context-length
    /// scaling with the same object compilation uses.
    pub fn decode_step_cycles(&self, curve: &ContextCurve, kv_len: u32) -> u64 {
        curve.step_cycles(kv_len)
    }
}

/// Context-length cost curve of a causal-attention decode step:
/// `cycles(kv) ≈ base_cycles + cycles_per_kv · kv`. The attention GEMMs
/// and the streamed KV cache scale linearly with context rows while the
/// weight GEMMs are context-independent, so a two-parameter affine curve
/// captures the regime (arxiv 2509.25155) that a static per-class scale
/// cannot: the *same* op class costs more at longer context.
///
/// Fitted from per-bucket `(kv_len, observed step cycles)` samples by
/// [`ContextCurve::fit`] (ordinary least squares); degenerate sample sets
/// (fewer than two distinct KV lengths, non-finite or negative slope)
/// yield `None` so a broken trace can never hand serving a wild curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContextCurve {
    /// Context-independent cycles per step (weight GEMMs, overheads).
    pub base_cycles: f64,
    /// Additional cycles per KV-cache row (attention + streaming).
    pub cycles_per_kv: f64,
}

impl ContextCurve {
    /// Predicted step cycles at `kv_len` context rows (≥ 1 cycle; the
    /// line is clamped at zero before rounding so an extrapolation below
    /// the fit range cannot go negative).
    pub fn step_cycles(&self, kv_len: u32) -> u64 {
        let y = self.base_cycles + self.cycles_per_kv * kv_len as f64;
        y.max(0.0).round().max(1.0) as u64
    }

    /// Ordinary least-squares fit of `cycles ≈ base + slope · kv` over
    /// `(kv_len, cycles)` samples. Returns `None` for degenerate inputs:
    /// fewer than two samples with distinct KV lengths, or a non-finite
    /// or negative fitted slope (a decode step can never get cheaper with
    /// more context under the DAE model — such a fit means the samples
    /// are corrupt, not that the curve slopes down).
    pub fn fit(samples: &[(u32, u64)]) -> Option<ContextCurve> {
        let n = samples.len() as f64;
        if samples.len() < 2 {
            return None;
        }
        let first = samples[0].0;
        if samples.iter().all(|&(kv, _)| kv == first) {
            return None;
        }
        let sx: f64 = samples.iter().map(|&(kv, _)| kv as f64).sum();
        let sy: f64 = samples.iter().map(|&(_, c)| c as f64).sum();
        let sxx: f64 = samples.iter().map(|&(kv, _)| (kv as f64) * (kv as f64)).sum();
        let sxy: f64 = samples.iter().map(|&(kv, c)| kv as f64 * c as f64).sum();
        let denom = n * sxx - sx * sx;
        let slope = (n * sxy - sx * sy) / denom;
        let base = (sy - slope * sx) / n;
        if !(slope.is_finite() && base.is_finite()) || slope < 0.0 {
            return None;
        }
        Some(ContextCurve { base_cycles: base, cycles_per_kv: slope })
    }

    /// Mean absolute percentage error of this curve over samples (the
    /// same scoring rule as the per-class calibration MAPE; zero-cycle
    /// samples are skipped).
    pub fn mape_pct(&self, samples: &[(u32, u64)]) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for &(kv, obs) in samples {
            if obs == 0 {
                continue;
            }
            sum += (self.step_cycles(kv) as f64 - obs as f64).abs() / obs as f64;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64 * 100.0
        }
    }
}

/// Warm-vs-cold dispatch price of one compiled artifact under the DAE
/// tick model.
///
/// `cold_cycles` is the ordinary service time (every transfer issues);
/// `warm_cycles` is the service time when every *parameter* fetch is
/// elided because the tiles are already resident in TCM — the same
/// filtered pricing `JobProgram::service_cycles_where` applies at
/// execution time, so the scheduler's "warm on instance 2 vs cold on
/// instance 0" comparison and the executor's clock can never disagree.
/// `param_fetch_cycles`/`param_bytes` total the elidable fetch transfers
/// themselves (what a residency install must move, and what a hit saves
/// on the DDR stream).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchCost {
    /// Service cycles with every DMA transfer issued (cold dispatch).
    pub cold_cycles: u64,
    /// Service cycles with parameter fetches elided (fully-warm dispatch).
    pub warm_cycles: u64,
    /// Total DMA cycles of the elidable parameter-fetch transfers.
    pub param_fetch_cycles: u64,
    /// Total bytes of the elidable parameter-fetch transfers.
    pub param_bytes: u64,
}

impl DispatchCost {
    /// Cycles a fully-warm dispatch saves over a cold one.
    pub fn warm_saving_cycles(&self) -> u64 {
        self.cold_cycles - self.warm_cycles
    }
}

/// Price warm-vs-cold dispatch of `compiled` from its schedule: per tick,
/// compute overlaps the datamover (`max`), and the warm variant drops
/// every transfer of a parameter tile (the tiles named by the compute
/// steps' `param_tile`) — the same rule the serving layer's
/// `marginal_service_cycles` and residency filter apply to the emitted
/// job program. `cold_cycles` equals `Schedule::total_cycles` and the
/// job program's unfiltered service time; `warm_cycles` equals the job
/// program's service time under the param-skipping filter.
pub fn dispatch_cost(compiled: &crate::compiler::Compiled) -> DispatchCost {
    let param_tiles: std::collections::HashSet<crate::compiler::TileId> =
        compiled.program.steps.iter().filter_map(|s| s.param_tile).collect();
    let is_param_fetch =
        |tr: &crate::compiler::ScheduledTransfer| param_tiles.contains(&tr.tile);
    let mut cost = DispatchCost::default();
    for tick in &compiled.schedule.ticks {
        let mut dm_cold = 0u64;
        let mut dm_warm = 0u64;
        for tr in &tick.transfers {
            dm_cold += tr.cycles;
            if is_param_fetch(tr) {
                cost.param_fetch_cycles += tr.cycles;
                cost.param_bytes += tr.bytes;
            } else {
                dm_warm += tr.cycles;
            }
        }
        cost.cold_cycles += tick.compute_cycles.max(dm_cold);
        cost.warm_cycles += tick.compute_cycles.max(dm_warm);
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Activation, ConvGeometry, GraphBuilder, Padding};

    fn graph_with_conv(h: usize, c_in: usize, c_out: usize, k: usize) -> Graph {
        let mut b = GraphBuilder::with_input("t", h, h, c_in);
        b.conv("c", c_out, ConvGeometry::square(k, 1, Padding::Same), Activation::Relu);
        b.finish()
    }

    #[test]
    fn profile_extracts_geometry() {
        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let p = OpProfile::of(&g, op, &cfg);
        assert_eq!((p.out_h, p.out_w, p.out_c, p.in_c), (32, 32, 64, 16));
        assert_eq!(p.filter_h, 3);
        assert_eq!(p.param_bytes, 64 * 3 * 3 * 16);
        assert!(p.is_compute);
    }

    #[test]
    fn halo_zero_for_1x1() {
        let g = graph_with_conv(32, 16, 64, 1);
        let cfg = NeutronConfig::flagship_2tops();
        let p = OpProfile::of(&g, &g.ops[0], &cfg);
        assert_eq!(p.line_halo_bytes(32, &cfg), 0);
    }

    #[test]
    fn halo_grows_with_kernel_and_cores() {
        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let p = OpProfile::of(&g, &g.ops[0], &cfg);
        // (3-1)·(4-1) = 6 rows of 32·16 bytes
        assert_eq!(p.line_halo_bytes(32, &cfg), 6 * 32 * 16);
    }

    #[test]
    fn line_beats_depth_for_shallow_wide_layer() {
        // Stem-like layer: 3 input channels, 16 outputs, big resolution.
        let g = graph_with_conv(112, 3, 16, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let line = layer_latency_cycles(&g, op, &cfg, Format::Line);
        let depth = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        assert!(line < depth, "line={line} depth={depth}");
    }

    #[test]
    fn calibration_identity_and_scaling() {
        use crate::ir::OpClass;
        let id = CostCalibration::identity();
        assert!(id.is_identity());
        assert_eq!(id.scale_for(OpClass::Conv), 1.0);
        assert_eq!(id.apply(OpClass::Conv, 1_000), 1_000);
        assert_eq!(id.apply(OpClass::Conv, 0), 0);

        let cal = CostCalibration::from_scales(&[(OpClass::Conv, 1.5), (OpClass::Pool, 0.5)]);
        assert!(!cal.is_identity());
        assert_eq!(cal.apply(OpClass::Conv, 1_000), 1_500);
        assert_eq!(cal.apply(OpClass::Pool, 1_000), 500);
        // Unfitted classes pass through; tiny predictions never vanish.
        assert_eq!(cal.apply(OpClass::Matmul, 777), 777);
        assert_eq!(cal.apply(OpClass::Pool, 1), 1);

        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let raw = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        let corrected = calibrated_layer_latency_cycles(&g, op, &cfg, Format::Depth, &cal);
        assert_eq!(corrected, (raw as f64 * 1.5).round() as u64);
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn degenerate_calibration_scale_is_rejected() {
        CostCalibration::from_scales(&[(crate::ir::OpClass::Conv, 0.0)]);
    }

    #[test]
    fn scale_clamp_bounds_wild_fits() {
        assert_eq!(CostCalibration::clamp_scale(100.0), CostCalibration::MAX_SCALE);
        assert_eq!(CostCalibration::clamp_scale(0.01), CostCalibration::MIN_SCALE);
        assert_eq!(CostCalibration::clamp_scale(1.3), 1.3);
        // A clamped scale is always accepted by the constructor.
        let _ = CostCalibration::from_scales(&[(
            crate::ir::OpClass::Conv,
            CostCalibration::clamp_scale(f64::MAX),
        )]);
    }

    #[test]
    fn identity_apply_is_bit_transparent_beyond_f64_range() {
        // (1<<60)+1 is not representable in f64; a round-trip through the
        // float path would change it. The identity short-circuit must not.
        let huge = (1u64 << 60) + 1;
        assert_eq!(CostCalibration::identity().apply(OpClass::Conv, huge), huge);
        let explicit = CostCalibration::from_scales(&[(OpClass::Conv, 1.0)]);
        assert_eq!(explicit.apply(OpClass::Conv, huge), huge);
        // An explicit all-1.0 spelling IS the identity (effectively).
        assert!(explicit.is_identity());
        assert!(!CostCalibration::from_scales(&[(OpClass::Conv, 1.5)]).is_identity());
    }

    #[test]
    fn cost_model_facade_matches_free_functions() {
        let g = graph_with_conv(32, 16, 64, 3);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let id = CostModel::uncalibrated(&cfg);
        assert_eq!(
            id.layer_cycles(&g, op, Format::Depth),
            layer_latency_cycles(&g, op, &cfg, Format::Depth)
        );
        assert_eq!(id.format_switch_cycles(4_096), format_switch_cycles(4_096, &cfg));
        assert_eq!(
            id.transfer_cycles(TransferKind::Fetch, 4_096),
            Transfer::new(TransferKind::Fetch, 4_096).cycles(&cfg)
        );
        let p = OpProfile::of(&g, op, &cfg);
        assert_eq!(
            id.step_cycles(op, &p, p.out_h, Format::Depth),
            p.tile_compute_cost(op, p.out_h, &cfg, Format::Depth).total()
        );

        let cal = CostCalibration::from_scales(&[
            (OpClass::Conv, 2.0),
            (OpClass::DataMovement, 2.0),
        ]);
        let cm = CostModel::new(&cfg, cal.clone());
        assert_eq!(
            cm.layer_cycles(&g, op, Format::Depth),
            2 * layer_latency_cycles(&g, op, &cfg, Format::Depth)
        );
        assert_eq!(cm.format_switch_cycles(4_096), 2 * format_switch_cycles(4_096, &cfg));
        // DMA transfer pricing stays uncorrected.
        assert_eq!(
            cm.transfer_cycles(TransferKind::Fetch, 4_096),
            id.transfer_cycles(TransferKind::Fetch, 4_096)
        );
        assert_eq!(cm.calibration(), &cal);
        assert_eq!(cm.cfg().tcm_banks, cfg.tcm_banks);
    }

    #[test]
    fn dispatch_cost_agrees_with_emitted_program() {
        use crate::compiler::{compile, CompileOptions};
        use crate::coordinator::{emit, Job};
        let g = crate::zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let d = dispatch_cost(&c);
        // Cold = the schedule's own latency = the job program's unfiltered
        // service time; warm = the program under the param-skip filter.
        assert_eq!(d.cold_cycles, c.schedule.total_cycles());
        let p = emit(&c, "m");
        assert_eq!(d.cold_cycles, p.service_cycles_where(|_| true));
        let params = p.param_tiles();
        let warm = p.service_cycles_where(|j| {
            !matches!(j, Job::Dma { tile, .. } if params.contains(tile))
        });
        assert_eq!(d.warm_cycles, warm, "compiler warm pricing = program's marginal pricing");
        assert!(d.warm_cycles < d.cold_cycles, "warm dispatch must save cycles");
        assert!(d.param_fetch_cycles > 0);
        assert!(d.param_bytes > 0);
        assert_eq!(d.warm_saving_cycles(), d.cold_cycles - d.warm_cycles);
        // The facade method is the same pricing.
        assert_eq!(CostModel::uncalibrated(&cfg).dispatch_cost(&c), d);
    }

    #[test]
    fn depth_beats_line_for_deep_narrow_layer() {
        let g = graph_with_conv(7, 512, 512, 1);
        let cfg = NeutronConfig::flagship_2tops();
        let op = &g.ops[0];
        let line = layer_latency_cycles(&g, op, &cfg, Format::Line);
        let depth = layer_latency_cycles(&g, op, &cfg, Format::Depth);
        assert!(depth < line, "line={line} depth={depth}");
    }

    #[test]
    fn context_curve_fit_recovers_exact_line() {
        // Samples on cycles = 1000 + 3·kv must fit back exactly.
        let samples: Vec<(u32, u64)> =
            [8u32, 16, 32, 64, 128].iter().map(|&kv| (kv, 1000 + 3 * kv as u64)).collect();
        let curve = ContextCurve::fit(&samples).expect("clean line must fit");
        assert!((curve.base_cycles - 1000.0).abs() < 1e-6, "base={}", curve.base_cycles);
        assert!((curve.cycles_per_kv - 3.0).abs() < 1e-9, "slope={}", curve.cycles_per_kv);
        for &(kv, obs) in &samples {
            assert_eq!(curve.step_cycles(kv), obs);
        }
        assert_eq!(curve.mape_pct(&samples), 0.0);
        // Monotone in kv: more context never predicts cheaper.
        assert!(curve.step_cycles(256) > curve.step_cycles(128));
        // The facade method is the same prediction.
        let cfg = NeutronConfig::flagship_2tops();
        assert_eq!(CostModel::uncalibrated(&cfg).decode_step_cycles(&curve, 64), curve.step_cycles(64));
    }

    #[test]
    fn context_curve_rejects_degenerate_samples() {
        // Under two samples, or all at one KV length: no fit.
        assert!(ContextCurve::fit(&[]).is_none());
        assert!(ContextCurve::fit(&[(16, 500)]).is_none());
        assert!(ContextCurve::fit(&[(16, 500), (16, 700), (16, 900)]).is_none());
        // Negative slope (cheaper at longer context) is corrupt data.
        assert!(ContextCurve::fit(&[(8, 900), (64, 100)]).is_none());
        // Prediction never rounds to zero cycles.
        let flat = ContextCurve { base_cycles: 0.0, cycles_per_kv: 0.0 };
        assert_eq!(flat.step_cycles(0), 1);
    }
}
