//! Format selection (Sec. IV-A): per layer, choose depth parallelism or
//! line parallelism, accounting for the cost of switching formats between
//! consecutive layers.
//!
//! "The compiler chooses the most suitable format for each layer of the NN
//! by estimating execution latencies and taking into account the overhead
//! of switching formats between consecutive layers." — modeled as a
//! shortest-path (Viterbi) pass over the topological layer order: state =
//! stored format of the op's output, edge cost = layer latency under the
//! consumer's format + conversion cost when the producer's stored format
//! differs.

use std::collections::HashMap;

use super::cost::CostModel;
use crate::arch::{Format, NeutronConfig};
use crate::ir::{Graph, OpId, TensorId, TensorKind};

/// Chosen format per op, plus the estimated per-op cycles that drove the
/// choice (reused by scheduling as tick compute latencies).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormatPlan {
    pub per_op: HashMap<OpId, Format>,
    pub est_cycles: HashMap<OpId, u64>,
    /// Ops whose *input* needs a format conversion (producer stored the
    /// other format) — lowered to l-copy jobs by the scheduler.
    pub conversions: Vec<(OpId, TensorId, u64)>,
}

impl FormatPlan {
    pub fn format_of(&self, op: OpId) -> Format {
        self.per_op.get(&op).copied().unwrap_or(Format::Depth)
    }
}

/// Run format selection over the graph under the raw analytic cost model
/// (identity calibration). See [`select_formats_with`].
pub fn select_formats(graph: &Graph, cfg: &NeutronConfig) -> FormatPlan {
    select_formats_with(graph, &CostModel::uncalibrated(cfg))
}

/// Run format selection over the graph, pricing every layer latency and
/// conversion through the calibrated cost facade.
///
/// Dynamic program over topological order. For ops with multiple activation
/// inputs the dominant (first) input's format drives the conversion cost —
/// element-wise ops are format-agnostic as long as both inputs agree, which
/// the plan enforces by converting mismatched secondary inputs too.
pub fn select_formats_with(graph: &Graph, cost: &CostModel) -> FormatPlan {
    let cfg = cost.cfg();
    let order = graph.topo_order();
    // best[op][format] = (cumulative cycles, predecessor format choice)
    let mut best: HashMap<(OpId, Format), (u64, Option<Format>)> = HashMap::new();
    // Stored format of each tensor under a given hypothesis is the format
    // of its producing op; graph inputs/parameters are stored depth-major
    // (HWC fragmented by C), the natural DRAM layout.
    let producer_of: HashMap<TensorId, OpId> =
        graph.ops.iter().map(|o| (o.output, o.id)).collect();

    for &oid in &order {
        let op = graph.op(oid);
        for fmt in [Format::Depth, Format::Line] {
            let own = cost.layer_cycles(graph, op, fmt);
            // Conversion cost: for each activation input whose producer's
            // best stored format differs from `fmt`.
            let mut total_in_cost = 0u64;
            let mut pred_fmt = None;
            for &inp in &op.inputs {
                let t = graph.tensor(inp);
                if t.kind == TensorKind::Parameter {
                    continue;
                }
                match producer_of.get(&inp) {
                    Some(&pid) => {
                        // Choose the producer hypothesis minimizing
                        // cumulative cost + conversion.
                        let bytes = t.padded_size_bytes(cfg.bus_bytes) as u64;
                        let mut best_choice = u64::MAX;
                        for pfmt in [Format::Depth, Format::Line] {
                            if let Some(&(c, _)) = best.get(&(pid, pfmt)) {
                                let conv = if pfmt != fmt && graph.op(pid).is_compute() {
                                    cost.format_switch_cycles(bytes)
                                } else {
                                    0
                                };
                                if c + conv < best_choice {
                                    best_choice = c + conv;
                                    pred_fmt = Some(pfmt);
                                }
                            }
                        }
                        if best_choice != u64::MAX {
                            total_in_cost = total_in_cost.saturating_add(best_choice);
                        }
                    }
                    None => {
                        // Graph input: stored depth-major; converting to
                        // line costs one rewrite.
                        if fmt == Format::Line {
                            let bytes = t.padded_size_bytes(cfg.bus_bytes) as u64;
                            total_in_cost += cost.format_switch_cycles(bytes);
                        }
                    }
                }
            }
            let cum = own + total_in_cost;
            let entry = best.entry((oid, fmt)).or_insert((u64::MAX, None));
            if cum < entry.0 {
                *entry = (cum, pred_fmt);
            }
        }
    }

    // Commit: per op pick the cheaper hypothesis; derive conversions.
    let mut per_op = HashMap::new();
    let mut est_cycles = HashMap::new();
    let mut conversions = Vec::new();
    for &oid in &order {
        let op = graph.op(oid);
        let d = best[&(oid, Format::Depth)].0;
        let l = best[&(oid, Format::Line)].0;
        let fmt = if l < d { Format::Line } else { Format::Depth };
        per_op.insert(oid, fmt);
        est_cycles.insert(oid, cost.layer_cycles(graph, op, fmt));
    }
    // Second sweep: record conversions where committed producer/consumer
    // formats disagree.
    for &oid in &order {
        let op = graph.op(oid);
        let fmt = per_op[&oid];
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if t.kind == TensorKind::Parameter {
                continue;
            }
            if let Some(&pid) = producer_of.get(&inp) {
                if graph.op(pid).is_compute() && per_op[&pid] != fmt {
                    let bytes = t.padded_size_bytes(cfg.bus_bytes) as u64;
                    conversions.push((oid, inp, cost.format_switch_cycles(bytes)));
                }
            }
        }
    }
    FormatPlan { per_op, est_cycles, conversions }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Activation, ConvGeometry, GraphBuilder, Padding};
    use crate::zoo;

    #[test]
    fn stem_layers_get_line_parallelism() {
        // MobileNetV1: the 3-channel stem cannot fill 4 engines by depth.
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(&g, &cfg);
        let stem = g.ops.iter().find(|o| o.name == "stem").unwrap();
        assert_eq!(plan.format_of(stem.id), Format::Line);
    }

    #[test]
    fn deep_tail_layers_get_depth_parallelism() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(&g, &cfg);
        // The 1024-channel pointwise near the end: depth parallelism.
        let tail = g.ops.iter().find(|o| o.name == "b12.pw").unwrap();
        assert_eq!(plan.format_of(tail.id), Format::Depth);
    }

    #[test]
    fn every_compute_op_has_a_format_and_cycles() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(&g, &cfg);
        for op in &g.ops {
            assert!(plan.per_op.contains_key(&op.id), "{} missing", op.name);
            assert!(plan.est_cycles[&op.id] > 0, "{} zero cycles", op.name);
        }
    }

    #[test]
    fn identity_facade_reproduces_the_raw_plan() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let raw = select_formats(&g, &cfg);
        let via_facade = select_formats_with(&g, &CostModel::uncalibrated(&cfg));
        assert_eq!(raw.per_op, via_facade.per_op);
        assert_eq!(raw.est_cycles, via_facade.est_cycles);
        assert_eq!(raw.conversions, via_facade.conversions);
    }

    #[test]
    fn single_layer_graph_picks_cheaper_format() {
        let mut b = GraphBuilder::with_input("one", 64, 64, 3);
        b.conv("c", 8, ConvGeometry::square(3, 1, Padding::Same), Activation::Relu);
        let g = b.finish();
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(&g, &cfg);
        assert_eq!(plan.format_of(g.ops[0].id), Format::Line);
    }
}
