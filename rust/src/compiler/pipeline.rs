//! End-to-end compilation driver: graph → formats → tiles → schedule →
//! allocation → job program, with the compile/inference-time metrics
//! Table II reports.

use std::sync::Arc;
use std::time::Instant;

use super::allocation::{allocate_with_stats, Allocation};
use super::cost::{CostCalibration, CostModel};
use super::format::{select_formats_with, FormatPlan};
use super::scheduling::{schedule_with_stats, Schedule, SchedulingOptions};
use super::tiling::{tile_graph_with_stats, TiledProgram, TilingOptions};
use crate::arch::NeutronConfig;
use crate::cp::{SearchConfig, SolveStats};
use crate::ir::Graph;

/// Compilation options — the Table II matrix is spanned by the two
/// partitioning switches; `calibration` selects the cost model every pass
/// prices against (identity by default, i.e. the raw analytic model).
#[derive(Debug, Clone, Default)]
pub struct CompileOptions {
    pub tiling: TilingOptions,
    pub scheduling: SchedulingOptions,
    pub allocation_solver: SearchConfig,
    /// Per-op-class cost corrections applied by every mid-end cost query
    /// (see [`CostModel`]). [`CostCalibration::identity`] — the default —
    /// reproduces the uncalibrated compiler bit for bit.
    pub calibration: CostCalibration,
    /// Warm start: a prior [`Compiled`] of the same graph (typically the
    /// nearest cached `(config, calibration)` neighbor). Each CP pass
    /// seeds its anytime search with the prior solution as the initial
    /// incumbent — tiling from the prior split counts, scheduling from
    /// the prior transfer placements, allocation from the prior bank
    /// starts — so a budget-limited recompile can only match or improve
    /// on the neighbor. Structurally stale seeds fail the solver's hint
    /// validation and each pass degrades to a cold solve.
    pub warm_start: Option<Arc<Compiled>>,
}

impl CompileOptions {
    /// Both partitionings on (production default, "Both" row).
    pub fn default_partitioned() -> Self {
        Self::default()
    }

    /// Solver budget for monolithic CPs: the whole-network problem gets a
    /// much larger budget, mirroring the paper's 3480-s "no partitioning"
    /// compile (our B&B at this budget still may not close the gap a
    /// commercial CP solver would — see EXPERIMENTS.md Table II notes).
    fn monolithic_solver() -> SearchConfig {
        SearchConfig { time_limit_ms: Some(20_000), ..Default::default() }
    }

    /// "No partitioning" row: monolithic optimization + scheduling CPs.
    pub fn monolithic() -> Self {
        Self {
            tiling: TilingOptions {
                partition: false,
                solver: Self::monolithic_solver(),
                ..Default::default()
            },
            scheduling: SchedulingOptions {
                partition: false,
                solver: Self::monolithic_solver(),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// "Only optimizations" row: tiling/fusion partitioned, scheduling not.
    pub fn partition_optimizations_only() -> Self {
        Self {
            tiling: TilingOptions { partition: true, ..Default::default() },
            scheduling: SchedulingOptions {
                partition: false,
                solver: Self::monolithic_solver(),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// "Only scheduling" row.
    pub fn partition_scheduling_only() -> Self {
        Self {
            tiling: TilingOptions {
                partition: false,
                solver: Self::monolithic_solver(),
                ..Default::default()
            },
            scheduling: SchedulingOptions { partition: true, ..Default::default() },
            ..Default::default()
        }
    }
}

/// Compiled artifact: everything the coordinator/simulator needs.
#[derive(Debug, Clone, PartialEq)]
pub struct Compiled {
    pub formats: FormatPlan,
    pub program: TiledProgram,
    pub schedule: Schedule,
    pub allocation: Allocation,
    /// Wall-clock compilation time (ms) — Table II's x-axis.
    pub compile_ms: u64,
    /// Estimated end-to-end inference latency (ms) on the target config.
    pub inference_ms: f64,
    /// The calibration this artifact was priced under — consumers joining
    /// predictions against observations (the trace recorder) must predict
    /// with the same corrections the compiler used.
    pub calibration: CostCalibration,
}

impl Compiled {
    /// Latency·TOPS product (Eq. 13) on `cfg`.
    pub fn ltp(&self, cfg: &NeutronConfig) -> f64 {
        self.inference_ms * cfg.peak_tops()
    }

    /// Effective TOPS: executed ops / latency (Table I's metric).
    pub fn effective_tops(&self, graph: &Graph) -> f64 {
        let ops = 2.0 * graph.total_macs() as f64;
        ops / (self.inference_ms * 1e-3) / 1e12
    }
}

/// Compile `graph` for `cfg`. Every pass prices through one calibrated
/// cost facade built from `opts.calibration`, so the CP objectives, the
/// emitted job cycles and `Compiled::inference_ms` agree on a single cost
/// model.
pub fn compile(graph: &Graph, cfg: &NeutronConfig, opts: &CompileOptions) -> Compiled {
    compile_with_stats(graph, cfg, opts).0
}

/// Like [`compile`], additionally returning the [`SolveStats`] merged over
/// every CP solve of the three mid-end passes (tiling regions, scheduling
/// windows, allocation clusters). The stats are pure telemetry: they are
/// not part of [`Compiled`], are never persisted into `.npu` artifacts,
/// and have no bearing on plan equality — the `neutron compile` verbose
/// output and the solver benches consume them.
pub fn compile_with_stats(
    graph: &Graph,
    cfg: &NeutronConfig,
    opts: &CompileOptions,
) -> (Compiled, SolveStats) {
    let t0 = Instant::now();
    let cost = CostModel::new(cfg, opts.calibration.clone());
    let formats = select_formats_with(graph, &cost);

    // Warm start: derive per-pass seeds from the prior artifact. Each seed
    // is validated against the fresh CP before adoption, so a neighbor
    // whose structure no longer matches costs nothing.
    let mut tiling = opts.tiling.clone();
    let mut scheduling = opts.scheduling.clone();
    if let Some(prev) = &opts.warm_start {
        if tiling.warm_splits.is_none() {
            let mut splits = std::collections::HashMap::new();
            for s in &prev.program.steps {
                splits.insert(s.op, prev.program.tile(s.out_tile).part.1);
            }
            tiling.warm_splits = Some(splits);
        }
        if scheduling.warm.is_none() {
            scheduling.warm = Some(Arc::new(prev.schedule.clone()));
        }
    }

    let mut stats = SolveStats::default();
    let (program, tile_stats) = tile_graph_with_stats(graph, &formats, &cost, &tiling);
    stats.merge(&tile_stats);
    let (sched, sched_stats) = schedule_with_stats(&program, &cost, &scheduling);
    stats.merge(&sched_stats);
    let (allocation, alloc_stats) = allocate_with_stats(
        &program,
        &sched,
        cfg,
        &opts.allocation_solver,
        opts.warm_start.as_ref().map(|p| &p.allocation),
    );
    stats.merge(&alloc_stats);
    let compile_ms = t0.elapsed().as_millis() as u64;
    let inference_ms = cfg.cycles_to_ms(sched.total_cycles());
    let compiled = Compiled {
        formats,
        program,
        schedule: sched,
        allocation,
        compile_ms,
        inference_ms,
        calibration: opts.calibration.clone(),
    };
    (compiled, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::{self, ModelId};

    #[test]
    fn compiles_mobilenet_v2_end_to_end() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        assert!(c.inference_ms > 0.0);
        assert!(c.inference_ms < 100.0, "V2 should be ~1 ms, got {}", c.inference_ms);
        assert!(!c.allocation.placements.is_empty());
    }

    #[test]
    fn effective_tops_below_peak() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let eff = c.effective_tops(&g);
        assert!(eff > 0.0 && eff <= cfg.peak_tops(), "eff={eff}");
    }

    #[test]
    fn ltp_scales_with_tops() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        assert!((c.ltp(&cfg) - c.inference_ms * cfg.peak_tops()).abs() < 1e-9);
    }

    #[test]
    fn all_models_compile() {
        let cfg = NeutronConfig::flagship_2tops();
        for id in [ModelId::MobileNetV3Min, ModelId::EfficientNetLite0, ModelId::ResNet50V1] {
            let g = id.build();
            let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
            assert!(c.inference_ms > 0.0, "{id:?}");
        }
    }
}
