//! DAE scheduling (Sec. IV-B): convert the tiled program into a sequence of
//! timed ticks, each hosting at most one compute job and any number of
//! datamover jobs, minimizing `δ·N_DM + Σ_t max(l_DM(t), l_C(t))` (Eq. 8).
//!
//! Faithful to the paper's split of concerns: the tile computation *order*
//! comes from the tiling/fusion pass; scheduling optimizes **memory latency
//! hiding** under the platform constraints. Tick `t` hosts compute step `t`
//! (the paper's model admits empty timesteps but eliminates them after
//! solving, which collapses to this). The CP decides *when*, within a
//! bounded lookahead window, each data transfer runs:
//!
//!   * persistency/dependency (Eq. 1–2) are enforced by construction: a
//!     fetch candidate range ends strictly before the consuming tick, and a
//!     residency expression `Σ_{t'≤t} fetch(τ,t')` feeds the capacity
//!     constraint;
//!   * bus-conflict constraints (Eq. 3) remove candidate ticks where the
//!     transferred tile shares banks (same tensor) with a tile the compute
//!     unit touches;
//!   * memory constraints (Eq. 7) bound resident banks per tick by C;
//!   * spills are decided by a Belady-style pre-pass (farthest next use)
//!     and their *placement* is optimized by the CP — partitioned solving
//!     loses exactly the cross-window spill freedom the paper describes as
//!     the partitioning trade-off (Table II).

use std::collections::HashMap;

use super::cost::CostModel;
use super::tiling::{TiledProgram, TileId};
use crate::arch::{DdrTraffic, NeutronConfig, Transfer, TransferKind};
use crate::cp::{CpModel, LinExpr, SearchConfig, SolveStats, Status, Var};

/// A scheduled data transfer inside a tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledTransfer {
    pub tile: TileId,
    pub kind: TransferKind,
    pub cycles: u64,
    pub bytes: u64,
}

/// One tick: ≤1 compute job + concurrent datamover jobs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Tick {
    /// Index into `TiledProgram::steps`.
    pub compute: Option<usize>,
    pub transfers: Vec<ScheduledTransfer>,
    pub compute_cycles: u64,
    pub dm_cycles: u64,
}

impl Tick {
    /// Tick latency: compute and datamover run concurrently (DAE).
    pub fn latency(&self) -> u64 {
        self.compute_cycles.max(self.dm_cycles)
    }
}

/// The schedule: ticks + aggregate statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Schedule {
    pub ticks: Vec<Tick>,
    pub ddr: DdrTraffic,
    /// Total CP solve wall time (compilation-time metric of Table II).
    pub solve_ms: u64,
    /// Number of CP subproblems solved.
    pub subproblems: usize,
    /// Total decision variables across subproblems.
    pub variables: usize,
}

impl Schedule {
    /// End-to-end latency in cycles (Σ_t max(l_DM, l_C)).
    pub fn total_cycles(&self) -> u64 {
        self.ticks.iter().map(|t| t.latency()).sum()
    }

    /// Latency with NO latency hiding (monolithic pipeline of Fig. 4:
    /// every tick serializes datamover after compute) — the Fig. 4
    /// comparison baseline.
    pub fn serialized_cycles(&self) -> u64 {
        self.ticks.iter().map(|t| t.compute_cycles + t.dm_cycles).sum()
    }
}

/// Scheduling options (Table II knobs).
#[derive(Debug, Clone)]
pub struct SchedulingOptions {
    /// Partition into fixed-size windows (on) vs one monolithic CP (off).
    pub partition: bool,
    /// Steps per window when partitioned.
    pub window: usize,
    /// δ: penalty per datamover op in the objective (Eq. 8).
    pub delta: u64,
    /// Lookahead ticks for transfer placement when partitioned (the
    /// monolithic problem gets double — the "complete view" of the paper).
    pub lookahead: usize,
    pub solver: SearchConfig,
    /// Warm start: a prior [`Schedule`] of the same tiled program (from a
    /// compile-cache neighbor). Each window CP seeds transfer placements
    /// from where the prior schedule put them, overriding the greedy hint
    /// where applicable; the solver validates the combined hint, so a
    /// structurally stale schedule degrades to the greedy cold start.
    pub warm: Option<std::sync::Arc<Schedule>>,
}

impl Default for SchedulingOptions {
    fn default() -> Self {
        Self {
            partition: true,
            window: 16,
            delta: 8,
            lookahead: 5,
            solver: SearchConfig { time_limit_ms: Some(2_000), ..Default::default() },
            warm: None,
        }
    }
}

/// A transfer that must be placed in some tick.
#[derive(Debug, Clone)]
struct Candidate {
    tile: TileId,
    kind: TransferKind,
    cycles: u64,
    bytes: u64,
    banks: usize,
    /// Inclusive tick range the transfer may occupy.
    range: (usize, usize),
    /// While un-issued the tile is resident (push) or not (fetch): fetch
    /// transfers ADD residency from their tick on; pushes REMOVE it after.
    adds_residency: bool,
}

/// Spill pre-pass + transfer enumeration + per-window CP solve under the
/// raw analytic cost model (identity calibration). See [`schedule_with`].
pub fn schedule(prog: &TiledProgram, cfg: &NeutronConfig, opts: &SchedulingOptions) -> Schedule {
    schedule_with(prog, &CostModel::uncalibrated(cfg), opts)
}

/// Spill pre-pass + transfer enumeration + per-window CP solve, pricing
/// every transfer through the calibrated cost facade (transfer pricing is
/// never class-corrected — see [`CostModel`] — but routing it through the
/// facade keeps one source of truth; the tick *compute* latencies arrive
/// already calibrated in `prog.steps[..].cycles`).
pub fn schedule_with(prog: &TiledProgram, cost: &CostModel, opts: &SchedulingOptions) -> Schedule {
    schedule_with_stats(prog, cost, opts).0
}

/// Like [`schedule_with`], additionally returning the merged [`SolveStats`]
/// of every window CP solve (propagation-engine telemetry — never part of
/// the schedule itself, so artifact bytes and plan equality are unaffected).
pub fn schedule_with_stats(
    prog: &TiledProgram,
    cost: &CostModel,
    opts: &SchedulingOptions,
) -> (Schedule, SolveStats) {
    let cfg = cost.cfg();
    let n = prog.steps.len();
    if n == 0 {
        return (Schedule::default(), SolveStats::default());
    }

    // --- Liveness ---
    let mut first_use: HashMap<TileId, usize> = HashMap::new();
    let mut last_use: HashMap<TileId, usize> = HashMap::new();
    let mut produced_at: HashMap<TileId, usize> = HashMap::new();
    for (si, s) in prog.steps.iter().enumerate() {
        produced_at.insert(s.out_tile, si);
        for t in s.in_tiles.iter().chain(s.param_tile.iter()) {
            first_use.entry(*t).or_insert(si);
            last_use.insert(*t, si);
        }
        last_use.entry(s.out_tile).or_insert(si);
        first_use.entry(s.out_tile).or_insert(si);
    }

    // --- Tick layout: tick 0 is a pure-datamover preamble (initial
    // fetches); compute step `si` runs at tick `si + 1`. ---
    let n_ticks = n + 1;
    let tick_of = |si: usize| si + 1;

    // --- Mandatory transfers ---
    let mut candidates: Vec<Candidate> = Vec::new();
    // Partitioned windows see a short placement horizon; the monolithic
    // problem gives every transfer (nearly) the full horizon — this is
    // exactly the quadratic tiles×timesteps variable growth the paper
    // describes (Sec. IV-B "Scalability"), and why unpartitioned compiles
    // are orders of magnitude slower (Table II).
    let look = if opts.partition { opts.lookahead } else { opts.lookahead.max(32) };
    let mut add_fetch = |cands: &mut Vec<Candidate>, tile: TileId, use_tick: usize, kind: TransferKind| {
        let tl = prog.tile(tile);
        let hi = use_tick.saturating_sub(1);
        let lo = use_tick.saturating_sub(look).min(hi);
        // §Perf: large fetches (big weight sets) are split into multiple
        // DMA descriptors so the scheduler can spread them over several
        // ticks — a single multi-hundred-µs burst can never hide behind a
        // tens-of-µs compute tick (this is what lifted ResNet50's
        // datamover hiding, see EXPERIMENTS.md §Perf).
        const CHUNK: u64 = 256 * 1024;
        let chunks = (tl.bytes.div_ceil(CHUNK)).clamp(1, (hi - lo + 1) as u64);
        let per = tl.bytes / chunks;
        for c in 0..chunks {
            let bytes = if c == chunks - 1 { tl.bytes - per * (chunks - 1) } else { per };
            cands.push(Candidate {
                tile,
                kind,
                cycles: cost.transfer_cycles(kind, bytes),
                bytes,
                banks: if c == 0 { tl.banks } else { 0 },
                range: (lo, hi),
                adds_residency: c == 0,
            });
        }
    };

    // DRAM-resident tiles (params, graph inputs): fetch before first use.
    // Line-format consumers fetch directly in line layout (l-fetch).
    let mut fetched: HashMap<TileId, ()> = HashMap::new();
    for (si, s) in prog.steps.iter().enumerate() {
        for t in s.in_tiles.iter().chain(s.param_tile.iter()) {
            let tl = prog.tile(*t);
            if tl.starts_in_dram && !fetched.contains_key(t) {
                fetched.insert(*t, ());
                let kind = if s.needs_line_expand && s.param_tile != Some(*t) {
                    TransferKind::LFetch
                } else {
                    TransferKind::Fetch
                };
                add_fetch(&mut candidates, *t, tick_of(si), kind);
            }
        }
        // Line-parallel expansion of on-chip inputs: halo l-copy right
        // before the compute tick.
        if s.needs_line_expand {
            for &t in &s.in_tiles {
                let tl = prog.tile(t);
                if !tl.starts_in_dram {
                    // Halo bytes ≈ tile bytes scaled by (cores-1)·(fh-1)/rows;
                    // conservative: 1/8 of the tile.
                    let bytes = (tl.bytes / 8).max(cfg.bus_bytes as u64);
                    let hi = tick_of(si).saturating_sub(1);
                    candidates.push(Candidate {
                        tile: t,
                        kind: TransferKind::LCopy,
                        cycles: cost.transfer_cycles(TransferKind::LCopy, bytes),
                        bytes,
                        banks: 0, // expansion reuses the tensor's own banks
                        range: (hi.saturating_sub(1), hi),
                        adds_residency: false,
                    });
                }
            }
        }
    }
    // Graph outputs: push after production.
    for (si, s) in prog.steps.iter().enumerate() {
        let tl = prog.tile(s.out_tile);
        if tl.is_graph_output {
            let lo = (tick_of(si) + 1).min(n_ticks - 1);
            let hi = (tick_of(si) + look).min(n_ticks - 1);
            candidates.push(Candidate {
                tile: s.out_tile,
                kind: TransferKind::Push,
                cycles: cost.transfer_cycles(TransferKind::Push, tl.bytes),
                bytes: tl.bytes,
                banks: tl.banks,
                range: (lo, hi),
                adds_residency: false,
            });
        }
    }

    // --- Belady spill pre-pass: determine which activation tiles must
    // round-trip to DRAM because TCM cannot hold them until their next
    // use. Adds push+fetch candidate pairs (tick indices = step + 1). ---
    {
        let mut resident: Vec<TileId> = Vec::new();
        let mut resident_banks = 0usize;
        let cap = cfg.tcm_banks;
        for (si, s) in prog.steps.iter().enumerate() {
            let mut need: Vec<TileId> = s.in_tiles.clone();
            need.push(s.out_tile);
            if let Some(p) = s.param_tile {
                need.push(p);
            }
            for &t in &need {
                if !resident.contains(&t) {
                    resident_banks += prog.tile(t).banks;
                    resident.push(t);
                }
            }
            // Evict: drop dead tiles first (free), then spill the live tile
            // with the farthest next use.
            resident.retain(|&t| {
                let dead = last_use.get(&t).is_none_or(|&l| l <= si) && !need.contains(&t);
                if dead {
                    resident_banks -= prog.tile(t).banks;
                }
                !dead
            });
            while resident_banks > cap {
                let victim = resident
                    .iter()
                    .filter(|t| !need.contains(t))
                    .max_by_key(|&&t| next_use_after(prog, &t, si))
                    .copied();
                let Some(v) = victim else { break };
                resident.retain(|&t| t != v);
                resident_banks -= prog.tile(v).banks;
                let tl = prog.tile(v);
                let nu = next_use_after(prog, &v, si);
                if nu < usize::MAX {
                    // Activation spill: push now-ish, fetch before next use.
                    if !tl.starts_in_dram {
                        let pt = tick_of(si).min(n_ticks - 1);
                        candidates.push(Candidate {
                            tile: v,
                            kind: TransferKind::Push,
                            cycles: cost.transfer_cycles(TransferKind::Push, tl.bytes),
                            bytes: tl.bytes,
                            banks: tl.banks,
                            range: (pt, pt),
                            adds_residency: false,
                        });
                    }
                    add_fetch(&mut candidates, v, tick_of(nu), TransferKind::Fetch);
                }
            }
        }
    }

    // --- Warm start: remember where a prior schedule of this program
    // placed each transfer. Keyed by (tile, kind, bytes) with FIFO order
    // over duplicates (chunked fetches of one tile share a key); each
    // window's hint consumes matching entries as it reuses them. ---
    let mut prior: HashMap<(TileId, TransferKind, u64), std::collections::VecDeque<usize>> =
        HashMap::new();
    if let Some(warm) = &opts.warm {
        for (ti, tick) in warm.ticks.iter().enumerate() {
            for tr in &tick.transfers {
                prior
                    .entry((tr.tile, tr.kind, tr.bytes))
                    .or_default()
                    .push_back(ti);
            }
        }
    }

    // --- Per-window CP placement ---
    let window = if opts.partition { opts.window } else { n_ticks };
    let mut ticks: Vec<Tick> = (0..n_ticks)
        .map(|ti| Tick {
            compute: ti.checked_sub(1),
            compute_cycles: ti.checked_sub(1).map_or(0, |si| prog.steps[si].cycles),
            ..Default::default()
        })
        .collect();
    let mut ddr = DdrTraffic::default();
    let mut solve_ms = 0u64;
    let mut subproblems = 0usize;
    let mut variables = 0usize;
    let mut cp_stats = SolveStats::default();

    let mut w_start = 0;
    while w_start < n_ticks {
        let w_end = (w_start + window).min(n_ticks);
        // Candidates whose range intersects the window; clamp to window.
        let in_window: Vec<(usize, (usize, usize))> = candidates
            .iter()
            .enumerate()
            .filter_map(|(ci, c)| {
                let lo = c.range.0.max(w_start);
                let hi = c.range.1.min(w_end - 1);
                (lo <= hi).then_some((ci, (lo, hi)))
            })
            .collect();

        let (placed, stats, sstats) = place_window(
            prog,
            cfg,
            opts,
            &ticks[w_start..w_end],
            &candidates,
            &in_window,
            w_start,
            &mut prior,
        );
        subproblems += 1;
        solve_ms += stats.0;
        variables += stats.1;
        cp_stats.merge(&sstats);
        for (ci, tick) in placed {
            let c = &candidates[ci];
            let tr = ScheduledTransfer {
                tile: c.tile,
                kind: c.kind,
                cycles: c.cycles,
                bytes: c.bytes,
            };
            ddr.record(&Transfer::new(c.kind, c.bytes));
            ticks[tick].dm_cycles += c.cycles;
            ticks[tick].transfers.push(tr);
        }
        w_start = w_end;
    }

    (Schedule { ticks, ddr, solve_ms, subproblems, variables }, cp_stats)
}

fn next_use_after(prog: &TiledProgram, tile: &TileId, after: usize) -> usize {
    prog.steps
        .iter()
        .enumerate()
        .skip(after + 1)
        .find(|(_, s)| s.in_tiles.contains(tile) || s.param_tile == Some(*tile))
        .map(|(i, _)| i)
        .unwrap_or(usize::MAX)
}

/// CP placement of the window's transfer candidates. Returns
/// `(placements, (solve_ms, vars), solve_stats)`. `prior` carries
/// remembered tick placements from a warm-start schedule (empty when
/// compiling cold); entries this window reuses are consumed so later
/// windows don't.
#[allow(clippy::too_many_arguments)]
fn place_window(
    prog: &TiledProgram,
    cfg: &NeutronConfig,
    opts: &SchedulingOptions,
    window_ticks: &[Tick],
    candidates: &[Candidate],
    in_window: &[(usize, (usize, usize))],
    w_start: usize,
    prior: &mut HashMap<(TileId, TransferKind, u64), std::collections::VecDeque<usize>>,
) -> (Vec<(usize, usize)>, (u64, usize), SolveStats) {
    if in_window.is_empty() {
        return (Vec::new(), (0, 0), SolveStats::default());
    }
    let w = window_ticks.len();
    let mut m = CpModel::new();

    // x[ci][t]: transfer ci runs at window-local tick t.
    let mut x: HashMap<(usize, usize), Var> = HashMap::new();
    for &(ci, (lo, hi)) in in_window {
        let c = &candidates[ci];
        let mut vars = Vec::new();
        for t in lo..=hi {
            let lt = t - w_start;
            // Bus-conflict (Eq. 3): skip ticks whose compute step touches a
            // sibling tile (same tensor) of the transferred tile.
            if let Some(si) = window_ticks[lt].compute {
                let s = &prog.steps[si];
                let same_tensor = |a: TileId, b: TileId| {
                    prog.tile(a).tensor == prog.tile(b).tensor && a != b
                };
                let conflict = s.in_tiles.iter().any(|&it| same_tensor(it, c.tile))
                    || same_tensor(s.out_tile, c.tile);
                if conflict && c.kind != TransferKind::LCopy {
                    continue;
                }
            }
            let v = m.bool_var(format!("x_{ci}_{t}"));
            x.insert((ci, lt), v);
            vars.push(v);
        }
        if vars.is_empty() {
            // All ticks conflicted: fall back to the earliest allowed tick.
            let v = m.bool_var(format!("x_{ci}_forced"));
            m.add_ge(LinExpr::var(v), 1);
            x.insert((ci, lo - w_start), v);
            vars.push(v);
        }
        m.add_exactly_one(vars);
    }

    // Capacity (Eq. 7): resident banks at tick t ≤ C. Residency from
    // fetch-style transfers accumulates from their tick; pushes free banks
    // after their tick. Const part: tiles produced by computes in/before
    // this window and still live (approximated by the tiling pass's
    // residency, which the Belady pre-pass already reduced below C).
    for lt in 0..w {
        let base = window_ticks[lt]
            .compute
            .and_then(|si| prog.residency_banks.get(si))
            .copied()
            .unwrap_or(0) as i64;
        let mut expr = LinExpr::new();
        for &(ci, (lo, hi)) in in_window {
            let c = &candidates[ci];
            if c.banks == 0 {
                continue;
            }
            if c.adds_residency {
                // Early fetch extends residency: count if fetched at ≤ lt
                // but the "natural" (latest) tick is > lt.
                for t in lo..=hi {
                    let tl = t - w_start;
                    if tl <= lt && t < hi {
                        if let Some(&v) = x.get(&(ci, tl)) {
                            expr.push(c.banks as i64, v);
                        }
                    }
                }
            }
        }
        if !expr.is_empty() {
            m.add_le(expr, (cfg.tcm_banks as i64 - base).max(0));
        }
    }

    // Tick latency vars: L_t ≥ compute (const), L_t ≥ Σ cycles·x.
    let scale = 1024u64; // cycles are large; scale to keep i64 comfy
    let mut obj = LinExpr::new();
    let mut l_vars = Vec::with_capacity(w);
    for lt in 0..w {
        let comp = (window_ticks[lt].compute_cycles / scale) as i64;
        let max_dm: i64 = in_window
            .iter()
            .map(|&(ci, _)| (candidates[ci].cycles / scale) as i64)
            .sum::<i64>()
            + comp;
        let l = m.int_var(comp, max_dm.max(comp), format!("L_{lt}"));
        l_vars.push(l);
        // L_t ≥ Σ cycles·x(·, t)  ⇔  L_t − Σ cycles·x ≥ 0.
        let mut con = LinExpr::var(l);
        for &(ci, _) in in_window {
            if let Some(&v) = x.get(&(ci, lt)) {
                con.push(-((candidates[ci].cycles / scale) as i64), v);
            }
        }
        m.add_ge(con, 0);
        obj.push(1, l);
    }
    // δ·N_DM term.
    for (&(_, _), &v) in &x {
        obj.push(opts.delta as i64, v);
    }
    m.minimize(obj);

    // Greedy warm start: place each transfer (largest first) at the
    // feasible tick that minimizes the resulting tick datamover load. The
    // CP can only improve on this incumbent — without it, time-limited
    // searches on big windows return clustered (poor-overlap) placements.
    let hint = {
        let mut assignment = vec![0i64; m.num_vars()];
        let mut dm_load = vec![0u64; w];
        let mut cand_order: Vec<usize> = in_window.iter().map(|&(ci, _)| ci).collect();
        cand_order.sort_by_key(|&ci| std::cmp::Reverse(candidates[ci].cycles));
        cand_order.dedup();
        for ci in cand_order {
            // Feasible local ticks for this candidate.
            let ticks: Vec<usize> = (0..w).filter(|&lt| x.contains_key(&(ci, lt))).collect();
            if ticks.is_empty() {
                continue;
            }
            let c = &candidates[ci];
            // Warm start: reuse the prior schedule's tick when it is still
            // a feasible candidate tick in this window.
            let from_prior = prior.get_mut(&(c.tile, c.kind, c.bytes)).and_then(|q| {
                let pos = q.iter().position(|&pt| {
                    pt.checked_sub(w_start)
                        .is_some_and(|lt| lt < w && x.contains_key(&(ci, lt)))
                })?;
                q.remove(pos)
            });
            let best = match from_prior {
                Some(pt) => pt - w_start,
                None => ticks
                    .iter()
                    .copied()
                    .min_by_key(|&lt| {
                        let after = dm_load[lt] + candidates[ci].cycles;
                        // Prefer ticks where the transfer hides under compute.
                        after.saturating_sub(window_ticks[lt].compute_cycles)
                    })
                    .unwrap(),
            };
            dm_load[best] += candidates[ci].cycles;
            assignment[x[&(ci, best)].index()] = 1;
        }
        for lt in 0..w {
            let comp = (window_ticks[lt].compute_cycles / scale) as i64;
            assignment[l_vars[lt].index()] = comp.max((dm_load[lt] / scale) as i64);
        }
        assignment
    };

    let vars = m.num_vars();
    let solver_cfg = SearchConfig { hint: Some(hint), ..opts.solver.clone() };
    let sol = crate::cp::solve(&m, solver_cfg);
    let mut placed = Vec::new();
    match sol.status {
        Status::Optimal | Status::Feasible => {
            for (&(ci, lt), &v) in &x {
                if sol.value(v) == Ok(1) {
                    placed.push((ci, w_start + lt));
                }
            }
        }
        _ => {
            // Solver exhausted without a solution (shouldn't happen — the
            // model is trivially satisfiable by latest-tick placement):
            // fall back deterministically.
            let mut seen = std::collections::HashSet::new();
            for &(ci, (_, hi)) in in_window {
                if seen.insert(ci) {
                    placed.push((ci, hi));
                }
            }
        }
    }
    placed.sort();
    (placed, (sol.solve_ms, vars), sol.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::format::select_formats;
    use crate::compiler::tiling::{tile_graph, TilingOptions};
    use crate::zoo;

    fn sched(g: &crate::ir::Graph, opts: &SchedulingOptions) -> (TiledProgram, Schedule) {
        let cfg = NeutronConfig::flagship_2tops();
        let plan = select_formats(g, &cfg);
        let prog = tile_graph(g, &plan, &cfg, &TilingOptions::default());
        let s = schedule(&prog, &cfg, opts);
        (prog, s)
    }

    #[test]
    fn schedule_covers_all_steps() {
        let g = zoo::mobilenet::mobilenet_v2();
        let (prog, s) = sched(&g, &SchedulingOptions::default());
        // One tick per compute step plus the pure-DM preamble tick.
        assert_eq!(s.ticks.len(), prog.steps.len() + 1);
        assert!(s.ticks[0].compute.is_none());
        assert!(s.total_cycles() > 0);
    }

    #[test]
    fn dae_beats_serialized_execution() {
        let g = zoo::mobilenet::mobilenet_v1();
        let (_, s) = sched(&g, &SchedulingOptions::default());
        // Latency hiding must help: Σ max(c, d) < Σ (c + d).
        assert!(
            s.total_cycles() < s.serialized_cycles(),
            "dae {} !< serial {}",
            s.total_cycles(),
            s.serialized_cycles()
        );
    }

    #[test]
    fn every_fetch_lands_before_first_use() {
        let g = zoo::mobilenet::mobilenet_v2();
        let (prog, s) = sched(&g, &SchedulingOptions::default());
        // Track fetch tick per tile; any compute step consuming a
        // DRAM-origin tile must come strictly after its fetch.
        let mut fetch_tick: HashMap<TileId, usize> = HashMap::new();
        for (ti, tick) in s.ticks.iter().enumerate() {
            for tr in &tick.transfers {
                if matches!(tr.kind, TransferKind::Fetch | TransferKind::LFetch) {
                    fetch_tick.entry(tr.tile).or_insert(ti);
                }
            }
        }
        for (ti, tick) in s.ticks.iter().enumerate() {
            if let Some(si) = tick.compute {
                let step = &prog.steps[si];
                for t in step.in_tiles.iter().chain(step.param_tile.iter()) {
                    if prog.tile(*t).starts_in_dram {
                        let ft = fetch_tick.get(t).copied();
                        assert!(
                            ft.is_some_and(|f| f < ti),
                            "tile {t:?} used at tick {ti} fetched at {ft:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn graph_outputs_are_pushed() {
        let g = zoo::mobilenet::mobilenet_v1();
        let (prog, s) = sched(&g, &SchedulingOptions::default());
        let out_tiles: Vec<TileId> = prog
            .tiles
            .iter()
            .filter(|t| t.is_graph_output)
            .map(|t| t.id)
            .collect();
        for ot in out_tiles {
            let pushed = s
                .ticks
                .iter()
                .any(|tk| tk.transfers.iter().any(|tr| tr.tile == ot && tr.kind == TransferKind::Push));
            assert!(pushed, "output tile {ot:?} never pushed to DRAM");
        }
    }

    #[test]
    fn monolithic_schedule_is_at_least_as_good() {
        let g = zoo::mobilenet::mobilenet_v2();
        let part = sched(&g, &SchedulingOptions::default()).1;
        let mono = sched(
            &g,
            &SchedulingOptions { partition: false, ..Default::default() },
        )
        .1;
        // The monolithic problem sees the full horizon, but a budgeted
        // B&B may not close the gap on the big model — the two must stay
        // within 10% of each other (the paper measures +3.3% inference
        // cost for partitioning on YOLOv8n, Table II).
        let lo = part.total_cycles() * 90 / 100;
        let hi = part.total_cycles() * 110 / 100;
        assert!(
            (lo..=hi).contains(&mono.total_cycles()),
            "mono {} vs part {}",
            mono.total_cycles(),
            part.total_cycles()
        );
        assert_eq!(mono.subproblems, 1);
        assert!(part.subproblems > 1);
    }
}
