//! Coordinator executor: the L3 "leader" loop that drives an inference.
//!
//! Plays the role of the on-device RISC-V controller + host runtime:
//! consumes a [`JobProgram`] tick by tick, advances the virtual clock with
//! the architecture timing model (compute ∥ datamover per tick), maintains
//! the V2P table, and — when a PJRT executable is attached — produces the
//! *actual numerics* of the model by running the AOT artifact once per
//! request. Timing comes from the model; numbers come from PJRT; Python is
//! never involved.
//!
//! The executor is **re-entrant**: [`Executor::run_program`] borrows the
//! job program per request, so one executor (= one virtual NPU instance in
//! the serving layer) can multiplex cached programs of different models,
//! and a single cached program can be shared across many executors. The
//! V2P table is re-initialized to identity per request (each program's
//! remaps assume the allocator's starting state); all other per-request
//! state lives in the returned [`InferenceResult`], and the executor's
//! aggregate [`Metrics`] are folded from it via [`Metrics::record`].

use anyhow::Result;

use super::jobs::{Job, JobProgram};
use super::metrics::Metrics;
use crate::arch::{NeutronConfig, V2pTable};

/// Execution result of one inference request — the complete per-request
/// state (timing, job counts, traffic, outputs).
#[derive(Debug, Clone, Default)]
pub struct InferenceResult {
    /// Simulated on-device latency, NPU core cycles.
    pub sim_cycles: u64,
    /// Simulated on-device latency in milliseconds (derived from
    /// `sim_cycles` at the config's core clock).
    pub sim_ms: f64,
    /// Wall-clock host time spent driving the program (coordinator cost).
    pub host_us: u64,
    /// Model outputs (present when a PJRT executable was attached).
    pub logits: Option<Vec<i32>>,
    /// Barrier-delimited scheduler ticks replayed for this request.
    pub ticks: usize,
    /// Compute jobs dispatched for this request.
    pub compute_jobs: u64,
    /// DMA jobs dispatched for this request.
    pub dma_jobs: u64,
    /// V2P remaps replayed for this request.
    pub v2p_updates: u64,
    /// DDR bytes moved for this request.
    pub ddr_bytes: u64,
}

/// The coordinator: owns the device state and (optionally) a resident
/// job program for the single-model fast path.
pub struct Executor {
    cfg: NeutronConfig,
    program: JobProgram,
    v2p: V2pTable,
    /// Aggregate metrics folded from every request this executor ran.
    pub metrics: Metrics,
}

impl Executor {
    /// Build an executor with `program` resident (the single-model fast
    /// path driven by [`Executor::run_request`]).
    pub fn new(cfg: NeutronConfig, program: JobProgram) -> Self {
        let v2p = V2pTable::identity(cfg.tcm_banks);
        Self { cfg, program, v2p, metrics: Metrics::default() }
    }

    /// A program-less executor for multi-tenant serving: one per virtual
    /// NPU instance, with each request supplying its (cached) program.
    pub fn with_config(cfg: NeutronConfig) -> Self {
        Self::new(cfg, JobProgram::default())
    }

    /// The architecture configuration this executor simulates.
    pub fn config(&self) -> &NeutronConfig {
        &self.cfg
    }

    /// Drive one inference through the resident job program. `run_numerics`
    /// is the optional PJRT closure producing the request's actual outputs.
    pub fn run_request(
        &mut self,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        let program = std::mem::take(&mut self.program);
        let result = self.run_program(&program, run_numerics);
        self.program = program;
        result
    }

    /// Drive one inference through an arbitrary (borrowed) job program —
    /// the re-entrant form the serving layer uses with cached programs.
    pub fn run_program(
        &mut self,
        program: &JobProgram,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        let t0 = std::time::Instant::now();
        // Each program's V2P updates were planned by its allocator against
        // an identity table; start every request from that state so
        // interleaved models replay the mappings their compiles assumed.
        self.v2p = V2pTable::identity(self.cfg.tcm_banks);
        let mut result = InferenceResult::default();

        for job in &program.jobs {
            match job {
                Job::Compute { .. } => result.compute_jobs += 1,
                Job::Dma { bytes, kind, .. } => {
                    result.dma_jobs += 1;
                    if kind.uses_ddr() {
                        result.ddr_bytes += bytes;
                    }
                }
                Job::V2p { virt_bank, phys_bank } => {
                    // Idle-mode remap: swap so the table stays a bijection.
                    let cur = self.v2p.translate(*virt_bank);
                    if cur != *phys_bank {
                        // Find which virtual bank currently maps to phys.
                        let other = (0..self.v2p.banks())
                            .find(|&v| self.v2p.translate(v) == *phys_bank)
                            .expect("bijection");
                        self.v2p.swap(*virt_bank, other);
                    }
                    result.v2p_updates += 1;
                }
                Job::Barrier => result.ticks += 1,
            }
        }
        // DAE tick timing (compute ∥ datamover) via the shared helper on
        // the program, counting every DMA job.
        let total_cycles = program.service_cycles_where(|_| true);

        result.logits = match run_numerics {
            Some(f) => Some(f()?),
            None => None,
        };

        result.sim_cycles = total_cycles;
        result.sim_ms = self.cfg.cycles_to_ms(total_cycles);
        result.host_us = t0.elapsed().as_micros() as u64;
        self.metrics.record(&result);
        Ok(result)
    }

    /// The resident job program (empty for serving executors built with
    /// [`Executor::with_config`]).
    pub fn program(&self) -> &JobProgram {
        &self.program
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::coordinator::jobs::emit;
    use crate::zoo;

    fn executor_for(g: &crate::ir::Graph) -> Executor {
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, &g.name);
        Executor::new(cfg, p)
    }

    #[test]
    fn run_request_accumulates_ticks_and_cycles() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let r = ex.run_request(None).unwrap();
        assert!(r.sim_cycles > 0);
        assert!(r.ticks > 0);
        assert!(r.sim_ms > 0.0);
        assert_eq!(ex.metrics.requests, 1);
    }

    #[test]
    fn repeated_requests_are_deterministic() {
        let g = zoo::mobilenet::mobilenet_v1();
        let mut ex = executor_for(&g);
        let a = ex.run_request(None).unwrap();
        let b = ex.run_request(None).unwrap();
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(ex.metrics.requests, 2);
    }

    #[test]
    fn numerics_closure_is_invoked() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let f = || Ok(vec![1, 2, 3]);
        let r = ex.run_request(Some(&f)).unwrap();
        assert_eq!(r.logits, Some(vec![1, 2, 3]));
    }

    #[test]
    fn executor_latency_matches_schedule_estimate() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let mut ex = Executor::new(cfg, p);
        let r = ex.run_request(None).unwrap();
        assert_eq!(r.sim_cycles, c.schedule.total_cycles());
    }

    #[test]
    fn run_program_is_reentrant_across_models() {
        let cfg = NeutronConfig::flagship_2tops();
        let g1 = zoo::mobilenet::mobilenet_v1();
        let g2 = zoo::mobilenet::mobilenet_v2();
        let c1 = compile(&g1, &cfg, &CompileOptions::default_partitioned());
        let c2 = compile(&g2, &cfg, &CompileOptions::default_partitioned());
        let p1 = emit(&c1, "m1");
        let p2 = emit(&c2, "m2");
        let mut ex = Executor::with_config(cfg.clone());
        let a1 = ex.run_program(&p1, None).unwrap();
        let b = ex.run_program(&p2, None).unwrap();
        let a2 = ex.run_program(&p1, None).unwrap();
        // Interleaving different models' programs must not perturb timing.
        assert_eq!(a1.sim_cycles, a2.sim_cycles);
        assert_eq!(a1.sim_cycles, c1.schedule.total_cycles());
        assert_eq!(b.sim_cycles, c2.schedule.total_cycles());
        assert_eq!(ex.metrics.requests, 3);
    }

    #[test]
    fn per_request_state_sums_to_aggregate_metrics() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let rs: Vec<InferenceResult> =
            (0..3).map(|_| ex.run_request(None).unwrap()).collect();
        assert_eq!(ex.metrics.requests, 3);
        assert_eq!(
            ex.metrics.compute_jobs,
            rs.iter().map(|r| r.compute_jobs).sum::<u64>()
        );
        assert_eq!(ex.metrics.dma_jobs, rs.iter().map(|r| r.dma_jobs).sum::<u64>());
        assert_eq!(
            ex.metrics.v2p_updates,
            rs.iter().map(|r| r.v2p_updates).sum::<u64>()
        );
        assert_eq!(ex.metrics.ddr_bytes, rs.iter().map(|r| r.ddr_bytes).sum::<u64>());
        assert_eq!(
            ex.metrics.total_sim_cycles,
            rs.iter().map(|r| r.sim_cycles).sum::<u64>()
        );
    }
}
