//! Coordinator executor: the L3 "leader" loop that drives an inference.
//!
//! Plays the role of the on-device RISC-V controller + host runtime:
//! consumes a [`JobProgram`] tick by tick, advances the virtual clock with
//! the architecture timing model (compute ∥ datamover per tick), maintains
//! the V2P table, and — when a PJRT executable is attached — produces the
//! *actual numerics* of the model by running the AOT artifact once per
//! request. Timing comes from the model; numbers come from PJRT; Python is
//! never involved.
//!
//! The executor is **re-entrant**: [`Executor::run_program`] borrows the
//! job program per request, so one executor (= one virtual NPU instance in
//! the serving layer) can multiplex cached programs of different models,
//! and a single cached program can be shared across many executors. The
//! V2P table is re-initialized to identity per request (each program's
//! remaps assume the allocator's starting state); all other per-request
//! state lives in the returned [`InferenceResult`], and the executor's
//! aggregate [`Metrics`] are folded from it via [`Metrics::record`].

use anyhow::Result;

use super::jobs::{Job, JobProgram};
use super::metrics::Metrics;
use crate::arch::{NeutronConfig, V2pTable};

/// Execution result of one inference request — the complete per-request
/// state (timing, job counts, traffic, outputs).
#[derive(Debug, Clone, Default)]
pub struct InferenceResult {
    /// Simulated on-device latency, NPU core cycles.
    pub sim_cycles: u64,
    /// Simulated on-device latency in milliseconds (derived from
    /// `sim_cycles` at the config's core clock).
    pub sim_ms: f64,
    /// Wall-clock host time spent driving the program (coordinator cost).
    pub host_us: u64,
    /// Model outputs (present when a PJRT executable was attached).
    pub logits: Option<Vec<i32>>,
    /// Barrier-delimited scheduler ticks replayed for this request.
    pub ticks: usize,
    /// Compute jobs dispatched for this request.
    pub compute_jobs: u64,
    /// DMA jobs dispatched for this request.
    pub dma_jobs: u64,
    /// V2P remaps replayed for this request.
    pub v2p_updates: u64,
    /// DDR bytes moved for this request.
    pub ddr_bytes: u64,
}

/// The coordinator: owns the device state and (optionally) a resident
/// job program for the single-model fast path.
pub struct Executor {
    cfg: NeutronConfig,
    program: JobProgram,
    v2p: V2pTable,
    /// Aggregate metrics folded from every request this executor ran.
    pub metrics: Metrics,
}

impl Executor {
    /// Build an executor with `program` resident (the single-model fast
    /// path driven by [`Executor::run_request`]).
    pub fn new(cfg: NeutronConfig, program: JobProgram) -> Self {
        let v2p = V2pTable::identity(cfg.tcm_banks);
        Self { cfg, program, v2p, metrics: Metrics::default() }
    }

    /// A program-less executor for multi-tenant serving: one per virtual
    /// NPU instance, with each request supplying its (cached) program.
    pub fn with_config(cfg: NeutronConfig) -> Self {
        Self::new(cfg, JobProgram::default())
    }

    /// The architecture configuration this executor simulates.
    pub fn config(&self) -> &NeutronConfig {
        &self.cfg
    }

    /// Drive one inference through the resident job program. `run_numerics`
    /// is the optional PJRT closure producing the request's actual outputs.
    pub fn run_request(
        &mut self,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        let program = std::mem::take(&mut self.program);
        let result = self.run_program(&program, run_numerics);
        self.program = program;
        result
    }

    /// Drive one inference through an arbitrary (borrowed) job program —
    /// the re-entrant form the serving layer uses with cached programs.
    /// Every DMA job is counted (the cold-dispatch baseline); this is the
    /// [`Executor::run_program_where`] fast path with an all-pass filter.
    pub fn run_program(
        &mut self,
        program: &JobProgram,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        self.run_program_where(program, |_| true, run_numerics)
    }

    /// [`Executor::run_program`] with a DMA filter: DMA jobs for which
    /// `count_dma` returns false are *elided* — they contribute no
    /// datamover cycles, no DMA-job count and no DDR traffic, exactly as
    /// if the transfer never issued. This is how the serving layer runs a
    /// residency-warm request whose parameter tiles are already in TCM.
    pub fn run_program_where(
        &mut self,
        program: &JobProgram,
        mut count_dma: impl FnMut(&Job) -> bool,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        let mut run = self.begin(program);
        while run.step_tick(&mut count_dma).is_some() {}
        run.finish(run_numerics)
    }

    /// Begin a resumable execution of `program`: the tick-loop form of
    /// [`Executor::run_program`]. The caller drives the returned
    /// [`ProgramRun`] one barrier-delimited tick at a time with
    /// [`ProgramRun::step_tick`] and seals it with [`ProgramRun::finish`]
    /// — which is what lets the serving layer hold one request's tail
    /// in flight while reasoning about the next request's head.
    pub fn begin<'e, 'p>(&'e mut self, program: &'p JobProgram) -> ProgramRun<'e, 'p> {
        // Each program's V2P updates were planned by its allocator against
        // an identity table; start every request from that state so
        // interleaved models replay the mappings their compiles assumed.
        self.v2p = V2pTable::identity(self.cfg.tcm_banks);
        ProgramRun {
            executor: self,
            program,
            next_job: 0,
            t0: std::time::Instant::now(),
            result: InferenceResult::default(),
        }
    }

    /// The resident job program (empty for serving executors built with
    /// [`Executor::with_config`]).
    pub fn program(&self) -> &JobProgram {
        &self.program
    }
}

/// What one [`ProgramRun::step_tick`] observed: the tick's DAE latency
/// and its two overlapped components.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TickStats {
    /// The tick's latency under the DAE model: `max(compute, dm)`.
    pub latency_cycles: u64,
    /// Total compute-engine cycles issued this tick.
    pub compute_cycles: u64,
    /// Total counted datamover cycles issued this tick.
    pub dm_cycles: u64,
}

/// A resumable, in-flight execution of one [`JobProgram`] on an
/// [`Executor`] — the coordinator's tick loop, reified so callers can
/// interleave per-tick progress with scheduling decisions.
///
/// Invariants: [`ProgramRun::step_tick`] consumes jobs up to and
/// including the next [`Job::Barrier`] (or the trailing unterminated
/// tick) and advances the virtual clock by that tick's DAE latency;
/// ticks sum to exactly [`JobProgram::service_cycles_where`] under the
/// same filter. [`ProgramRun::finish`] runs the optional numerics
/// closure, folds the request into the executor's [`Metrics`] and
/// returns the [`InferenceResult`] — identical, field for field, to what
/// the old run-to-completion loop produced.
pub struct ProgramRun<'e, 'p> {
    executor: &'e mut Executor,
    program: &'p JobProgram,
    next_job: usize,
    t0: std::time::Instant,
    result: InferenceResult,
}

impl<'e, 'p> ProgramRun<'e, 'p> {
    /// Execute the next barrier-delimited tick. DMA jobs rejected by
    /// `count_dma` are elided (no cycles, no job count, no DDR bytes).
    /// Returns `None` once the job stream is exhausted.
    pub fn step_tick(&mut self, mut count_dma: impl FnMut(&Job) -> bool) -> Option<TickStats> {
        if self.next_job >= self.program.jobs.len() {
            return None;
        }
        let mut stats = TickStats::default();
        while let Some(job) = self.program.jobs.get(self.next_job) {
            self.next_job += 1;
            match job {
                Job::Compute { cycles, .. } => {
                    self.result.compute_jobs += 1;
                    stats.compute_cycles += cycles;
                }
                Job::Dma { bytes, kind, cycles, .. } => {
                    if count_dma(job) {
                        self.result.dma_jobs += 1;
                        if kind.uses_ddr() {
                            self.result.ddr_bytes += bytes;
                        }
                        stats.dm_cycles += cycles;
                    }
                }
                Job::V2p { virt_bank, phys_bank } => {
                    // Idle-mode remap: swap so the table stays a bijection.
                    let cur = self.executor.v2p.translate(*virt_bank);
                    if cur != *phys_bank {
                        // Find which virtual bank currently maps to phys.
                        let other = (0..self.executor.v2p.banks())
                            .find(|&v| self.executor.v2p.translate(v) == *phys_bank)
                            .expect("bijection");
                        self.executor.v2p.swap(*virt_bank, other);
                    }
                    self.result.v2p_updates += 1;
                }
                Job::Barrier => {
                    self.result.ticks += 1;
                    break;
                }
            }
        }
        stats.latency_cycles = stats.compute_cycles.max(stats.dm_cycles);
        self.result.sim_cycles += stats.latency_cycles;
        Some(stats)
    }

    /// Simulated cycles accumulated so far (the virtual clock).
    pub fn cycles_so_far(&self) -> u64 {
        self.result.sim_cycles
    }

    /// True when every job has been consumed.
    pub fn is_done(&self) -> bool {
        self.next_job >= self.program.jobs.len()
    }

    /// Seal the run: execute the optional numerics closure, stamp derived
    /// fields, fold into the executor's aggregate [`Metrics`] and return
    /// the per-request result. Any unconsumed ticks are first drained
    /// counting every DMA job (so a sealed run is always complete).
    pub fn finish(
        mut self,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        while self.step_tick(|_| true).is_some() {}
        let mut result = self.result;
        result.logits = match run_numerics {
            Some(f) => Some(f()?),
            None => None,
        };
        result.sim_ms = self.executor.cfg.cycles_to_ms(result.sim_cycles);
        result.host_us = self.t0.elapsed().as_micros() as u64;
        self.executor.metrics.record(&result);
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::coordinator::jobs::emit;
    use crate::zoo;

    fn executor_for(g: &crate::ir::Graph) -> Executor {
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, &g.name);
        Executor::new(cfg, p)
    }

    #[test]
    fn run_request_accumulates_ticks_and_cycles() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let r = ex.run_request(None).unwrap();
        assert!(r.sim_cycles > 0);
        assert!(r.ticks > 0);
        assert!(r.sim_ms > 0.0);
        assert_eq!(ex.metrics.requests, 1);
    }

    #[test]
    fn repeated_requests_are_deterministic() {
        let g = zoo::mobilenet::mobilenet_v1();
        let mut ex = executor_for(&g);
        let a = ex.run_request(None).unwrap();
        let b = ex.run_request(None).unwrap();
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(ex.metrics.requests, 2);
    }

    #[test]
    fn numerics_closure_is_invoked() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let f = || Ok(vec![1, 2, 3]);
        let r = ex.run_request(Some(&f)).unwrap();
        assert_eq!(r.logits, Some(vec![1, 2, 3]));
    }

    #[test]
    fn executor_latency_matches_schedule_estimate() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let mut ex = Executor::new(cfg, p);
        let r = ex.run_request(None).unwrap();
        assert_eq!(r.sim_cycles, c.schedule.total_cycles());
    }

    #[test]
    fn run_program_is_reentrant_across_models() {
        let cfg = NeutronConfig::flagship_2tops();
        let g1 = zoo::mobilenet::mobilenet_v1();
        let g2 = zoo::mobilenet::mobilenet_v2();
        let c1 = compile(&g1, &cfg, &CompileOptions::default_partitioned());
        let c2 = compile(&g2, &cfg, &CompileOptions::default_partitioned());
        let p1 = emit(&c1, "m1");
        let p2 = emit(&c2, "m2");
        let mut ex = Executor::with_config(cfg.clone());
        let a1 = ex.run_program(&p1, None).unwrap();
        let b = ex.run_program(&p2, None).unwrap();
        let a2 = ex.run_program(&p1, None).unwrap();
        // Interleaving different models' programs must not perturb timing.
        assert_eq!(a1.sim_cycles, a2.sim_cycles);
        assert_eq!(a1.sim_cycles, c1.schedule.total_cycles());
        assert_eq!(b.sim_cycles, c2.schedule.total_cycles());
        assert_eq!(ex.metrics.requests, 3);
    }

    #[test]
    fn resumable_tick_loop_matches_run_to_completion() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let mut whole_ex = Executor::with_config(cfg.clone());
        let whole = whole_ex.run_program(&p, None).unwrap();

        let mut ex = Executor::with_config(cfg);
        let mut run = ex.begin(&p);
        let mut steps = 0usize;
        let mut summed = 0u64;
        while let Some(s) = run.step_tick(|_| true) {
            steps += 1;
            summed += s.latency_cycles;
            assert_eq!(s.latency_cycles, s.compute_cycles.max(s.dm_cycles));
            assert_eq!(run.cycles_so_far(), summed);
        }
        assert!(run.is_done());
        let stepped = run.finish(None).unwrap();
        // Barrier-terminated programs: one step per tick barrier.
        assert_eq!(steps, p.tick_count());
        assert_eq!(stepped.ticks, whole.ticks);
        assert_eq!(stepped.sim_cycles, whole.sim_cycles);
        assert_eq!(stepped.sim_ms, whole.sim_ms);
        assert_eq!(stepped.compute_jobs, whole.compute_jobs);
        assert_eq!(stepped.dma_jobs, whole.dma_jobs);
        assert_eq!(stepped.v2p_updates, whole.v2p_updates);
        assert_eq!(stepped.ddr_bytes, whole.ddr_bytes);
        assert_eq!(ex.metrics.requests, 1);
    }

    #[test]
    fn finish_drains_unconsumed_ticks() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let mut ex = Executor::with_config(cfg.clone());
        let whole = ex.run_program(&p, None).unwrap();
        let mut run = ex.begin(&p);
        run.step_tick(|_| true); // consume just the first tick…
        let sealed = run.finish(None).unwrap(); // …finish drains the rest
        assert_eq!(sealed.sim_cycles, whole.sim_cycles);
        assert_eq!(sealed.ticks, whole.ticks);
        assert_eq!(sealed.dma_jobs, whole.dma_jobs);
    }

    #[test]
    fn run_program_where_elides_filtered_dma() {
        use crate::arch::TransferKind;
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let params = p.param_tiles();
        let skip = |j: &Job| {
            !matches!(j, Job::Dma { tile, kind: TransferKind::Fetch, .. }
                if params.contains(tile))
        };
        let mut ex = Executor::with_config(cfg);
        let cold = ex.run_program(&p, None).unwrap();
        let warm = ex.run_program_where(&p, skip, None).unwrap();
        // Elided fetches disappear from the clock, the job counts and the
        // DDR traffic, and the effective time agrees with the program's
        // own filtered pricing (one timing model, two consumers).
        assert_eq!(warm.sim_cycles, p.service_cycles_where(skip));
        assert!(warm.sim_cycles <= cold.sim_cycles);
        assert!(warm.dma_jobs < cold.dma_jobs);
        assert!(warm.ddr_bytes < cold.ddr_bytes);
        assert_eq!(warm.compute_jobs, cold.compute_jobs);
        assert_eq!(warm.ticks, cold.ticks);
    }

    #[test]
    fn per_request_state_sums_to_aggregate_metrics() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let rs: Vec<InferenceResult> =
            (0..3).map(|_| ex.run_request(None).unwrap()).collect();
        assert_eq!(ex.metrics.requests, 3);
        assert_eq!(
            ex.metrics.compute_jobs,
            rs.iter().map(|r| r.compute_jobs).sum::<u64>()
        );
        assert_eq!(ex.metrics.dma_jobs, rs.iter().map(|r| r.dma_jobs).sum::<u64>());
        assert_eq!(
            ex.metrics.v2p_updates,
            rs.iter().map(|r| r.v2p_updates).sum::<u64>()
        );
        assert_eq!(ex.metrics.ddr_bytes, rs.iter().map(|r| r.ddr_bytes).sum::<u64>());
        assert_eq!(
            ex.metrics.total_sim_cycles,
            rs.iter().map(|r| r.sim_cycles).sum::<u64>()
        );
    }
}
