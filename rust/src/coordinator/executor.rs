//! Coordinator executor: the L3 "leader" loop that drives an inference.
//!
//! Plays the role of the on-device RISC-V controller + host runtime:
//! consumes a [`JobProgram`] tick by tick, advances the virtual clock with
//! the architecture timing model (compute ∥ datamover per tick), maintains
//! the V2P table, and — when a PJRT executable is attached — produces the
//! *actual numerics* of the model by running the AOT artifact once per
//! request. Timing comes from the model; numbers come from PJRT; Python is
//! never involved.

use anyhow::Result;

use super::jobs::{Job, JobProgram};
use super::metrics::Metrics;
use crate::arch::{NeutronConfig, V2pTable};

/// Execution result of one inference request.
#[derive(Debug, Clone, Default)]
pub struct InferenceResult {
    /// Simulated on-device latency.
    pub sim_cycles: u64,
    pub sim_ms: f64,
    /// Wall-clock host time spent driving the program (coordinator cost).
    pub host_us: u64,
    /// Model outputs (present when a PJRT executable was attached).
    pub logits: Option<Vec<i32>>,
    pub ticks: usize,
}

/// The coordinator: owns the job program and the device state.
pub struct Executor {
    cfg: NeutronConfig,
    program: JobProgram,
    v2p: V2pTable,
    pub metrics: Metrics,
}

impl Executor {
    pub fn new(cfg: NeutronConfig, program: JobProgram) -> Self {
        let v2p = V2pTable::identity(cfg.tcm_banks);
        Self { cfg, program, v2p, metrics: Metrics::default() }
    }

    /// Drive one inference through the job program. `run_numerics` is the
    /// optional PJRT closure producing the request's actual outputs.
    pub fn run_request(
        &mut self,
        run_numerics: Option<&dyn Fn() -> Result<Vec<i32>>>,
    ) -> Result<InferenceResult> {
        let t0 = std::time::Instant::now();
        let mut total_cycles = 0u64;
        let mut tick_compute = 0u64;
        let mut tick_dm = 0u64;
        let mut ticks = 0usize;

        for job in &self.program.jobs {
            match job {
                Job::Compute { cycles, .. } => {
                    tick_compute += cycles;
                    self.metrics.compute_jobs += 1;
                }
                Job::Dma { cycles, bytes, kind, .. } => {
                    tick_dm += cycles;
                    self.metrics.dma_jobs += 1;
                    if kind.uses_ddr() {
                        self.metrics.ddr_bytes += bytes;
                    }
                }
                Job::V2p { virt_bank, phys_bank } => {
                    // Idle-mode remap: swap so the table stays a bijection.
                    let cur = self.v2p.translate(*virt_bank);
                    if cur != *phys_bank {
                        // Find which virtual bank currently maps to phys.
                        let other = (0..self.v2p.banks())
                            .find(|&v| self.v2p.translate(v) == *phys_bank)
                            .expect("bijection");
                        self.v2p.swap(*virt_bank, other);
                    }
                    self.metrics.v2p_updates += 1;
                }
                Job::Barrier => {
                    // DAE tick: compute and datamover overlap.
                    total_cycles += tick_compute.max(tick_dm);
                    tick_compute = 0;
                    tick_dm = 0;
                    ticks += 1;
                }
            }
        }
        total_cycles += tick_compute.max(tick_dm);

        let logits = match run_numerics {
            Some(f) => Some(f()?),
            None => None,
        };

        let host_us = t0.elapsed().as_micros() as u64;
        self.metrics.requests += 1;
        self.metrics.total_sim_cycles += total_cycles;
        self.metrics.total_host_us += host_us;

        Ok(InferenceResult {
            sim_cycles: total_cycles,
            sim_ms: self.cfg.cycles_to_ms(total_cycles),
            host_us,
            logits,
            ticks,
        })
    }

    pub fn program(&self) -> &JobProgram {
        &self.program
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::coordinator::jobs::emit;
    use crate::zoo;

    fn executor_for(g: &crate::ir::Graph) -> Executor {
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, &g.name);
        Executor::new(cfg, p)
    }

    #[test]
    fn run_request_accumulates_ticks_and_cycles() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let r = ex.run_request(None).unwrap();
        assert!(r.sim_cycles > 0);
        assert!(r.ticks > 0);
        assert!(r.sim_ms > 0.0);
        assert_eq!(ex.metrics.requests, 1);
    }

    #[test]
    fn repeated_requests_are_deterministic() {
        let g = zoo::mobilenet::mobilenet_v1();
        let mut ex = executor_for(&g);
        let a = ex.run_request(None).unwrap();
        let b = ex.run_request(None).unwrap();
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(ex.metrics.requests, 2);
    }

    #[test]
    fn numerics_closure_is_invoked() {
        let g = zoo::mobilenet::mobilenet_v2();
        let mut ex = executor_for(&g);
        let f = || Ok(vec![1, 2, 3]);
        let r = ex.run_request(Some(&f)).unwrap();
        assert_eq!(r.logits, Some(vec![1, 2, 3]));
    }

    #[test]
    fn executor_latency_matches_schedule_estimate() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let mut ex = Executor::new(cfg, p);
        let r = ex.run_request(None).unwrap();
        assert_eq!(r.sim_cycles, c.schedule.total_cycles());
    }
}
