//! Job program: the executable form the compiler backend emits for the
//! on-device RISC-V controller (Sec. IV intro) — compute jobs, data-transfer
//! jobs, V2P updates and synchronization barriers.

use crate::arch::{Format, TransferKind};
use crate::compiler::TileId;
use crate::ir::OpId;

/// One job for the controller.
///
/// `PartialEq`/`Eq` support bit-identical program comparison — the serving
/// layer's cache-coherence property checks a cache hit against a cold
/// compile job-for-job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Job {
    /// Program the compute cores with one kernel-library call.
    Compute {
        op: OpId,
        out_tile: TileId,
        in_tiles: Vec<TileId>,
        param_tile: Option<TileId>,
        format: Format,
        /// Cycle estimate (the simulator re-derives; the runtime uses it
        /// for progress accounting).
        cycles: u64,
    },
    /// Program the DMA engine with one transfer descriptor.
    Dma { tile: TileId, kind: TransferKind, bytes: u64, cycles: u64 },
    /// Update the V2P table (idle-mode remap).
    V2p { virt_bank: usize, phys_bank: usize },
    /// Tick barrier: all jobs since the previous barrier must complete
    /// before any job after it starts (the discretized-time contract).
    Barrier,
}

/// The complete program for one inference.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct JobProgram {
    /// Job stream in controller order (barriers delimit ticks).
    pub jobs: Vec<Job>,
    /// Name of the model this program was emitted for.
    pub model: String,
}

impl JobProgram {
    /// Number of tick barriers (== scheduler ticks).
    pub fn tick_count(&self) -> usize {
        self.jobs.iter().filter(|j| matches!(j, Job::Barrier)).count()
    }

    /// Tick-accurate DAE service time of this program: within each
    /// barrier-delimited tick, compute and datamover overlap
    /// (`max(compute, dm)`), and ticks sum. `count_dma` selects which DMA
    /// jobs contribute datamover cycles — the executor counts all of
    /// them, while the serving layer prices batch followers with
    /// parameter fetches excluded. Single source of truth for the tick
    /// timing model, so the two cannot drift apart.
    pub fn service_cycles_where(&self, mut count_dma: impl FnMut(&Job) -> bool) -> u64 {
        let mut total = 0u64;
        let mut tick_compute = 0u64;
        let mut tick_dm = 0u64;
        for job in &self.jobs {
            match job {
                Job::Compute { cycles, .. } => tick_compute += cycles,
                Job::Dma { cycles, .. } => {
                    if count_dma(job) {
                        tick_dm += cycles;
                    }
                }
                Job::V2p { .. } => {}
                Job::Barrier => {
                    total += tick_compute.max(tick_dm);
                    tick_compute = 0;
                    tick_dm = 0;
                }
            }
        }
        total + tick_compute.max(tick_dm)
    }

    /// Compute / DMA job counts.
    pub fn job_counts(&self) -> (usize, usize) {
        let c = self.jobs.iter().filter(|j| matches!(j, Job::Compute { .. })).count();
        let d = self.jobs.iter().filter(|j| matches!(j, Job::Dma { .. })).count();
        (c, d)
    }
}

/// Lower a compiled artifact into the job program (backend code emission).
pub fn emit(compiled: &crate::compiler::Compiled, model: &str) -> JobProgram {
    let mut jobs = Vec::new();
    // V2P updates replay grouped before their tick's barrier.
    let mut v2p_by_tick: std::collections::HashMap<usize, Vec<(usize, usize)>> =
        std::collections::HashMap::new();
    for &(tick, v, p) in &compiled.allocation.v2p_updates {
        v2p_by_tick.entry(tick).or_default().push((v, p));
    }
    for (ti, tick) in compiled.schedule.ticks.iter().enumerate() {
        for (v, p) in v2p_by_tick.remove(&ti).unwrap_or_default() {
            jobs.push(Job::V2p { virt_bank: v, phys_bank: p });
        }
        for tr in &tick.transfers {
            jobs.push(Job::Dma {
                tile: tr.tile,
                kind: tr.kind,
                bytes: tr.bytes,
                cycles: tr.cycles,
            });
        }
        if let Some(si) = tick.compute {
            let s = &compiled.program.steps[si];
            jobs.push(Job::Compute {
                op: s.op,
                out_tile: s.out_tile,
                in_tiles: s.in_tiles.clone(),
                param_tile: s.param_tile,
                format: s.format,
                cycles: s.cycles,
            });
        }
        jobs.push(Job::Barrier);
    }
    JobProgram { jobs, model: model.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::NeutronConfig;
    use crate::compiler::{compile, CompileOptions};
    use crate::zoo;

    #[test]
    fn emit_produces_barrier_per_tick() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "mobilenet-v2");
        assert_eq!(p.tick_count(), c.schedule.ticks.len());
        let (comp, dma) = p.job_counts();
        assert_eq!(comp, c.program.steps.len());
        assert!(dma > 0);
    }

    #[test]
    fn compute_jobs_follow_their_transfers_within_tick() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        // Within each barrier-delimited group, DMA jobs are emitted before
        // the compute job (controller programs DMA first so the DAE overlap
        // starts immediately).
        let mut seen_compute = false;
        for j in &p.jobs {
            match j {
                Job::Barrier => seen_compute = false,
                Job::Compute { .. } => seen_compute = true,
                Job::Dma { .. } | Job::V2p { .. } => {
                    assert!(!seen_compute, "DMA after compute inside a tick");
                }
            }
        }
    }
}
