//! Job program: the executable form the compiler backend emits for the
//! on-device RISC-V controller (Sec. IV intro) — compute jobs, data-transfer
//! jobs, V2P updates and synchronization barriers.

use crate::arch::{Format, TransferKind};
use crate::compiler::TileId;
use crate::ir::OpId;

/// One job for the controller.
///
/// `PartialEq`/`Eq` support bit-identical program comparison — the serving
/// layer's cache-coherence property checks a cache hit against a cold
/// compile job-for-job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Job {
    /// Program the compute cores with one kernel-library call.
    Compute {
        op: OpId,
        out_tile: TileId,
        in_tiles: Vec<TileId>,
        param_tile: Option<TileId>,
        format: Format,
        /// Cycle estimate (the simulator re-derives; the runtime uses it
        /// for progress accounting).
        cycles: u64,
    },
    /// Program the DMA engine with one transfer descriptor.
    Dma { tile: TileId, kind: TransferKind, bytes: u64, cycles: u64 },
    /// Update the V2P table (idle-mode remap).
    V2p { virt_bank: usize, phys_bank: usize },
    /// Tick barrier: all jobs since the previous barrier must complete
    /// before any job after it starts (the discretized-time contract).
    Barrier,
}

/// The complete program for one inference.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct JobProgram {
    /// Job stream in controller order (barriers delimit ticks).
    pub jobs: Vec<Job>,
    /// Name of the model this program was emitted for.
    pub model: String,
}

impl JobProgram {
    /// Number of tick barriers (== scheduler ticks).
    pub fn tick_count(&self) -> usize {
        self.jobs.iter().filter(|j| matches!(j, Job::Barrier)).count()
    }

    /// Tick-accurate DAE service time of this program: within each
    /// barrier-delimited tick, compute and datamover overlap
    /// (`max(compute, dm)`), and ticks sum. `count_dma` selects which DMA
    /// jobs contribute datamover cycles — the executor counts all of
    /// them, while the serving layer prices batch followers with
    /// parameter fetches excluded. Single source of truth for the tick
    /// timing model, so the two cannot drift apart.
    pub fn service_cycles_where(&self, mut count_dma: impl FnMut(&Job) -> bool) -> u64 {
        let mut total = 0u64;
        let mut tick_compute = 0u64;
        let mut tick_dm = 0u64;
        for job in &self.jobs {
            match job {
                Job::Compute { cycles, .. } => tick_compute += cycles,
                Job::Dma { cycles, .. } => {
                    if count_dma(job) {
                        tick_dm += cycles;
                    }
                }
                Job::V2p { .. } => {}
                Job::Barrier => {
                    total += tick_compute.max(tick_dm);
                    tick_compute = 0;
                    tick_dm = 0;
                }
            }
        }
        total + tick_compute.max(tick_dm)
    }

    /// Per-op observed service cycles under the tick timing model: each
    /// barrier-delimited tick costs `max(compute, dm)`, attributed to the
    /// tick's compute op. Compute-less ticks (prologue prefetches,
    /// conversion copies) are attributed to the *next* compute op — the
    /// transfer exists to feed it — and trailing compute-less ticks
    /// (writebacks) to the last op. Sums to
    /// `service_cycles_where(|_| true)` exactly, so the per-op breakdown
    /// never disagrees with the total the serving layer charges.
    ///
    /// The trace recorder embeds this breakdown so `neutron validate` can
    /// join compiler-predicted per-op cycles against what the executor
    /// tick path actually observed.
    pub fn per_op_tick_cycles(&self) -> Vec<(OpId, u64)> {
        let mut per_op: Vec<(OpId, u64)> = Vec::new();
        let mut charge = |op: OpId, cycles: u64, per_op: &mut Vec<(OpId, u64)>| {
            match per_op.iter_mut().find(|(o, _)| *o == op) {
                Some((_, c)) => *c += cycles,
                None => per_op.push((op, cycles)),
            }
        };
        let mut tick_compute = 0u64;
        let mut tick_dm = 0u64;
        let mut tick_op: Option<OpId> = None;
        // Cycles of compute-less ticks waiting for the next compute op.
        let mut orphan_cycles = 0u64;
        for job in &self.jobs {
            match job {
                Job::Compute { op, cycles, .. } => {
                    tick_compute += cycles;
                    tick_op = Some(*op);
                }
                Job::Dma { cycles, .. } => tick_dm += cycles,
                Job::V2p { .. } => {}
                Job::Barrier => {
                    let latency = tick_compute.max(tick_dm);
                    match tick_op {
                        Some(op) => charge(op, latency + orphan_cycles, &mut per_op),
                        None => orphan_cycles += latency,
                    }
                    if tick_op.is_some() {
                        orphan_cycles = 0;
                    }
                    tick_compute = 0;
                    tick_dm = 0;
                    tick_op = None;
                }
            }
        }
        // Unterminated trailing tick, then any leftover orphan cycles.
        let latency = tick_compute.max(tick_dm);
        match tick_op {
            Some(op) => charge(op, latency + orphan_cycles, &mut per_op),
            None => {
                orphan_cycles += latency;
                if orphan_cycles > 0 {
                    match per_op.last_mut() {
                        Some((_, c)) => *c += orphan_cycles,
                        // Program with no compute at all: bucket under a
                        // sentinel op so the total stays conserved.
                        None => per_op.push((OpId(u32::MAX), orphan_cycles)),
                    }
                }
            }
        }
        per_op
    }

    /// Compute / DMA job counts.
    pub fn job_counts(&self) -> (usize, usize) {
        let c = self.jobs.iter().filter(|j| matches!(j, Job::Compute { .. })).count();
        let d = self.jobs.iter().filter(|j| matches!(j, Job::Dma { .. })).count();
        (c, d)
    }
}

/// Lower a compiled artifact into the job program (backend code emission).
pub fn emit(compiled: &crate::compiler::Compiled, model: &str) -> JobProgram {
    let mut jobs = Vec::new();
    // V2P updates replay grouped before their tick's barrier.
    let mut v2p_by_tick: std::collections::HashMap<usize, Vec<(usize, usize)>> =
        std::collections::HashMap::new();
    for &(tick, v, p) in &compiled.allocation.v2p_updates {
        v2p_by_tick.entry(tick).or_default().push((v, p));
    }
    for (ti, tick) in compiled.schedule.ticks.iter().enumerate() {
        for (v, p) in v2p_by_tick.remove(&ti).unwrap_or_default() {
            jobs.push(Job::V2p { virt_bank: v, phys_bank: p });
        }
        for tr in &tick.transfers {
            jobs.push(Job::Dma {
                tile: tr.tile,
                kind: tr.kind,
                bytes: tr.bytes,
                cycles: tr.cycles,
            });
        }
        if let Some(si) = tick.compute {
            let s = &compiled.program.steps[si];
            jobs.push(Job::Compute {
                op: s.op,
                out_tile: s.out_tile,
                in_tiles: s.in_tiles.clone(),
                param_tile: s.param_tile,
                format: s.format,
                cycles: s.cycles,
            });
        }
        jobs.push(Job::Barrier);
    }
    JobProgram { jobs, model: model.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::NeutronConfig;
    use crate::compiler::{compile, CompileOptions};
    use crate::zoo;

    #[test]
    fn emit_produces_barrier_per_tick() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "mobilenet-v2");
        assert_eq!(p.tick_count(), c.schedule.ticks.len());
        let (comp, dma) = p.job_counts();
        assert_eq!(comp, c.program.steps.len());
        assert!(dma > 0);
    }

    #[test]
    fn per_op_tick_cycles_conserve_the_service_total() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let per_op = p.per_op_tick_cycles();
        assert!(!per_op.is_empty());
        assert_eq!(
            per_op.iter().map(|&(_, c)| c).sum::<u64>(),
            p.service_cycles_where(|_| true),
            "per-op breakdown must sum to the program's service time"
        );
        // No sentinel bucket for a real model program.
        assert!(per_op.iter().all(|&(op, _)| op != crate::ir::OpId(u32::MAX)));
    }

    #[test]
    fn per_op_tick_cycles_attribute_prologue_to_next_op() {
        use crate::arch::{Format, TransferKind};
        use crate::compiler::TileId;
        use crate::ir::OpId;
        // Prologue DMA tick (600), compute tick for op 0 (1000 vs 300 DMA),
        // compute tick for op 1 (200), trailing writeback tick (50).
        let p = JobProgram {
            jobs: vec![
                Job::Dma { tile: TileId(9), kind: TransferKind::Fetch, bytes: 1, cycles: 600 },
                Job::Barrier,
                Job::Dma { tile: TileId(1), kind: TransferKind::Fetch, bytes: 1, cycles: 300 },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![],
                    param_tile: None,
                    format: Format::Depth,
                    cycles: 1_000,
                },
                Job::Barrier,
                Job::Compute {
                    op: OpId(1),
                    out_tile: TileId(2),
                    in_tiles: vec![],
                    param_tile: None,
                    format: Format::Depth,
                    cycles: 200,
                },
                Job::Barrier,
                Job::Dma { tile: TileId(0), kind: TransferKind::Push, bytes: 1, cycles: 50 },
                Job::Barrier,
            ],
            model: "toy".into(),
        };
        let per_op = p.per_op_tick_cycles();
        assert_eq!(per_op, vec![(OpId(0), 1_600), (OpId(1), 250)]);
        assert_eq!(p.service_cycles_where(|_| true), 1_850);
    }

    #[test]
    fn compute_jobs_follow_their_transfers_within_tick() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        // Within each barrier-delimited group, DMA jobs are emitted before
        // the compute job (controller programs DMA first so the DAE overlap
        // starts immediately).
        let mut seen_compute = false;
        for j in &p.jobs {
            match j {
                Job::Barrier => seen_compute = false,
                Job::Compute { .. } => seen_compute = true,
                Job::Dma { .. } | Job::V2p { .. } => {
                    assert!(!seen_compute, "DMA after compute inside a tick");
                }
            }
        }
    }
}
