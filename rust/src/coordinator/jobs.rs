//! Job program: the executable form the compiler backend emits for the
//! on-device RISC-V controller (Sec. IV intro) — compute jobs, data-transfer
//! jobs, V2P updates and synchronization barriers.

use crate::arch::{Format, TransferKind};
use crate::compiler::TileId;
use crate::ir::OpId;

/// One job for the controller.
///
/// `PartialEq`/`Eq` support bit-identical program comparison — the serving
/// layer's cache-coherence property checks a cache hit against a cold
/// compile job-for-job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Job {
    /// Program the compute cores with one kernel-library call.
    Compute {
        op: OpId,
        out_tile: TileId,
        in_tiles: Vec<TileId>,
        param_tile: Option<TileId>,
        format: Format,
        /// Cycle estimate (the simulator re-derives; the runtime uses it
        /// for progress accounting).
        cycles: u64,
    },
    /// Program the DMA engine with one transfer descriptor.
    Dma { tile: TileId, kind: TransferKind, bytes: u64, cycles: u64 },
    /// Update the V2P table (idle-mode remap).
    V2p { virt_bank: usize, phys_bank: usize },
    /// Tick barrier: all jobs since the previous barrier must complete
    /// before any job after it starts (the discretized-time contract).
    Barrier,
}

/// The complete program for one inference.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct JobProgram {
    /// Job stream in controller order (barriers delimit ticks).
    pub jobs: Vec<Job>,
    /// Name of the model this program was emitted for.
    pub model: String,
}

/// Head/tail shape of a program under the tick timing model, used by the
/// serving layer to price intra-instance pipelining (overlapping one
/// request's tail with the next request's head parameter fetches).
///
/// `head_cycles` is the latency of the leading compute-less ticks (the
/// prologue: pure parameter/input prefetch, no compute engine use) — the
/// part of a request that can start while the predecessor is still
/// finishing. `tail_window_cycles` is the latency after the last tick
/// containing a counted DDR *fetch*: from there on the instance issues no
/// inbound DDR reads (only compute and writeback pushes), so a
/// successor's head fetches can share the window without contending for
/// the inbound DDR stream. Both are measured with the same `count_dma`
/// filter as [`JobProgram::service_cycles_where`], so residency-skipped
/// fetches neither extend a head nor shrink a tail window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipelineProfile {
    /// Latency of the leading compute-less (prefetch-only) ticks.
    pub head_cycles: u64,
    /// Latency after the last counted DDR-fetch tick (the fetch-free
    /// tail). Equals the whole service time when nothing is fetched.
    pub tail_window_cycles: u64,
}

impl JobProgram {
    /// Number of tick barriers (== scheduler ticks).
    pub fn tick_count(&self) -> usize {
        self.jobs.iter().filter(|j| matches!(j, Job::Barrier)).count()
    }

    /// Barrier-delimited tick slices in program order (each slice excludes
    /// its terminating [`Job::Barrier`]). The slice after the last barrier
    /// is included too — it is empty for the barrier-terminated programs
    /// [`emit`] produces, and carries the trailing unterminated tick
    /// otherwise — so walking the slices covers every job exactly once.
    /// Shared walker behind the timing queries and the executor's
    /// resumable tick loop, so the two cannot drift apart.
    pub fn tick_slices(&self) -> impl Iterator<Item = &[Job]> {
        self.jobs.split(|j| matches!(j, Job::Barrier))
    }

    /// DAE latency of one tick slice: compute and datamover overlap, so
    /// the tick costs `max(Σ compute, Σ counted DMA)`. `count_dma` selects
    /// which DMA jobs occupy the datamover (see
    /// [`JobProgram::service_cycles_where`]).
    pub fn tick_latency_where(tick: &[Job], mut count_dma: impl FnMut(&Job) -> bool) -> u64 {
        let mut compute = 0u64;
        let mut dm = 0u64;
        for job in tick {
            match job {
                Job::Compute { cycles, .. } => compute += cycles,
                Job::Dma { cycles, .. } => {
                    if count_dma(job) {
                        dm += cycles;
                    }
                }
                Job::V2p { .. } | Job::Barrier => {}
            }
        }
        compute.max(dm)
    }

    /// Tick-accurate DAE service time of this program: within each
    /// barrier-delimited tick, compute and datamover overlap
    /// (`max(compute, dm)`), and ticks sum. `count_dma` selects which DMA
    /// jobs contribute datamover cycles — the executor counts all of
    /// them, while the serving layer prices batch followers and
    /// residency-warm requests with parameter fetches excluded. Single
    /// source of truth for the tick timing model, so the consumers cannot
    /// drift apart.
    pub fn service_cycles_where(&self, mut count_dma: impl FnMut(&Job) -> bool) -> u64 {
        self.tick_slices()
            .map(|tick| Self::tick_latency_where(tick, &mut count_dma))
            .sum()
    }

    /// The pipelining shape of this program under `count_dma` — see
    /// [`PipelineProfile`]. The head stops at the first tick containing a
    /// compute job; the tail window opens after the last tick containing
    /// a *counted* DDR-fetch DMA job ([`TransferKind::uses_ddr`] and not
    /// a writeback push). `count_dma` must be a pure predicate here — it
    /// is consulted more than once per DMA job.
    pub fn pipeline_profile_where(&self, mut count_dma: impl FnMut(&Job) -> bool) -> PipelineProfile {
        let is_inbound_fetch = |j: &Job| {
            matches!(j, Job::Dma { kind, .. }
                if kind.uses_ddr() && !matches!(kind, TransferKind::Push))
        };
        let mut head_cycles = 0u64;
        let mut in_head = true;
        let mut total = 0u64;
        // Running latency up to and including the last counted-fetch tick.
        let mut through_last_fetch = 0u64;
        for tick in self.tick_slices() {
            let latency = Self::tick_latency_where(tick, &mut count_dma);
            let has_compute = tick.iter().any(|j| matches!(j, Job::Compute { .. }));
            let has_fetch = tick.iter().any(|j| is_inbound_fetch(j) && count_dma(j));
            if in_head && has_compute {
                in_head = false;
            }
            if in_head {
                head_cycles += latency;
            }
            total += latency;
            if has_fetch {
                through_last_fetch = total;
            }
        }
        PipelineProfile { head_cycles, tail_window_cycles: total - through_last_fetch }
    }

    /// The set of parameter tiles this program's compute jobs read — the
    /// tiles whose DDR fetches a residency hit (or a batch follower) can
    /// skip.
    pub fn param_tiles(&self) -> std::collections::HashSet<TileId> {
        self.jobs
            .iter()
            .filter_map(|j| match j {
                Job::Compute { param_tile, .. } => *param_tile,
                _ => None,
            })
            .collect()
    }

    /// Per-op observed service cycles under the tick timing model: each
    /// barrier-delimited tick costs `max(compute, dm)`, attributed to the
    /// tick's compute op. Compute-less ticks (prologue prefetches,
    /// conversion copies) are attributed to the *next* compute op — the
    /// transfer exists to feed it — and trailing compute-less ticks
    /// (writebacks) to the last op. Sums to
    /// `service_cycles_where(|_| true)` exactly, so the per-op breakdown
    /// never disagrees with the total the serving layer charges.
    ///
    /// The trace recorder embeds this breakdown so `neutron validate` can
    /// join compiler-predicted per-op cycles against what the executor
    /// tick path actually observed.
    pub fn per_op_tick_cycles(&self) -> Vec<(OpId, u64)> {
        let mut per_op: Vec<(OpId, u64)> = Vec::new();
        let mut charge = |op: OpId, cycles: u64, per_op: &mut Vec<(OpId, u64)>| {
            match per_op.iter_mut().find(|(o, _)| *o == op) {
                Some((_, c)) => *c += cycles,
                None => per_op.push((op, cycles)),
            }
        };
        let mut tick_compute = 0u64;
        let mut tick_dm = 0u64;
        let mut tick_op: Option<OpId> = None;
        // Cycles of compute-less ticks waiting for the next compute op.
        let mut orphan_cycles = 0u64;
        for job in &self.jobs {
            match job {
                Job::Compute { op, cycles, .. } => {
                    tick_compute += cycles;
                    tick_op = Some(*op);
                }
                Job::Dma { cycles, .. } => tick_dm += cycles,
                Job::V2p { .. } => {}
                Job::Barrier => {
                    let latency = tick_compute.max(tick_dm);
                    match tick_op {
                        Some(op) => charge(op, latency + orphan_cycles, &mut per_op),
                        None => orphan_cycles += latency,
                    }
                    if tick_op.is_some() {
                        orphan_cycles = 0;
                    }
                    tick_compute = 0;
                    tick_dm = 0;
                    tick_op = None;
                }
            }
        }
        // Unterminated trailing tick, then any leftover orphan cycles.
        let latency = tick_compute.max(tick_dm);
        match tick_op {
            Some(op) => charge(op, latency + orphan_cycles, &mut per_op),
            None => {
                orphan_cycles += latency;
                if orphan_cycles > 0 {
                    match per_op.last_mut() {
                        Some((_, c)) => *c += orphan_cycles,
                        // Program with no compute at all: bucket under a
                        // sentinel op so the total stays conserved.
                        None => per_op.push((OpId(u32::MAX), orphan_cycles)),
                    }
                }
            }
        }
        per_op
    }

    /// Compute / DMA job counts.
    pub fn job_counts(&self) -> (usize, usize) {
        let c = self.jobs.iter().filter(|j| matches!(j, Job::Compute { .. })).count();
        let d = self.jobs.iter().filter(|j| matches!(j, Job::Dma { .. })).count();
        (c, d)
    }
}

/// One KV-length bucket of a [`DecodeJob`]: the decode-step program
/// compiled at `kv_len` context rows, plus the metadata the serving layer
/// needs to price a step (which DMA jobs are the streamed KV cache, and
/// what the compiler predicted the step would cost — the sample the
/// context cost curve is fitted from).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeBucket {
    /// Context rows this bucket was compiled for; a step with
    /// `kv <= kv_len` runs on this program.
    pub kv_len: u32,
    /// The emitted single-token step program.
    pub program: JobProgram,
    /// Tiles of the streamed KV-cache input tensors — the DMA jobs a
    /// KV-resident sequence elides, exactly as weight residency elides
    /// parameter-tile fetches.
    pub kv_tiles: std::collections::HashSet<TileId>,
    /// Compiler-predicted step cycles under the artifact's calibration —
    /// joined against the observed tick service time by the context-curve
    /// fit in `trace/validate.rs`.
    pub predicted_cycles: u64,
}

impl DecodeBucket {
    /// Counted datamover cycles of the bucket's KV-cache fetches: the
    /// recompute-or-refetch price a preempted (evicted) sequence pays to
    /// re-stream its context, and the cycles a KV-resident step saves.
    pub fn kv_fetch_cycles(&self) -> u64 {
        self.program
            .jobs
            .iter()
            .filter_map(|j| match j {
                Job::Dma { tile, kind, cycles, .. }
                    if kind.uses_ddr()
                        && !matches!(kind, TransferKind::Push)
                        && self.kv_tiles.contains(tile) =>
                {
                    Some(*cycles)
                }
                _ => None,
            })
            .sum()
    }

    /// Bytes of KV cache the bucket streams from DDR, counting each KV
    /// tile once at its largest transfer (a tile re-fetched across ticks
    /// is still one resident footprint). This is the TCM footprint a
    /// KV-resident sequence occupies.
    pub fn kv_stream_bytes(&self) -> u64 {
        let mut per_tile: std::collections::HashMap<TileId, u64> =
            std::collections::HashMap::new();
        for j in &self.program.jobs {
            if let Job::Dma { tile, kind, bytes, .. } = j {
                if kind.uses_ddr()
                    && !matches!(kind, TransferKind::Push)
                    && self.kv_tiles.contains(tile)
                {
                    let e = per_tile.entry(*tile).or_insert(0);
                    *e = (*e).max(*bytes);
                }
            }
        }
        per_tile.values().sum()
    }
}

/// The per-token executable form of an autoregressive model: the prefill
/// program (prompt ingestion, produces the first token) plus decode-step
/// programs bucketed by KV-cache length. Token `t` of a sequence whose
/// context holds `kv` rows runs the smallest bucket with `kv_len >= kv`,
/// so the per-token cost is a non-decreasing staircase over the true
/// context-length cost curve — deterministic, and compiled only
/// `O(log max_context)` times per model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeJob {
    /// Model name (matches [`JobProgram::model`] of every program held).
    pub model: String,
    /// The prompt-ingestion program (the model's canonical prefill).
    pub prefill: JobProgram,
    /// Step buckets in strictly ascending `kv_len` order (non-empty).
    pub buckets: Vec<DecodeBucket>,
}

impl DecodeJob {
    /// Assemble and check the bucket invariants (non-empty, strictly
    /// ascending KV lengths).
    pub fn new(model: String, prefill: JobProgram, buckets: Vec<DecodeBucket>) -> Self {
        assert!(!buckets.is_empty(), "a decode job needs at least one step bucket");
        assert!(
            buckets.windows(2).all(|w| w[0].kv_len < w[1].kv_len),
            "decode buckets must be strictly ascending in kv_len"
        );
        Self { model, prefill, buckets }
    }

    /// The bucket serving a step over `kv` context rows: the smallest
    /// bucket with `kv_len >= kv`, saturating at the largest bucket (the
    /// serving layer clamps `kv` to `max_context` before asking).
    pub fn bucket_for(&self, kv: u32) -> &DecodeBucket {
        self.buckets
            .iter()
            .find(|b| b.kv_len >= kv)
            .unwrap_or_else(|| self.buckets.last().expect("non-empty"))
    }

    /// Largest compiled context length.
    pub fn max_kv(&self) -> u32 {
        self.buckets.last().expect("non-empty").kv_len
    }

    /// `(kv_len, predicted, observed)` per bucket — the samples the
    /// context cost curve is fitted from (observed = the bucket program's
    /// full tick service time).
    pub fn curve_samples(&self) -> Vec<(u32, u64, u64)> {
        self.buckets
            .iter()
            .map(|b| {
                (b.kv_len, b.predicted_cycles, b.program.service_cycles_where(|_| true))
            })
            .collect()
    }
}

/// Lower a compiled artifact into the job program (backend code emission).
pub fn emit(compiled: &crate::compiler::Compiled, model: &str) -> JobProgram {
    let mut jobs = Vec::new();
    // V2P updates replay grouped before their tick's barrier.
    let mut v2p_by_tick: std::collections::HashMap<usize, Vec<(usize, usize)>> =
        std::collections::HashMap::new();
    for &(tick, v, p) in &compiled.allocation.v2p_updates {
        v2p_by_tick.entry(tick).or_default().push((v, p));
    }
    for (ti, tick) in compiled.schedule.ticks.iter().enumerate() {
        for (v, p) in v2p_by_tick.remove(&ti).unwrap_or_default() {
            jobs.push(Job::V2p { virt_bank: v, phys_bank: p });
        }
        for tr in &tick.transfers {
            jobs.push(Job::Dma {
                tile: tr.tile,
                kind: tr.kind,
                bytes: tr.bytes,
                cycles: tr.cycles,
            });
        }
        if let Some(si) = tick.compute {
            let s = &compiled.program.steps[si];
            jobs.push(Job::Compute {
                op: s.op,
                out_tile: s.out_tile,
                in_tiles: s.in_tiles.clone(),
                param_tile: s.param_tile,
                format: s.format,
                cycles: s.cycles,
            });
        }
        jobs.push(Job::Barrier);
    }
    JobProgram { jobs, model: model.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::NeutronConfig;
    use crate::compiler::{compile, CompileOptions};
    use crate::zoo;

    #[test]
    fn emit_produces_barrier_per_tick() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "mobilenet-v2");
        assert_eq!(p.tick_count(), c.schedule.ticks.len());
        let (comp, dma) = p.job_counts();
        assert_eq!(comp, c.program.steps.len());
        assert!(dma > 0);
    }

    #[test]
    fn per_op_tick_cycles_conserve_the_service_total() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let per_op = p.per_op_tick_cycles();
        assert!(!per_op.is_empty());
        assert_eq!(
            per_op.iter().map(|&(_, c)| c).sum::<u64>(),
            p.service_cycles_where(|_| true),
            "per-op breakdown must sum to the program's service time"
        );
        // No sentinel bucket for a real model program.
        assert!(per_op.iter().all(|&(op, _)| op != crate::ir::OpId(u32::MAX)));
    }

    /// Prologue DMA tick (600), compute tick for op 0 (1000 vs 300 DMA),
    /// compute tick for op 1 (200), trailing writeback tick (50).
    fn toy_program() -> JobProgram {
        use crate::arch::Format;
        use crate::ir::OpId;
        JobProgram {
            jobs: vec![
                Job::Dma { tile: TileId(9), kind: TransferKind::Fetch, bytes: 1, cycles: 600 },
                Job::Barrier,
                Job::Dma { tile: TileId(1), kind: TransferKind::Fetch, bytes: 1, cycles: 300 },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![],
                    param_tile: None,
                    format: Format::Depth,
                    cycles: 1_000,
                },
                Job::Barrier,
                Job::Compute {
                    op: OpId(1),
                    out_tile: TileId(2),
                    in_tiles: vec![],
                    param_tile: None,
                    format: Format::Depth,
                    cycles: 200,
                },
                Job::Barrier,
                Job::Dma { tile: TileId(0), kind: TransferKind::Push, bytes: 1, cycles: 50 },
                Job::Barrier,
            ],
            model: "toy".into(),
        }
    }

    #[test]
    fn per_op_tick_cycles_attribute_prologue_to_next_op() {
        use crate::ir::OpId;
        let p = toy_program();
        let per_op = p.per_op_tick_cycles();
        assert_eq!(per_op, vec![(OpId(0), 1_600), (OpId(1), 250)]);
        assert_eq!(p.service_cycles_where(|_| true), 1_850);
    }

    #[test]
    fn tick_slices_cover_every_job_once() {
        let p = toy_program();
        // 4 barriers → 4 tick slices plus the empty trailing slice.
        let slices: Vec<&[Job]> = p.tick_slices().collect();
        assert_eq!(slices.len(), p.tick_count() + 1);
        assert!(slices.last().unwrap().is_empty());
        let walked: usize = slices.iter().map(|s| s.len()).sum();
        assert_eq!(walked + p.tick_count(), p.jobs.len());
        // Summing per-slice latencies is the service time, by construction.
        let summed: u64 =
            slices.iter().map(|s| JobProgram::tick_latency_where(s, |_| true)).sum();
        assert_eq!(summed, p.service_cycles_where(|_| true));
    }

    #[test]
    fn pipeline_profile_measures_head_and_fetch_free_tail() {
        let p = toy_program();
        // Head = the 600-cycle prefetch-only prologue; the last counted
        // fetch lands in the 1000-cycle tick, leaving a 200+50 tail.
        let all = p.pipeline_profile_where(|_| true);
        assert_eq!(all, PipelineProfile { head_cycles: 600, tail_window_cycles: 250 });
        // Skipping every fetch (a fully-warm request) empties the head and
        // opens the entire shortened program as a fetch-free window.
        let skip_fetches =
            |j: &Job| !matches!(j, Job::Dma { kind: TransferKind::Fetch, .. });
        assert_eq!(
            p.pipeline_profile_where(skip_fetches),
            PipelineProfile { head_cycles: 0, tail_window_cycles: 1_250 }
        );
        assert_eq!(p.service_cycles_where(skip_fetches), 1_250);
    }

    #[test]
    fn pipeline_profile_of_real_program_is_consistent() {
        let g = zoo::mobilenet::mobilenet_v2();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        let total = p.service_cycles_where(|_| true);
        let prof = p.pipeline_profile_where(|_| true);
        assert!(prof.head_cycles > 0, "emitted programs start with a prefetch tick");
        assert!(prof.head_cycles < total);
        assert!(prof.tail_window_cycles <= total);
        // Param tiles are exactly the compute steps' declared param tiles.
        let tiles = p.param_tiles();
        assert!(!tiles.is_empty());
        for s in &c.program.steps {
            if let Some(t) = s.param_tile {
                assert!(tiles.contains(&t));
            }
        }
    }

    #[test]
    fn decode_job_buckets_resolve_by_kv_length() {
        use std::collections::HashSet;
        let bucket = |kv: u32, cycles: u64| DecodeBucket {
            kv_len: kv,
            program: JobProgram {
                jobs: vec![
                    Job::Dma {
                        tile: TileId(7),
                        kind: TransferKind::Fetch,
                        bytes: 1,
                        cycles,
                    },
                    Job::Barrier,
                ],
                model: "d".into(),
            },
            kv_tiles: HashSet::from([TileId(7)]),
            predicted_cycles: cycles,
        };
        let job = DecodeJob::new(
            "d".into(),
            JobProgram::default(),
            vec![bucket(16, 100), bucket(32, 180), bucket(64, 350)],
        );
        assert_eq!(job.max_kv(), 64);
        assert_eq!(job.bucket_for(1).kv_len, 16);
        assert_eq!(job.bucket_for(16).kv_len, 16);
        assert_eq!(job.bucket_for(17).kv_len, 32);
        // Saturates at the largest bucket when asked beyond it.
        assert_eq!(job.bucket_for(1000).kv_len, 64);
        // The KV fetch cycles are the counted DDR fetches of KV tiles.
        assert_eq!(job.bucket_for(40).kv_fetch_cycles(), 350);
        let samples = job.curve_samples();
        assert_eq!(samples, vec![(16, 100, 100), (32, 180, 180), (64, 350, 350)]);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn decode_job_rejects_unsorted_buckets() {
        let b = |kv: u32| DecodeBucket {
            kv_len: kv,
            program: JobProgram::default(),
            kv_tiles: Default::default(),
            predicted_cycles: 0,
        };
        DecodeJob::new("d".into(), JobProgram::default(), vec![b(32), b(16)]);
    }

    #[test]
    fn compute_jobs_follow_their_transfers_within_tick() {
        let g = zoo::mobilenet::mobilenet_v1();
        let cfg = NeutronConfig::flagship_2tops();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let p = emit(&c, "m");
        // Within each barrier-delimited group, DMA jobs are emitted before
        // the compute job (controller programs DMA first so the DAE overlap
        // starts immediately).
        let mut seen_compute = false;
        for j in &p.jobs {
            match j {
                Job::Barrier => seen_compute = false,
                Job::Compute { .. } => seen_compute = true,
                Job::Dma { .. } | Job::V2p { .. } => {
                    assert!(!seen_compute, "DMA after compute inside a tick");
                }
            }
        }
    }
}
