//! L3 coordinator: the job-program representation the compiler backend
//! emits (compute / DMA / V2P / barrier jobs for the RISC-V controller),
//! the executor loop that drives inferences (simulated timing + PJRT
//! numerics), and runtime metrics.

pub mod executor;
pub mod jobs;
pub mod metrics;

pub use executor::{Executor, InferenceResult, ProgramRun, TickStats};
pub use jobs::{emit, DecodeBucket, DecodeJob, Job, JobProgram, PipelineProfile};
pub use metrics::Metrics;
