//! Coordinator metrics: request counters, job counts, traffic, timing.
//!
//! Aggregates are folded from per-request [`InferenceResult`]s via
//! [`Metrics::record`]; the executor never mutates individual counters
//! directly, which keeps per-request state and aggregate state consistent
//! by construction (the serving layer relies on this).

use super::executor::InferenceResult;

/// Aggregate execution metrics across requests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Requests recorded (executor runs, not batch members).
    pub requests: u64,
    /// Compute jobs dispatched across all requests.
    pub compute_jobs: u64,
    /// DMA jobs dispatched across all requests.
    pub dma_jobs: u64,
    /// V2P table remaps replayed across all requests.
    pub v2p_updates: u64,
    /// DDR bytes moved across all requests.
    pub ddr_bytes: u64,
    /// Total simulated on-device cycles across all requests.
    pub total_sim_cycles: u64,
    /// Total wall-clock host time spent driving programs, microseconds.
    pub total_host_us: u64,
}

impl Metrics {
    /// Fold one request's result into the aggregates.
    pub fn record(&mut self, r: &InferenceResult) {
        self.requests += 1;
        self.compute_jobs += r.compute_jobs;
        self.dma_jobs += r.dma_jobs;
        self.v2p_updates += r.v2p_updates;
        self.ddr_bytes += r.ddr_bytes;
        self.total_sim_cycles += r.sim_cycles;
        self.total_host_us += r.host_us;
    }

    /// Reset to the zero state (e.g. between serving epochs).
    pub fn reset(&mut self) {
        *self = Metrics::default();
    }

    /// Merge another aggregate (e.g. per-instance metrics into a fleet
    /// view).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.compute_jobs += other.compute_jobs;
        self.dma_jobs += other.dma_jobs;
        self.v2p_updates += other.v2p_updates;
        self.ddr_bytes += other.ddr_bytes;
        self.total_sim_cycles += other.total_sim_cycles;
        self.total_host_us += other.total_host_us;
    }

    /// Mean simulated latency per request, ms, at the given clock.
    pub fn mean_sim_ms(&self, freq_ghz: f64) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_sim_cycles as f64 / self.requests as f64 / (freq_ghz * 1e9) * 1e3
    }

    /// Mean host-side coordination overhead per request, µs.
    pub fn mean_host_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_host_us as f64 / self.requests as f64
    }

    /// One-line report.
    pub fn summary(&self, freq_ghz: f64) -> String {
        format!(
            "requests={} compute_jobs={} dma_jobs={} v2p={} ddr={:.1}MB sim={:.2}ms/req host={:.0}µs/req",
            self.requests,
            self.compute_jobs,
            self.dma_jobs,
            self.v2p_updates,
            self.ddr_bytes as f64 / 1e6,
            self.mean_sim_ms(freq_ghz),
            self.mean_host_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(sim_cycles: u64, host_us: u64) -> InferenceResult {
        InferenceResult {
            sim_cycles,
            host_us,
            ticks: 4,
            compute_jobs: 2,
            dma_jobs: 3,
            v2p_updates: 1,
            ddr_bytes: 100,
            ..Default::default()
        }
    }

    #[test]
    fn means_handle_zero_requests() {
        let m = Metrics::default();
        assert_eq!(m.mean_sim_ms(1.0), 0.0);
        assert_eq!(m.mean_host_us(), 0.0);
    }

    #[test]
    fn summary_mentions_requests() {
        let m = Metrics { requests: 3, total_sim_cycles: 3_000_000, ..Default::default() };
        let s = m.summary(1.0);
        assert!(s.contains("requests=3"));
        assert!(s.contains("sim=1.00ms"));
    }

    #[test]
    fn record_accumulates_across_requests() {
        let mut m = Metrics::default();
        m.record(&result(1_000, 5));
        m.record(&result(3_000, 7));
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_sim_cycles, 4_000);
        assert_eq!(m.total_host_us, 12);
        assert_eq!(m.compute_jobs, 4);
        assert_eq!(m.dma_jobs, 6);
        assert_eq!(m.v2p_updates, 2);
        assert_eq!(m.ddr_bytes, 200);
        // 2000 cycles/request at 1 GHz = 2 µs = 0.002 ms.
        assert!((m.mean_sim_ms(1.0) - 0.002).abs() < 1e-12);
    }

    #[test]
    fn reset_returns_to_zero_state() {
        let mut m = Metrics::default();
        m.record(&result(1_000, 5));
        assert_ne!(m, Metrics::default());
        m.reset();
        assert_eq!(m, Metrics::default());
        // The zero-request path stays division-safe after a reset.
        assert_eq!(m.mean_sim_ms(1.0), 0.0);
        assert_eq!(m.mean_host_us(), 0.0);
    }

    #[test]
    fn merge_combines_aggregates() {
        let mut a = Metrics::default();
        a.record(&result(1_000, 5));
        let mut b = Metrics::default();
        b.record(&result(2_000, 6));
        b.record(&result(3_000, 7));
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.total_sim_cycles, 6_000);
        assert_eq!(a.total_host_us, 18);
        assert_eq!(a.compute_jobs, 6);
    }
}
