//! Coordinator metrics: request counters, job counts, traffic, timing.

/// Aggregate execution metrics across requests.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    pub compute_jobs: u64,
    pub dma_jobs: u64,
    pub v2p_updates: u64,
    pub ddr_bytes: u64,
    pub total_sim_cycles: u64,
    pub total_host_us: u64,
}

impl Metrics {
    /// Mean simulated latency per request, ms, at the given clock.
    pub fn mean_sim_ms(&self, freq_ghz: f64) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_sim_cycles as f64 / self.requests as f64 / (freq_ghz * 1e9) * 1e3
    }

    /// Mean host-side coordination overhead per request, µs.
    pub fn mean_host_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_host_us as f64 / self.requests as f64
    }

    /// One-line report.
    pub fn summary(&self, freq_ghz: f64) -> String {
        format!(
            "requests={} compute_jobs={} dma_jobs={} v2p={} ddr={:.1}MB sim={:.2}ms/req host={:.0}µs/req",
            self.requests,
            self.compute_jobs,
            self.dma_jobs,
            self.v2p_updates,
            self.ddr_bytes as f64 / 1e6,
            self.mean_sim_ms(freq_ghz),
            self.mean_host_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_handle_zero_requests() {
        let m = Metrics::default();
        assert_eq!(m.mean_sim_ms(1.0), 0.0);
        assert_eq!(m.mean_host_us(), 0.0);
    }

    #[test]
    fn summary_mentions_requests() {
        let m = Metrics { requests: 3, total_sim_cycles: 3_000_000, ..Default::default() };
        let s = m.summary(1.0);
        assert!(s.contains("requests=3"));
        assert!(s.contains("sim=1.00ms"));
    }
}
