//! Paper-table regeneration: one function per table/figure in the paper's
//! evaluation, printing the same rows/series the paper reports. Shared by
//! the `neutron report` CLI and the `benches/` harnesses; EXPERIMENTS.md
//! records paper-vs-measured from these outputs.

use crate::arch::NeutronConfig;
use crate::baselines::{cpu, enpu, inpu, CpuConfig, EnpuConfig, InpuConfig};
use crate::compiler::{compile, CompileOptions, Compiled};
use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding};
use crate::sim::{simulate, SimOptions};
use crate::util::table::Table;
use crate::zoo::{decoder_prefill, ModelId, TransformerConfig};

/// The quickstart CNN as an IR graph — mirrors `python/compile/model.py`
/// (the timing side of the e2e example; numerics come from the artifact).
pub fn quickstart_graph(hw: usize, c_in: usize) -> Graph {
    let mut b = GraphBuilder::with_input("quickstart", hw, hw, c_in);
    b.conv("conv1", 16, ConvGeometry::square(3, 2, Padding::Same), Activation::Relu);
    b.conv("conv2", 32, ConvGeometry::square(3, 2, Padding::Same), Activation::Relu);
    b.conv("conv3", 64, ConvGeometry::square(3, 2, Padding::Same), Activation::Relu);
    b.conv("head", 10, ConvGeometry::unit(), Activation::None);
    b.global_avg_pool("gap");
    b.finish()
}

/// Compile + simulate one zoo model on the flagship config.
pub fn ours(id: ModelId) -> (Graph, Compiled, f64) {
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let r = simulate(&c, &cfg, &SimOptions::default());
    (g, c, r.latency_ms)
}

/// Table I: effective TOPS of the two industry NPUs on ResNet50V1 and
/// EfficientNet-Lite0 (paper: eNPU 4T → 0.73 / 0.82; iNPU 11T → 0.89 / 0.26).
pub fn table1() {
    let mut t = Table::new(&["NPU", "Peak TOPS", "ResNet50 V1", "EfficientNet Lite0"]);
    let models = [ModelId::ResNet50V1, ModelId::EfficientNetLite0];
    let eff = |latency_ms: f64, g: &Graph| 2.0 * g.total_macs() as f64 / (latency_ms * 1e-3) / 1e12;

    let e = EnpuConfig::enpu_b(); // the 4-TOPS eNPU of Table I
    let mut row = vec![e.name.to_string(), format!("{:.0}", e.peak_tops())];
    for id in models {
        let g = id.build();
        let r = enpu::estimate(&g, &e);
        row.push(format!("{:.2}", eff(r.latency_ms, &g)));
    }
    t.row(row);

    let i = InpuConfig::vision_11tops();
    let mut row = vec![i.name.to_string(), format!("{:.0}", i.peak_tops)];
    for id in models {
        let g = id.build();
        let r = inpu::estimate(&g, &i);
        row.push(format!("{:.2}", eff(r.latency_ms, &g)));
    }
    t.row(row);

    println!("\nTable I — effective TOPS on real-world benchmarks");
    println!("(paper: eNPU 4T → 0.73 / 0.82; iNPU 11T → 0.89 / 0.26)\n");
    t.print();
}

/// Table II: problem-partitioning impact on YOLOv8N-det compilation and
/// inference time (paper: 3480 s → 667 s compile, 23.9 → 24.7 ms infer).
/// `quick` swaps YOLOv8n for MobileNetV2 to keep CI fast.
pub fn table2(quick: bool) {
    let id = if quick { ModelId::MobileNetV2 } else { ModelId::YoloV8nDet };
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let variants: [(&str, CompileOptions); 4] = [
        ("No partitioning", CompileOptions::monolithic()),
        ("Only optimizations", CompileOptions::partition_optimizations_only()),
        ("Only scheduling", CompileOptions::partition_scheduling_only()),
        ("Both", CompileOptions::default_partitioned()),
    ];
    let mut t = Table::new(&["Problem partitioning", "Compilation Time (ms)", "Inference Time (ms)"]);
    let mut base: Option<(f64, f64)> = None;
    for (name, opts) in variants {
        let c = compile(&g, &cfg, &opts);
        let r = simulate(&c, &cfg, &SimOptions::default());
        let (ct, it) = (c.compile_ms as f64, r.latency_ms);
        let (b_ct, b_it) = *base.get_or_insert((ct, it));
        t.row(vec![
            name.to_string(),
            format!("{ct:.0} ({:+.1}%)", (ct - b_ct) / b_ct * 100.0),
            format!("{it:.2} ({:+.1}%)", (it - b_it) / b_it * 100.0),
        ]);
    }
    println!("\nTable II — problem partitioning on {} ({})", id.display_name(), if quick { "quick mode" } else { "full" });
    println!("(paper, YOLOv8n: compile 3480→667 s (−80.8%), inference 23.9→24.7 ms (+3.3%))\n");
    t.print();
}

/// Table III: latency + LTP for all 12 models × 4 NPUs.
pub fn table3() {
    let enpu_a = EnpuConfig::enpu_a();
    let enpu_b = EnpuConfig::enpu_b();
    let inpu_c = InpuConfig::vision_11tops();
    let cfg = NeutronConfig::flagship_2tops();

    let mut t = Table::new(&[
        "Model", "Ours [ms]", "LTP", "eNPU-A [ms]", "LTP", "eNPU-B [ms]", "LTP", "iNPU [ms]", "LTP",
    ]);
    let mut speedup_a = Vec::new();
    let mut speedup_b = Vec::new();
    let mut speedup_i = Vec::new();
    let mut best_ltp_ours = 0usize;

    for id in ModelId::table3() {
        let (g, _c, ours_ms) = ours(id);
        let a = enpu::estimate(&g, &enpu_a).latency_ms;
        let b = enpu::estimate(&g, &enpu_b).latency_ms;
        let i = inpu::estimate(&g, &inpu_c).latency_ms;
        let ltp = |ms: f64, tops: f64| ms * tops;
        let ltps = [
            ltp(ours_ms, cfg.peak_tops()),
            ltp(a, enpu_a.peak_tops()),
            ltp(b, enpu_b.peak_tops()),
            ltp(i, inpu_c.peak_tops),
        ];
        if ltps[0] <= ltps[1].min(ltps[2]).min(ltps[3]) {
            best_ltp_ours += 1;
        }
        speedup_a.push(a / ours_ms);
        speedup_b.push(b / ours_ms);
        speedup_i.push(i / ours_ms);
        t.row(vec![
            id.display_name().to_string(),
            format!("{ours_ms:.1}"),
            format!("{:.1}", ltps[0]),
            format!("{a:.1}"),
            format!("{:.1}", ltps[1]),
            format!("{b:.1}"),
            format!("{:.1}", ltps[2]),
            format!("{i:.1}"),
            format!("{:.1}", ltps[3]),
        ]);
    }
    println!("\nTable III — inference latency and LTP (latency·TOPS)");
    println!("(paper: 1.8x mean vs eNPU-A (max 4x); 1.3x vs eNPU-B (max 3.3x); 1.25x vs iNPU; best LTP on all rows)\n");
    t.print();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\nspeedup vs eNPU-A: mean {:.2}x max {:.2}x | vs eNPU-B: mean {:.2}x max {:.2}x | vs iNPU: mean {:.2}x max {:.2}x",
        mean(&speedup_a), max(&speedup_a),
        mean(&speedup_b), max(&speedup_b),
        mean(&speedup_i), max(&speedup_i),
    );
    println!("best LTP rows: {best_ltp_ours}/12 (paper: 12/12)");
}

/// Table IV: model characteristics (MACs, params) vs the paper's values.
pub fn table4() {
    let mut t = Table::new(&[
        "Model", "GMACs (ours)", "GMACs (paper)", "MParams (ours)", "MParams (paper)",
    ]);
    for id in ModelId::table_iv() {
        let g = id.build();
        let (gm_ref, mp_ref) = id.table_iv_reference();
        t.row(vec![
            id.display_name().to_string(),
            format!("{:.2}", g.total_macs() as f64 / 1e9),
            format!("{gm_ref:.2}"),
            format!("{:.1}", g.total_params() as f64 / 1e6),
            format!("{mp_ref:.1}"),
        ]);
    }
    println!("\nTable IV — models used for validation");
    println!("(note: paper's ResNet50 '2.0' halves the fvcore MAC count; V1-SSD uses the 6.8M-param public predictor — see EXPERIMENTS.md)\n");
    t.print();
}

/// Fig. 4: DAE pipeline vs monolithic execution — per-model latency with
/// and without compute/datamover overlap.
pub fn fig4() {
    let cfg = NeutronConfig::flagship_2tops();
    let mut t = Table::new(&["Model", "DAE [ms]", "Monolithic [ms]", "speedup"]);
    for id in [ModelId::MobileNetV1, ModelId::MobileNetV2, ModelId::ResNet50V1, ModelId::EfficientNetLite0] {
        let g = id.build();
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let dae = simulate(&c, &cfg, &SimOptions::default());
        let ser = simulate(&c, &cfg, &SimOptions { serialize_dae: true, ..Default::default() });
        t.row(vec![
            id.display_name().to_string(),
            format!("{:.2}", dae.latency_ms),
            format!("{:.2}", ser.latency_ms),
            format!("{:.2}x", ser.latency_ms / dae.latency_ms),
        ]);
    }
    println!("\nFig. 4 — decoupled access-execute vs monolithic pipeline\n");
    t.print();

    // ASCII timeline of the first ticks of MobileNetV2 (the figure's shape).
    let g = ModelId::MobileNetV2.build();
    let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let r = simulate(&c, &cfg, &SimOptions::default());
    println!("\nfirst 12 ticks (C=compute-bound, D=datamover-bound, .=idle side):");
    let mut line_c = String::from("compute : ");
    let mut line_d = String::from("datamove: ");
    for tick in r.ticks.iter().take(12) {
        let c_ch = if tick.compute_cycles == 0 { '.' } else if tick.compute_cycles >= tick.ddr_cycles { 'C' } else { 'c' };
        let d_ch = if tick.ddr_cycles + tick.tcm_copy_cycles == 0 { '.' } else if tick.ddr_cycles > tick.compute_cycles { 'D' } else { 'd' };
        line_c.push(c_ch);
        line_d.push(d_ch);
    }
    println!("{line_c}\n{line_d}");
}

/// Fig. 6: memory usage over time for the first five layers of MobileNetV2
/// with and without fusion+tiling.
pub fn fig6() {
    let cfg = NeutronConfig::flagship_2tops();
    // First five layers of MobileNetV2 (stem + ir0 expand/dw/project + ir1 expand).
    let g_full = ModelId::MobileNetV2.build();
    let mut b = GraphBuilder::with_input("mnv2_prefix", 224, 224, 3);
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), Activation::Relu6);
    b.dwconv("ir0.dw", ConvGeometry::square(3, 1, Padding::Same), Activation::Relu6);
    b.conv("ir0.project", 16, ConvGeometry::unit(), Activation::None);
    b.conv("ir1.expand", 96, ConvGeometry::unit(), Activation::Relu6);
    b.dwconv("ir1.dw", ConvGeometry::square(3, 2, Padding::Same), Activation::Relu6);
    let g = b.finish();
    let _ = g_full;

    // With the optimization: fused+tiled compile. Without: force 1-tile
    // layer-by-layer (monolithic tiles) by compiling with huge TCM and
    // replaying residency against the real capacity.
    let c_opt = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let r_opt = simulate(&c_opt, &cfg, &SimOptions::default());

    let mut cfg_big = cfg.clone();
    cfg_big.tcm_bytes = 64 << 20; // effectively infinite: no tiling/fusion pressure
    cfg_big.tcm_banks = 2048;
    let c_raw = compile(&g, &cfg_big, &CompileOptions::default_partitioned());
    let r_raw = simulate(&c_raw, &cfg_big, &SimOptions::default());

    println!("\nFig. 6 — memory over time, first 5 layers of MobileNetV2");
    println!("(paper: optimized stays within TCM; unoptimized peaks far above)\n");
    let peak_opt = r_opt.ticks.iter().map(|t| t.resident_bytes).max().unwrap_or(0);
    let peak_raw = r_raw.ticks.iter().map(|t| t.resident_bytes).max().unwrap_or(0);
    println!("TCM capacity:            {:>8} KiB", cfg.tcm_bytes / 1024);
    println!("peak memory (optimized): {:>8} KiB over {} ticks", peak_opt / 1024, r_opt.ticks.len());
    println!("peak memory (layerwise): {:>8} KiB over {} ticks", peak_raw / 1024, r_raw.ticks.len());
    println!("reduction: {:.1}x", peak_raw as f64 / peak_opt.max(1) as f64);

    // ASCII sparkline of resident KiB per tick (optimized).
    let spark = |ticks: &[crate::sim::TickTrace]| -> String {
        let max = ticks.iter().map(|t| t.resident_bytes).max().unwrap_or(1).max(1);
        ticks
            .iter()
            .map(|t| {
                let lvl = (t.resident_bytes * 7 / max) as usize;
                char::from_u32(0x2581 + lvl as u32).unwrap_or('.')
            })
            .collect()
    };
    println!("\noptimized : {}", spark(&r_opt.ticks));
    println!("layerwise : {}", spark(&r_raw.ticks));
}

/// Sec. VI Gen-AI claim: transformer GEMMs ~10× faster than 4×A55 @1.8GHz.
pub fn genai() {
    let cfg = NeutronConfig::flagship_2tops();
    let cpu_cfg = CpuConfig::quad_a55_1_8ghz();
    let mut t = Table::new(&["Workload", "NPU [ms]", "4xA55 [ms]", "speedup"]);
    for (label, tokens) in [("prefill 64 tok", 64), ("prefill 128 tok", 128), ("prefill 256 tok", 256)] {
        let g = decoder_prefill(TransformerConfig::gpt_100m(tokens));
        let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
        let r = simulate(&c, &cfg, &SimOptions::default());
        let cpu_ms = cpu::estimate_ms(&g, &cpu_cfg);
        t.row(vec![
            label.to_string(),
            format!("{:.1}", r.latency_ms),
            format!("{cpu_ms:.1}"),
            format!("{:.1}x", cpu_ms / r.latency_ms),
        ]);
    }
    println!("\nSec. VI — decoder-only transformer (~100M params) GEMMs");
    println!("(paper: \"tenfold speedups compared to execution on four Cortex-A55 cores at 1.8x the clock frequency\")\n");
    t.print();
}
