//! Persistent `.npu` artifact store: versioned binary serialization of
//! [`Compiled`] mid-end artifacts so a restarted server warms from disk
//! instead of re-running the CP solver over the model zoo.
//!
//! ## File layout
//!
//! ```text
//! magic      8 B   b"eIQ.npu\0"
//! version    u32   format version (readers accept exactly the versions
//!                  they know; everything else is VersionSkew)
//! config     u64   `serve::config_fingerprint` of the target NPU config
//! calib      u64   `serve::calibration_fingerprint` of the cost calibration
//! options    u64   `serve::options_fingerprint` of the compile budgets
//! model      str   `ModelId::slug()` the artifact was compiled from
//! sections   u32   section count, then per section:
//!                    name str · payload-length u64 · payload bytes
//! ```
//!
//! Sections: `formats`, `program`, `schedule`, `allocation`, `meta`
//! (compile_ms + inference_ms), `calibration`. All integers little-endian;
//! `f64`s stored via `to_bits` so every float round-trips bit-identically;
//! hash maps serialized in sorted key order so identical artifacts produce
//! identical bytes.
//!
//! Propagation-engine telemetry ([`crate::cp::SolveStats`]) is deliberately
//! **not** persisted: it is pure diagnostics, lives outside [`Compiled`]
//! (see `compiler::compile_with_stats`), and keeping it out of the format
//! means the incremental-solver work never perturbs artifact bytes — a
//! loaded plan stays bit-identical to the freshly compiled one.
//!
//! ## Validation contract
//!
//! A `.npu` file is *evidence* of a prior compile, so nothing is silently
//! skipped or repaired at load time: bad magic, version skew, truncation,
//! a fingerprint mismatch, a wrong model, trailing garbage inside a
//! section, or a non-finite calibration scale each reject the file with a
//! [`StoreError`] naming the offending section. The serving layer treats
//! any load error as a cache miss and recompiles — a corrupt artifact can
//! cost time, never correctness.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::arch::{Format, NeutronConfig, TransferKind};
use crate::compiler::{
    Allocation, Compiled, CompileOptions, CostCalibration, FormatPlan, Placement, Schedule,
    ScheduledTransfer, Tick,
};
use crate::compiler::{ComputeStep, Tile, TileId, TiledProgram};
use crate::ir::{OpClass, OpId, TensorId};
use crate::serve::{calibration_fingerprint, config_fingerprint};
use crate::zoo::ModelId;

/// File magic: identifies a `.npu` artifact regardless of version.
pub const NPU_MAGIC: [u8; 8] = *b"eIQ.npu\0";
/// Current format version. Readers accept exactly the versions they know.
pub const NPU_VERSION: u32 = 1;

/// Why a `.npu` artifact was rejected (or could not be written).
#[derive(Debug)]
pub enum StoreError {
    /// Filesystem error reading or writing the artifact.
    Io(std::io::Error),
    /// The file does not start with [`NPU_MAGIC`] — not a `.npu` artifact.
    BadMagic,
    /// The file's format version is not one this reader understands.
    VersionSkew {
        /// Version stamped in the file.
        found: u32,
        /// Version this reader implements.
        expected: u32,
    },
    /// The named section (or the header) ended before its payload did.
    Truncated {
        /// Section being decoded when the data ran out.
        section: &'static str,
    },
    /// The named section decoded to something structurally invalid.
    Corrupt {
        /// Section the invalid data lives in.
        section: &'static str,
        /// Human-readable description of the defect.
        detail: String,
    },
    /// A header fingerprint does not match what the loader compiled for.
    FingerprintMismatch {
        /// Which fingerprint mismatched: `"config"`, `"calibration"` or
        /// `"options"`.
        which: &'static str,
        /// Fingerprint the loader expected.
        expected: u64,
        /// Fingerprint stamped in the file.
        found: u64,
    },
    /// The artifact was compiled from a different model than requested.
    ModelMismatch {
        /// Slug the loader asked for.
        expected: String,
        /// Slug stamped in the file.
        found: String,
    },
    /// A required section is absent from the section table.
    MissingSection {
        /// Name of the absent section.
        name: &'static str,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "artifact io error: {e}"),
            StoreError::BadMagic => write!(f, "bad magic: not a .npu artifact"),
            StoreError::VersionSkew { found, expected } => {
                write!(f, "version skew: file is v{found}, reader supports v{expected}")
            }
            StoreError::Truncated { section } => {
                write!(f, "truncated artifact in section {section:?}")
            }
            StoreError::Corrupt { section, detail } => {
                write!(f, "corrupt artifact in section {section:?}: {detail}")
            }
            StoreError::FingerprintMismatch { which, expected, found } => write!(
                f,
                "{which} fingerprint mismatch: expected {expected:#018x}, file has {found:#018x}"
            ),
            StoreError::ModelMismatch { expected, found } => {
                write!(f, "model mismatch: expected {expected:?}, file has {found:?}")
            }
            StoreError::MissingSection { name } => {
                write!(f, "missing required section {name:?}")
            }
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

// --- Little-endian byte writer ---

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

// --- Checked little-endian reader scoped to one section ---

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    section: &'static str,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8], section: &'static str) -> Self {
        Self { buf, pos: 0, section }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or(StoreError::Truncated { section: self.section })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, StoreError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn usize(&mut self) -> Result<usize, StoreError> {
        Ok(self.u64()? as usize)
    }
    fn bool(&mut self) -> Result<bool, StoreError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(self.corrupt(format!("invalid bool byte {v}"))),
        }
    }
    fn str(&mut self) -> Result<String, StoreError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| self.corrupt("non-UTF-8 string".to_string()))
    }

    fn corrupt(&self, detail: impl Into<String>) -> StoreError {
        StoreError::Corrupt { section: self.section, detail: detail.into() }
    }

    /// Every section must be consumed exactly: trailing bytes are as
    /// suspicious as missing ones.
    fn finish(self) -> Result<(), StoreError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(StoreError::Corrupt {
                section: self.section,
                detail: format!("{} trailing bytes", self.buf.len() - self.pos),
            })
        }
    }
}

// --- Enum codecs ---

fn format_code(f: Format) -> u8 {
    match f {
        Format::Depth => 0,
        Format::Line => 1,
    }
}

fn format_from(code: u8, r: &Reader<'_>) -> Result<Format, StoreError> {
    match code {
        0 => Ok(Format::Depth),
        1 => Ok(Format::Line),
        v => Err(r.corrupt(format!("invalid format code {v}"))),
    }
}

fn kind_code(k: TransferKind) -> u8 {
    match k {
        TransferKind::Fetch => 0,
        TransferKind::Push => 1,
        TransferKind::LCopy => 2,
        TransferKind::LFetch => 3,
    }
}

fn kind_from(code: u8, r: &Reader<'_>) -> Result<TransferKind, StoreError> {
    match code {
        0 => Ok(TransferKind::Fetch),
        1 => Ok(TransferKind::Push),
        2 => Ok(TransferKind::LCopy),
        3 => Ok(TransferKind::LFetch),
        v => Err(r.corrupt(format!("invalid transfer kind {v}"))),
    }
}

// --- Section encoders/decoders ---

fn encode_formats(p: &FormatPlan) -> Vec<u8> {
    let mut w = Writer::new();
    let mut per_op: Vec<_> = p.per_op.iter().collect();
    per_op.sort_by_key(|&(op, _)| *op);
    w.u32(per_op.len() as u32);
    for (op, fmt) in per_op {
        w.u32(op.0);
        w.u8(format_code(*fmt));
    }
    let mut est: Vec<_> = p.est_cycles.iter().collect();
    est.sort_by_key(|&(op, _)| *op);
    w.u32(est.len() as u32);
    for (op, cycles) in est {
        w.u32(op.0);
        w.u64(*cycles);
    }
    w.u32(p.conversions.len() as u32);
    for (op, tensor, cycles) in &p.conversions {
        w.u32(op.0);
        w.u32(tensor.0);
        w.u64(*cycles);
    }
    w.buf
}

fn decode_formats(buf: &[u8]) -> Result<FormatPlan, StoreError> {
    let mut r = Reader::new(buf, "formats");
    let n = r.u32()?;
    let mut per_op = HashMap::new();
    for _ in 0..n {
        let op = OpId(r.u32()?);
        let code = r.u8()?;
        per_op.insert(op, format_from(code, &r)?);
    }
    let n = r.u32()?;
    let mut est_cycles = HashMap::new();
    for _ in 0..n {
        let op = OpId(r.u32()?);
        est_cycles.insert(op, r.u64()?);
    }
    let n = r.u32()?;
    let mut conversions = Vec::with_capacity(n as usize);
    for _ in 0..n {
        conversions.push((OpId(r.u32()?), TensorId(r.u32()?), r.u64()?));
    }
    r.finish()?;
    Ok(FormatPlan { per_op, est_cycles, conversions })
}

fn encode_program(p: &TiledProgram) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(p.tiles.len() as u32);
    for t in &p.tiles {
        w.u32(t.id.0);
        w.u32(t.tensor.0);
        w.usize(t.part.0);
        w.usize(t.part.1);
        w.usize(t.rows);
        w.u64(t.bytes);
        w.usize(t.banks);
        w.bool(t.starts_in_dram);
        w.bool(t.is_graph_output);
    }
    w.u32(p.steps.len() as u32);
    for s in &p.steps {
        w.u32(s.op.0);
        w.u32(s.out_tile.0);
        w.u32(s.in_tiles.len() as u32);
        for t in &s.in_tiles {
            w.u32(t.0);
        }
        match s.param_tile {
            Some(t) => {
                w.u8(1);
                w.u32(t.0);
            }
            None => w.u8(0),
        }
        w.u8(format_code(s.format));
        w.u64(s.cycles);
        w.bool(s.needs_line_expand);
    }
    w.u32(p.residency_banks.len() as u32);
    for &b in &p.residency_banks {
        w.usize(b);
    }
    w.buf
}

fn decode_program(buf: &[u8]) -> Result<TiledProgram, StoreError> {
    let mut r = Reader::new(buf, "program");
    let n = r.u32()?;
    let mut tiles = Vec::with_capacity(n as usize);
    for i in 0..n {
        let id = TileId(r.u32()?);
        if id.0 != i {
            return Err(r.corrupt(format!("tile {i} has id {}", id.0)));
        }
        tiles.push(Tile {
            id,
            tensor: TensorId(r.u32()?),
            part: (r.usize()?, r.usize()?),
            rows: r.usize()?,
            bytes: r.u64()?,
            banks: r.usize()?,
            starts_in_dram: r.bool()?,
            is_graph_output: r.bool()?,
        });
    }
    let n = r.u32()?;
    let mut steps = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let op = OpId(r.u32()?);
        let out_tile = TileId(r.u32()?);
        let k = r.u32()?;
        let mut in_tiles = Vec::with_capacity(k as usize);
        for _ in 0..k {
            in_tiles.push(TileId(r.u32()?));
        }
        let param_tile = match r.u8()? {
            0 => None,
            1 => Some(TileId(r.u32()?)),
            v => return Err(r.corrupt(format!("invalid option tag {v}"))),
        };
        let code = r.u8()?;
        steps.push(ComputeStep {
            op,
            out_tile,
            in_tiles,
            param_tile,
            format: format_from(code, &r)?,
            cycles: r.u64()?,
            needs_line_expand: r.bool()?,
        });
    }
    let n = r.u32()?;
    let mut residency_banks = Vec::with_capacity(n as usize);
    for _ in 0..n {
        residency_banks.push(r.usize()?);
    }
    let prog = TiledProgram { tiles, steps, residency_banks };
    for s in &prog.steps {
        let valid = |t: &TileId| t.index() < prog.tiles.len();
        if !valid(&s.out_tile)
            || !s.in_tiles.iter().all(|t| valid(t))
            || s.param_tile.as_ref().is_some_and(|t| !valid(t))
        {
            return Err(StoreError::Corrupt {
                section: "program",
                detail: format!("step for op {:?} references an out-of-range tile", s.op),
            });
        }
    }
    r.finish()?;
    Ok(prog)
}

fn encode_schedule(s: &Schedule) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(s.ticks.len() as u32);
    for t in &s.ticks {
        match t.compute {
            Some(si) => {
                w.u8(1);
                w.usize(si);
            }
            None => w.u8(0),
        }
        w.u32(t.transfers.len() as u32);
        for tr in &t.transfers {
            w.u32(tr.tile.0);
            w.u8(kind_code(tr.kind));
            w.u64(tr.cycles);
            w.u64(tr.bytes);
        }
        w.u64(t.compute_cycles);
        w.u64(t.dm_cycles);
    }
    w.u64(s.ddr.fetch_bytes);
    w.u64(s.ddr.push_bytes);
    w.u64(s.ddr.transfers);
    w.u64(s.solve_ms);
    w.usize(s.subproblems);
    w.usize(s.variables);
    w.buf
}

fn decode_schedule(buf: &[u8]) -> Result<Schedule, StoreError> {
    let mut r = Reader::new(buf, "schedule");
    let n = r.u32()?;
    let mut ticks = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let compute = match r.u8()? {
            0 => None,
            1 => Some(r.usize()?),
            v => return Err(r.corrupt(format!("invalid option tag {v}"))),
        };
        let k = r.u32()?;
        let mut transfers = Vec::with_capacity(k as usize);
        for _ in 0..k {
            let tile = TileId(r.u32()?);
            let code = r.u8()?;
            transfers.push(ScheduledTransfer {
                tile,
                kind: kind_from(code, &r)?,
                cycles: r.u64()?,
                bytes: r.u64()?,
            });
        }
        ticks.push(Tick {
            compute,
            transfers,
            compute_cycles: r.u64()?,
            dm_cycles: r.u64()?,
        });
    }
    let ddr = crate::arch::DdrTraffic {
        fetch_bytes: r.u64()?,
        push_bytes: r.u64()?,
        transfers: r.u64()?,
    };
    let sched = Schedule {
        ticks,
        ddr,
        solve_ms: r.u64()?,
        subproblems: r.usize()?,
        variables: r.usize()?,
    };
    r.finish()?;
    Ok(sched)
}

fn encode_allocation(a: &Allocation) -> Vec<u8> {
    let mut w = Writer::new();
    let mut placements: Vec<_> = a.placements.iter().collect();
    placements.sort_by_key(|&(t, _)| *t);
    w.u32(placements.len() as u32);
    for (t, p) in placements {
        w.u32(t.0);
        w.usize(p.first_bank);
        w.usize(p.banks);
    }
    w.u32(a.v2p_updates.len() as u32);
    for &(tick, vb, pb) in &a.v2p_updates {
        w.usize(tick);
        w.usize(vb);
        w.usize(pb);
    }
    w.u64(a.solve_ms);
    w.usize(a.subproblems);
    w.buf
}

fn decode_allocation(buf: &[u8]) -> Result<Allocation, StoreError> {
    let mut r = Reader::new(buf, "allocation");
    let n = r.u32()?;
    let mut placements = HashMap::new();
    for _ in 0..n {
        let t = TileId(r.u32()?);
        placements.insert(t, Placement { first_bank: r.usize()?, banks: r.usize()? });
    }
    let n = r.u32()?;
    let mut v2p_updates = Vec::with_capacity(n as usize);
    for _ in 0..n {
        v2p_updates.push((r.usize()?, r.usize()?, r.usize()?));
    }
    let alloc = Allocation {
        placements,
        v2p_updates,
        solve_ms: r.u64()?,
        subproblems: r.usize()?,
    };
    r.finish()?;
    Ok(alloc)
}

fn encode_meta(c: &Compiled) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(c.compile_ms);
    w.f64(c.inference_ms);
    w.buf
}

fn decode_meta(buf: &[u8]) -> Result<(u64, f64), StoreError> {
    let mut r = Reader::new(buf, "meta");
    let compile_ms = r.u64()?;
    let inference_ms = r.f64()?;
    r.finish()?;
    Ok((compile_ms, inference_ms))
}

fn class_code(c: OpClass) -> u8 {
    OpClass::all().iter().position(|&x| x == c).unwrap() as u8
}

fn encode_calibration(cal: &CostCalibration) -> Vec<u8> {
    let mut w = Writer::new();
    let scales = cal.scales();
    w.u32(scales.len() as u32);
    for &(class, scale) in scales {
        w.u8(class_code(class));
        w.f64(scale);
    }
    w.buf
}

fn decode_calibration(buf: &[u8]) -> Result<CostCalibration, StoreError> {
    let mut r = Reader::new(buf, "calibration");
    let n = r.u32()?;
    let classes = OpClass::all();
    let mut scales = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let code = r.u8()? as usize;
        let class = *classes
            .get(code)
            .ok_or_else(|| r.corrupt(format!("invalid op-class code {code}")))?;
        let scale = r.f64()?;
        if !scale.is_finite() || scale <= 0.0 {
            return Err(r.corrupt(format!("non-positive scale {scale} for {class:?}")));
        }
        scales.push((class, scale));
    }
    r.finish()?;
    Ok(CostCalibration::from_scales(&scales))
}

// --- Whole-artifact encode/decode ---

/// FNV-1a over 64-bit words — same construction as the serve-layer
/// fingerprints, kept local so the store has no private-item dependency.
fn fnv1a_words(words: impl IntoIterator<Item = u64>) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for w in words {
        for b in w.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    h
}

/// Fingerprint of the compile *budgets and partitioning knobs* — the
/// deterministic-compile inputs beyond config and calibration. An artifact
/// compiled under different solver node limits or window shapes would be a
/// different (still valid, but not bit-identical) plan, so the fingerprint
/// is part of the `.npu` header and checked at load.
pub fn options_fingerprint(opts: &CompileOptions) -> u64 {
    fn solver_words(s: &crate::cp::SearchConfig, out: &mut Vec<u64>) {
        out.push(u64::from(s.node_limit.is_some()));
        out.push(s.node_limit.unwrap_or(0));
        out.push(u64::from(s.time_limit_ms.is_some()));
        out.push(s.time_limit_ms.unwrap_or(0));
        out.push(u64::from(s.first_solution_only));
    }
    let mut words: Vec<u64> = Vec::new();
    words.push(u64::from(opts.tiling.partition));
    solver_words(&opts.tiling.solver, &mut words);
    words.push(u64::from(opts.scheduling.partition));
    words.push(opts.scheduling.window as u64);
    words.push(opts.scheduling.delta);
    words.push(opts.scheduling.lookahead as u64);
    solver_words(&opts.scheduling.solver, &mut words);
    solver_words(&opts.allocation_solver, &mut words);
    fnv1a_words(words)
}

/// Serialize a [`Compiled`] artifact to `.npu` bytes.
pub fn encode_npu(
    model: ModelId,
    cfg: &NeutronConfig,
    compiled: &Compiled,
    options_fp: u64,
) -> Vec<u8> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(&NPU_MAGIC);
    w.u32(NPU_VERSION);
    w.u64(config_fingerprint(cfg));
    w.u64(calibration_fingerprint(&compiled.calibration));
    w.u64(options_fp);
    w.str(model.slug());
    let sections: [(&str, Vec<u8>); 6] = [
        ("formats", encode_formats(&compiled.formats)),
        ("program", encode_program(&compiled.program)),
        ("schedule", encode_schedule(&compiled.schedule)),
        ("allocation", encode_allocation(&compiled.allocation)),
        ("meta", encode_meta(compiled)),
        ("calibration", encode_calibration(&compiled.calibration)),
    ];
    w.u32(sections.len() as u32);
    for (name, payload) in sections {
        w.str(name);
        w.u64(payload.len() as u64);
        w.buf.extend_from_slice(&payload);
    }
    w.buf
}

/// Header fields + payload of a parsed `.npu` file, before fingerprint
/// validation against a load request.
#[derive(Debug)]
pub struct NpuArtifact {
    /// Model slug stamped in the header.
    pub model_slug: String,
    /// Config fingerprint stamped in the header.
    pub config_fp: u64,
    /// Calibration fingerprint stamped in the header.
    pub calibration_fp: u64,
    /// Compile-options fingerprint stamped in the header.
    pub options_fp: u64,
    /// The decoded artifact.
    pub compiled: Compiled,
}

/// Decode `.npu` bytes into the artifact, validating structure but not
/// yet the fingerprints (see [`ArtifactStore::load`] for the full check).
pub fn decode_npu(bytes: &[u8]) -> Result<NpuArtifact, StoreError> {
    let mut r = Reader::new(bytes, "header");
    let magic = r.take(NPU_MAGIC.len()).map_err(|_| StoreError::BadMagic)?;
    if magic != NPU_MAGIC {
        return Err(StoreError::BadMagic);
    }
    let version = r.u32()?;
    if version != NPU_VERSION {
        return Err(StoreError::VersionSkew { found: version, expected: NPU_VERSION });
    }
    let config_fp = r.u64()?;
    let calibration_fp = r.u64()?;
    let options_fp = r.u64()?;
    let model_slug = r.str()?;
    let n_sections = r.u32()?;

    let mut sections: HashMap<String, &[u8]> = HashMap::new();
    for _ in 0..n_sections {
        let name = r.str()?;
        let len = r.u64()? as usize;
        // Re-scope truncation errors to the section being framed.
        let payload = {
            let sec: &'static str = match name.as_str() {
                "formats" => "formats",
                "program" => "program",
                "schedule" => "schedule",
                "allocation" => "allocation",
                "meta" => "meta",
                "calibration" => "calibration",
                other => {
                    return Err(StoreError::Corrupt {
                        section: "header",
                        detail: format!("unknown section {other:?}"),
                    })
                }
            };
            r.section = sec;
            r.take(len)?
        };
        if sections.insert(name.clone(), payload).is_some() {
            return Err(StoreError::Corrupt {
                section: "header",
                detail: format!("duplicate section {name:?}"),
            });
        }
        r.section = "header";
    }
    r.finish()?;

    let get = |name: &'static str| -> Result<&[u8], StoreError> {
        sections
            .get(name)
            .copied()
            .ok_or(StoreError::MissingSection { name })
    };
    let formats = decode_formats(get("formats")?)?;
    let program = decode_program(get("program")?)?;
    let schedule = decode_schedule(get("schedule")?)?;
    let allocation = decode_allocation(get("allocation")?)?;
    let (compile_ms, inference_ms) = decode_meta(get("meta")?)?;
    let calibration = decode_calibration(get("calibration")?)?;
    if calibration_fingerprint(&calibration) != calibration_fp {
        return Err(StoreError::Corrupt {
            section: "calibration",
            detail: "section disagrees with the header calibration fingerprint".to_string(),
        });
    }
    Ok(NpuArtifact {
        model_slug,
        config_fp,
        calibration_fp,
        options_fp,
        compiled: Compiled {
            formats,
            program,
            schedule,
            allocation,
            compile_ms,
            inference_ms,
            calibration,
        },
    })
}

/// A directory of `.npu` artifacts, one file per
/// `(model, config fingerprint, calibration fingerprint)`. This is the
/// persistent tier behind the in-memory [`crate::serve::CompileCache`]:
/// `neutron compile --save` populates it, `neutron serve --artifact-dir`
/// pre-warms from it at startup so a restarted server performs zero CP
/// solves for models it already planned.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if needed) the store directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self, StoreError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(Self { dir })
    }

    /// The store's directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Canonical artifact path for a key. The config and calibration
    /// fingerprints are part of the file name, so artifacts for different
    /// configs/calibrations of one model coexist.
    pub fn path_for(&self, model: ModelId, cfg: &NeutronConfig, calibration: &CostCalibration) -> PathBuf {
        self.dir.join(format!(
            "{}-{:016x}-{:016x}.npu",
            model.slug(),
            config_fingerprint(cfg),
            calibration_fingerprint(calibration),
        ))
    }

    /// Persist a compiled artifact. Writes to a temp file then renames, so
    /// a crashed writer never leaves a half-written `.npu` behind.
    pub fn save(
        &self,
        model: ModelId,
        cfg: &NeutronConfig,
        compiled: &Compiled,
        options_fp: u64,
    ) -> Result<PathBuf, StoreError> {
        let bytes = encode_npu(model, cfg, compiled, options_fp);
        let path = self.path_for(model, cfg, &compiled.calibration);
        let tmp = path.with_extension(format!("npu.tmp.{}", std::process::id()));
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// Load and fully validate the artifact for a key. Every rejection
    /// names its cause: wrong magic/version, truncated or corrupt
    /// sections, or header fingerprints that do not match the requested
    /// `(config, calibration, options)`.
    pub fn load(
        &self,
        model: ModelId,
        cfg: &NeutronConfig,
        calibration: &CostCalibration,
        options_fp: u64,
    ) -> Result<Compiled, StoreError> {
        let path = self.path_for(model, cfg, calibration);
        let bytes = std::fs::read(&path)?;
        let art = decode_npu(&bytes)?;
        if art.model_slug != model.slug() {
            return Err(StoreError::ModelMismatch {
                expected: model.slug().to_string(),
                found: art.model_slug,
            });
        }
        let want_cfg = config_fingerprint(cfg);
        if art.config_fp != want_cfg {
            return Err(StoreError::FingerprintMismatch {
                which: "config",
                expected: want_cfg,
                found: art.config_fp,
            });
        }
        let want_cal = calibration_fingerprint(calibration);
        if art.calibration_fp != want_cal {
            return Err(StoreError::FingerprintMismatch {
                which: "calibration",
                expected: want_cal,
                found: art.calibration_fp,
            });
        }
        if art.options_fp != options_fp {
            return Err(StoreError::FingerprintMismatch {
                which: "options",
                expected: options_fp,
                found: art.options_fp,
            });
        }
        Ok(art.compiled)
    }

    /// Does a (possibly invalid) artifact file exist for this key?
    pub fn contains(
        &self,
        model: ModelId,
        cfg: &NeutronConfig,
        calibration: &CostCalibration,
    ) -> bool {
        self.path_for(model, cfg, calibration).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::deterministic_compile_options;

    fn compile_small() -> (ModelId, NeutronConfig, CompileOptions, Compiled) {
        let model = ModelId::MobileNetV3Min;
        let cfg = NeutronConfig::flagship_2tops();
        let opts = deterministic_compile_options();
        let compiled = crate::compiler::compile(&model.build(), &cfg, &opts);
        (model, cfg, opts, compiled)
    }

    fn tmp_store(tag: &str) -> ArtifactStore {
        let dir = std::env::temp_dir().join(format!(
            "eiq_store_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ArtifactStore::open(dir).unwrap()
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let (model, cfg, opts, compiled) = compile_small();
        let store = tmp_store("roundtrip");
        let fp = options_fingerprint(&opts);
        store.save(model, &cfg, &compiled, fp).unwrap();
        let loaded = store.load(model, &cfg, &compiled.calibration, fp).unwrap();
        assert_eq!(loaded, compiled);
        assert_eq!(loaded.inference_ms.to_bits(), compiled.inference_ms.to_bits());
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let (model, cfg, opts, compiled) = compile_small();
        let fp = options_fingerprint(&opts);
        let mut bytes = encode_npu(model, &cfg, &compiled, fp);
        let mut wrong = bytes.clone();
        wrong[0] ^= 0xff;
        assert!(matches!(decode_npu(&wrong), Err(StoreError::BadMagic)));
        // Bump version.
        bytes[8] = 99;
        match decode_npu(&bytes) {
            Err(StoreError::VersionSkew { found: 99, expected: NPU_VERSION }) => {}
            other => panic!("expected version skew, got {other:?}"),
        }
    }

    #[test]
    fn truncation_names_the_section() {
        let (model, cfg, opts, compiled) = compile_small();
        let fp = options_fingerprint(&opts);
        let bytes = encode_npu(model, &cfg, &compiled, fp);
        // Chop inside the last section's payload.
        let cut = &bytes[..bytes.len() - 4];
        match decode_npu(cut) {
            Err(StoreError::Truncated { section }) => {
                assert_eq!(section, "calibration");
            }
            other => panic!("expected truncation, got {other:?}"),
        }
        // Chop in the middle: an earlier section is named.
        let cut = &bytes[..bytes.len() / 2];
        match decode_npu(cut) {
            Err(StoreError::Truncated { section }) => {
                assert!(
                    ["formats", "program", "schedule", "allocation", "meta", "calibration"]
                        .contains(&section),
                    "unexpected section {section}"
                );
            }
            other => panic!("expected truncation, got {other:?}"),
        }
    }

    #[test]
    fn fingerprint_mismatches_are_named() {
        let (model, cfg, opts, compiled) = compile_small();
        let store = tmp_store("fp");
        let fp = options_fingerprint(&opts);
        store.save(model, &cfg, &compiled, fp).unwrap();
        // Wrong options fingerprint.
        match store.load(model, &cfg, &compiled.calibration, fp ^ 1) {
            Err(StoreError::FingerprintMismatch { which: "options", .. }) => {}
            other => panic!("expected options mismatch, got {other:?}"),
        }
        // A different config resolves to a different path → io (absent).
        let other_cfg = NeutronConfig::mcu_half_tops();
        assert!(matches!(
            store.load(model, &other_cfg, &compiled.calibration, fp),
            Err(StoreError::Io(_))
        ));
        // Forge the header config fingerprint: content check still rejects.
        let path = store.path_for(model, &cfg, &compiled.calibration);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12] ^= 0xff; // first byte of config_fp
        std::fs::write(&path, &bytes).unwrap();
        match store.load(model, &cfg, &compiled.calibration, fp) {
            Err(StoreError::FingerprintMismatch { which: "config", .. }) => {}
            other => panic!("expected config mismatch, got {other:?}"),
        }
    }
}
