//! PJRT runtime layer: loads AOT-compiled HLO-text artifacts (produced once
//! by `make artifacts`) and executes them on the request path. Python is
//! never invoked at runtime.

pub mod artifact;
pub mod client;

pub use artifact::Manifest;
pub use client::{
    deterministic_i8, literal_i32_1d, literal_i8, literal_to_i32s, Executable, Runtime,
};
