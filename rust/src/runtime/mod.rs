//! PJRT runtime layer: loads AOT-compiled HLO-text artifacts (produced once
//! by `make artifacts`) and executes them on the request path. Python is
//! never invoked at runtime.
//!
//! Also home of the persistent [`ArtifactStore`] (`store`): versioned
//! `.npu` serialization of compiled mid-end artifacts, so a restarted
//! server warms its compile cache from disk instead of re-running the CP
//! solver.

pub mod artifact;
pub mod client;
pub mod store;

pub use artifact::Manifest;
pub use client::{
    deterministic_i8, literal_i32_1d, literal_i8, literal_to_i32s, Executable, Runtime,
};
pub use store::{
    decode_npu, encode_npu, options_fingerprint, ArtifactStore, NpuArtifact, StoreError,
    NPU_MAGIC, NPU_VERSION,
};
