//! PJRT runtime: load AOT-lowered HLO-text artifacts and execute them from
//! the L3 hot path. Python is never on the request path — the artifacts
//! are produced once by `make artifacts`.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`
//! (pattern from /opt/xla-example/load_hlo).

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client with a cache-free set of loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled executable (one model variant / kernel instance).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
        })
    }
}

impl Executable {
    /// Execute with literal inputs; returns the tuple elements of the
    /// (single, tupled) output — aot.py lowers with `return_tuple=True`.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("executing PJRT computation")?;
        let mut out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py always returns a tuple; decompose robustly.
        match out.decompose_tuple() {
            Ok(elems) if !elems.is_empty() => Ok(elems),
            _ => Ok(vec![out]),
        }
    }
}

/// Build an int8 literal of the given shape (the `xla` crate's `vec1` has
/// no i8 instantiation, so go through untyped bytes).
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        dims,
        bytes,
    )?)
}

/// Build an int32 literal vector.
pub fn literal_i32_1d(data: &[i32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data))
}

/// Read an i32 vector out of a literal (converting from S8/S32 payloads).
pub fn literal_to_i32s(lit: &xla::Literal) -> Result<Vec<i32>> {
    match lit.ty()? {
        xla::ElementType::S32 => Ok(lit.to_vec::<i32>()?),
        xla::ElementType::S64 => {
            // jax with x64 enabled promotes integer reductions to i64.
            let v = lit.to_vec::<i64>()?;
            Ok(v.into_iter().map(|x| x as i32).collect())
        }
        xla::ElementType::S8 => {
            let v = lit.to_vec::<i8>()?;
            Ok(v.into_iter().map(|x| x as i32).collect())
        }
        other => anyhow::bail!("unsupported literal type {other:?}"),
    }
}

/// SplitMix64 — mirrors numpy's role for deterministic check vectors. The
/// manifest seeds use numpy's PCG64 streams, so the runtime tests load the
/// expected outputs from the manifest instead of regenerating inputs; this
/// generator is only for synthetic request payloads.
pub fn deterministic_i8(seed: u64, len: usize) -> Vec<i8> {
    let mut rng = crate::util::prop::Rng::new(seed);
    (0..len).map(|_| rng.i8()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT tests are integration-level (rust/tests/runtime_integration.rs)
    // because they need built artifacts; here only the literal helpers.

    #[test]
    fn literal_shapes() {
        let l = literal_i8(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        let back = l.to_vec::<i8>().unwrap();
        assert_eq!(back, vec![1, 2, 3, 4, 5, 6]);
        let l3 = literal_i8(&vec![0i8; 24], &[2, 3, 4]).unwrap();
        assert_eq!(l3.element_count(), 24);
    }

    #[test]
    fn deterministic_payloads_repeat() {
        assert_eq!(deterministic_i8(9, 32), deterministic_i8(9, 32));
        assert_ne!(deterministic_i8(9, 32), deterministic_i8(10, 32));
    }
}
