//! Artifact discovery: locate `artifacts/` and parse the build manifest the
//! AOT exporter writes (shapes, seeds, expected outputs for self-checks).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Parsed `manifest.txt`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: HashMap<String, String>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                entries.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        Ok(Self { entries, dir })
    }

    /// Find the artifacts directory relative to the repo root (walks up
    /// from the current dir so examples/tests work from any cwd).
    pub fn discover() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return Self::load(cand);
            }
            if !dir.pop() {
                bail!("no artifacts/manifest.txt found — run `make artifacts`");
            }
        }
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.entries
            .get(key)
            .map(String::as_str)
            .with_context(|| format!("manifest key {key} missing"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        Ok(self.get(key)?.parse()?)
    }

    /// Comma-separated i32 list.
    pub fn get_i32s(&self, key: &str) -> Result<Vec<i32>> {
        self.get(key)?
            .split(',')
            .map(|s| s.trim().parse().context("bad int in manifest"))
            .collect()
    }

    /// Absolute path of an artifact file referenced by a `*.path` key.
    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.get(key)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp_manifest(content: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eiq_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        dir
    }

    #[test]
    fn parses_key_values() {
        let dir = temp_manifest("a=1\nb.path=x.hlo.txt\nlist=1,2,-3\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.get_usize("a").unwrap(), 1);
        assert_eq!(m.get_i32s("list").unwrap(), vec![1, 2, -3]);
        assert!(m.artifact_path("b.path").unwrap().ends_with("x.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_key_errors() {
        let dir = temp_manifest("a=1\n");
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
