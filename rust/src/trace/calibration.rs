//! Calibration file format: a single-line JSON document carrying the
//! fitted per-op-class cost corrections, so a fit recorded on one run can
//! be fed back into later compiles (`neutron compile|serve|replay
//! --calibration`, `neutron validate|tune --save-calibration`).
//!
//! ```json
//! {"format":"eiq-neutron-calibration","version":1,
//!  "config_fingerprint":1234,
//!  "scales":[{"class":"conv","scale":1.31},{"class":"pool","scale":2.05}]}
//! ```
//!
//! Versioning and strictness follow the trace format's rules (see
//! `trace/format.rs`): the reader accepts exactly the versions it knows,
//! unknown fields and unknown classes are hard errors, and every scale
//! must be finite, positive and inside
//! `[CostCalibration::MIN_SCALE, MAX_SCALE]` — the writer only emits
//! clamped fits, so anything outside that range is a corrupt or
//! hand-mangled file, not a fit. Scales are written in Rust's shortest
//! round-trip `f64` form, so save → load reproduces the calibration (and
//! its cache fingerprint) bit-exactly.

use anyhow::{anyhow, bail, Result};

use crate::arch::NeutronConfig;
use crate::compiler::CostCalibration;
use crate::ir::OpClass;
use crate::serve::config_fingerprint;

use super::format::Json;

/// The calibration file format version this build reads and writes.
pub const CALIBRATION_FORMAT_VERSION: u64 = 1;

/// The format name stamped into (and required from) every file.
pub const CALIBRATION_FORMAT_NAME: &str = "eiq-neutron-calibration";

/// A saved calibration: the fitted scales plus the fingerprint of the
/// config they were measured on (a fit transplanted onto a different
/// architecture would correct the wrong model, so loading checks it).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationFile {
    /// FNV-1a fingerprint of the `NeutronConfig` the fit was measured on.
    pub config_fingerprint: u64,
    /// The fitted per-class corrections.
    pub calibration: CostCalibration,
}

impl CalibrationFile {
    /// Wrap a fitted calibration for saving against `cfg`.
    pub fn new(cfg: &NeutronConfig, calibration: CostCalibration) -> Self {
        Self { config_fingerprint: config_fingerprint(cfg), calibration }
    }

    /// Serialize to the single-line JSON document (plus a trailing
    /// newline, so the file is a well-formed text file).
    pub fn to_json(&self) -> String {
        let scales = self
            .calibration
            .scales()
            .iter()
            .map(|&(class, scale)| {
                Json::Object(vec![
                    ("class".into(), Json::Str(class.name().into())),
                    ("scale".into(), Json::Float(scale)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("format".into(), Json::Str(CALIBRATION_FORMAT_NAME.into())),
            ("version".into(), Json::UInt(CALIBRATION_FORMAT_VERSION)),
            ("config_fingerprint".into(), Json::UInt(self.config_fingerprint)),
            ("scales".into(), Json::Array(scales)),
        ]);
        let mut out = doc.to_string_compact();
        out.push('\n');
        out
    }

    /// Parse a calibration file. Strict: exact format name and version,
    /// no unknown fields, known classes only, and every scale finite,
    /// positive and within the clamp range.
    pub fn parse(text: &str) -> Result<CalibrationFile> {
        let j = Json::parse(text.trim())?;
        if let Json::Object(fields) = &j {
            for (k, _) in fields {
                if !["format", "version", "config_fingerprint", "scales"]
                    .contains(&k.as_str())
                {
                    bail!("unknown field {k:?} (adding fields requires a version bump)");
                }
            }
        } else {
            bail!("calibration file must be a JSON object");
        }
        let format = j
            .req("format")?
            .as_str()
            .ok_or_else(|| anyhow!("field \"format\" must be a string"))?;
        if format != CALIBRATION_FORMAT_NAME {
            bail!("not a {CALIBRATION_FORMAT_NAME} file (format {format:?})");
        }
        let version = j
            .req("version")?
            .as_u64()
            .ok_or_else(|| anyhow!("field \"version\" must be an unsigned integer"))?;
        if version != CALIBRATION_FORMAT_VERSION {
            bail!(
                "unsupported calibration format version {version} (this build reads only \
                 version {CALIBRATION_FORMAT_VERSION})"
            );
        }
        let config_fingerprint = j
            .req("config_fingerprint")?
            .as_u64()
            .ok_or_else(|| anyhow!("field \"config_fingerprint\" must be an unsigned integer"))?;
        let mut scales: Vec<(OpClass, f64)> = Vec::new();
        for entry in j
            .req("scales")?
            .as_array()
            .ok_or_else(|| anyhow!("field \"scales\" must be an array"))?
        {
            if let Json::Object(fields) = entry {
                for (k, _) in fields {
                    if !["class", "scale"].contains(&k.as_str()) {
                        bail!("unknown scale field {k:?}");
                    }
                }
            }
            let class_name = entry
                .req("class")?
                .as_str()
                .ok_or_else(|| anyhow!("scale field \"class\" must be a string"))?;
            let class = OpClass::parse(class_name)
                .ok_or_else(|| anyhow!("unknown op class {class_name:?}"))?;
            let scale = entry
                .req("scale")?
                .as_f64()
                .ok_or_else(|| anyhow!("scale field \"scale\" must be a number"))?;
            if !scale.is_finite()
                || scale < CostCalibration::MIN_SCALE
                || scale > CostCalibration::MAX_SCALE
            {
                bail!(
                    "scale {scale} for class {class_name:?} outside the sane range \
                     [{}, {}] — corrupt file?",
                    CostCalibration::MIN_SCALE,
                    CostCalibration::MAX_SCALE
                );
            }
            if scales.iter().any(|&(c, _)| c == class) {
                bail!("duplicate scale entry for class {class_name:?}");
            }
            scales.push((class, scale));
        }
        Ok(CalibrationFile {
            config_fingerprint,
            calibration: CostCalibration::from_scales(&scales),
        })
    }

    /// The wrapped calibration, after checking the file was measured on
    /// `cfg` (a mismatching fingerprint is an error — the corrections
    /// would target the wrong architecture).
    pub fn calibration_for(&self, cfg: &NeutronConfig) -> Result<CostCalibration> {
        let live = config_fingerprint(cfg);
        if live != self.config_fingerprint {
            bail!(
                "config mismatch: calibration was fitted on config fingerprint {:#x}, \
                 compiling on {:#x} — refit on the live config",
                self.config_fingerprint,
                live
            );
        }
        Ok(self.calibration.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CalibrationFile {
        CalibrationFile::new(
            &NeutronConfig::flagship_2tops(),
            CostCalibration::from_scales(&[
                (OpClass::Conv, 1.3125),
                (OpClass::DepthwiseConv, 0.875),
                (OpClass::Pool, 2.0 / 3.0), // not exactly representable in decimal
            ]),
        )
    }

    #[test]
    fn calibration_file_round_trips_bit_exactly() {
        let f = sample();
        let text = f.to_json();
        let parsed = CalibrationFile::parse(&text).unwrap();
        assert_eq!(parsed, f);
        // The effective scales — and hence the compile-cache key — are
        // preserved exactly through the shortest-round-trip float form.
        for class in OpClass::all() {
            assert_eq!(
                parsed.calibration.scale_for(class).to_bits(),
                f.calibration.scale_for(class).to_bits()
            );
        }
    }

    #[test]
    fn identity_calibration_saves_and_loads() {
        let cfg = NeutronConfig::flagship_2tops();
        let f = CalibrationFile::new(&cfg, CostCalibration::identity());
        let parsed = CalibrationFile::parse(&f.to_json()).unwrap();
        assert!(parsed.calibration.is_identity());
        assert!(parsed.calibration_for(&cfg).unwrap().is_identity());
    }

    #[test]
    fn strict_parse_rejects_bad_files() {
        let good = sample().to_json();
        for (bad, why) in [
            (good.replace("eiq-neutron-calibration", "something-else"), "format name"),
            (good.replace("\"version\":1", "\"version\":9"), "version"),
            (good.replace("\"conv\"", "\"warp-drive\""), "unknown class"),
            (good.replace("1.3125", "400.0"), "out-of-range scale"),
            (good.replace("1.3125", "0.0"), "non-positive scale"),
            (good.replace("{\"format\"", "{\"extra\":1,\"format\""), "unknown field"),
            ("not json at all".to_string(), "garbage"),
        ] {
            assert!(CalibrationFile::parse(&bad).is_err(), "{why} should be rejected");
        }
        // Duplicate class entries are ambiguous → rejected.
        let dup = good.replace(
            "{\"class\":\"conv\",\"scale\":1.3125}",
            "{\"class\":\"conv\",\"scale\":1.3125},{\"class\":\"conv\",\"scale\":1.5}",
        );
        assert!(CalibrationFile::parse(&dup).is_err());
    }

    #[test]
    fn config_mismatch_is_refused() {
        let f = sample();
        let err = f
            .calibration_for(&NeutronConfig::mcu_half_tops())
            .unwrap_err()
            .to_string();
        assert!(err.contains("config mismatch"), "{err}");
    }
}
