//! Trace replay: feed a recorded trace back through the scheduler in
//! place of the synthetic generator, preserving the virtual-clock
//! determinism contract.
//!
//! Because every scheduling decision is a pure function of the request
//! stream, the scheduler knobs and the config (see `serve/mod.rs`),
//! replaying a trace on the config it was recorded against produces a
//! **bit-identical** [`ServeReport`] — including every `f64` percentile —
//! provided the replay compiles cold (the recorded report's cache
//! counters assume a fresh cache, which `neutron serve` and `neutron
//! record` use). The driver also cross-checks the replayed completions
//! and shed set against the recording, so a drifted timing model (code
//! changed since the trace was captured) is detected instead of silently
//! reported.

use anyhow::{bail, Result};

use crate::arch::NeutronConfig;
use crate::serve::{
    config_fingerprint, report_from_outcome, run_trace, CompileCache, ServeReport,
};

use super::format::Trace;

/// Result of a replay: the rebuilt report plus the recording cross-check.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Report built from the replayed run through the same builder
    /// `serve` uses.
    pub report: ServeReport,
    /// Description of the first divergence from the recorded completions
    /// or shed set; `None` when the replay matches the recording (or the
    /// trace carries no completions to compare against).
    pub divergence: Option<String>,
}

impl ReplayOutcome {
    /// Did the replay reproduce the recording exactly?
    pub fn matches_recording(&self) -> bool {
        self.divergence.is_none()
    }
}

/// Replays a parsed [`Trace`] through the scheduler.
pub struct ReplayDriver {
    trace: Trace,
}

impl ReplayDriver {
    /// Wrap an already-parsed trace.
    pub fn new(trace: Trace) -> Self {
        Self { trace }
    }

    /// Parse a JSONL trace and wrap it.
    pub fn from_jsonl(text: &str) -> Result<Self> {
        Ok(Self::new(Trace::parse(text)?))
    }

    /// The trace being replayed.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Replay on a fresh compile cache — the configuration under which
    /// the report is bit-identical to the recording run's.
    pub fn replay(&self, cfg: &NeutronConfig) -> Result<ReplayOutcome> {
        let mut cache = CompileCache::for_serving(cfg.clone());
        self.replay_with_cache(cfg, &mut cache)
    }

    /// Replay resolving programs through a caller-owned cache. Timing is
    /// identical to [`ReplayDriver::replay`]; only the report's
    /// cache-hit/miss counters differ when the cache is warm.
    pub fn replay_with_cache(
        &self,
        cfg: &NeutronConfig,
        cache: &mut CompileCache,
    ) -> Result<ReplayOutcome> {
        let meta = &self.trace.meta;
        let live = config_fingerprint(cfg);
        if live != meta.config_fingerprint {
            bail!(
                "config mismatch: trace was recorded on config fingerprint {:#x}, \
                 replaying on {:#x} — the timing would not be comparable",
                meta.config_fingerprint,
                live
            );
        }
        if !self
            .trace
            .requests
            .windows(2)
            .all(|w| w[0].arrival_cycles <= w[1].arrival_cycles)
        {
            bail!("trace request arrivals are not non-decreasing — corrupt or re-ordered file");
        }
        let (hits0, misses0) = (cache.hits, cache.misses);
        let outcome = run_trace(cfg, &self.trace.requests, &meta.scheduler, cache);
        let report = report_from_outcome(
            cfg,
            &meta.models,
            meta.scheduler.instances,
            &self.trace.requests,
            &outcome,
            cache.hits - hits0,
            cache.misses - misses0,
        );
        let divergence = self.first_divergence(&outcome.completions, &outcome.shed);
        Ok(ReplayOutcome { report, divergence })
    }

    /// First difference between the replayed run and the recorded one
    /// (`None` when they agree, or when the trace has nothing recorded to
    /// compare — e.g. a hand-written arrivals-only file).
    fn first_divergence(
        &self,
        completions: &[crate::serve::Completion],
        shed: &[crate::serve::Request],
    ) -> Option<String> {
        let rec = &self.trace;
        if rec.completions.is_empty() && rec.shed_ids.is_empty() {
            return None;
        }
        let replayed_shed: Vec<u64> = shed.iter().map(|r| r.id).collect();
        if replayed_shed != rec.shed_ids {
            return Some(format!(
                "shed set diverged: recorded {:?}, replayed {:?}",
                rec.shed_ids, replayed_shed
            ));
        }
        if completions.len() != rec.completions.len() {
            return Some(format!(
                "completion count diverged: recorded {}, replayed {}",
                rec.completions.len(),
                completions.len()
            ));
        }
        for (a, b) in rec.completions.iter().zip(completions) {
            if a != b {
                return Some(format!(
                    "request {} diverged: recorded finish {} on instance {}, \
                     replayed finish {} on instance {}",
                    a.id, a.finish_cycles, a.instance, b.finish_cycles, b.instance
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{SchedulerOptions, ServeOptions};
    use crate::trace::serve_recorded;
    use crate::zoo::ModelId;

    fn small_opts() -> ServeOptions {
        ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 12,
            mean_gap_cycles: 250_000,
            seed: 5,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        }
    }

    #[test]
    fn replay_reproduces_the_recorded_report_bit_for_bit() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        // Through the serialized form, as the CLI does.
        let driver = ReplayDriver::from_jsonl(&trace.to_jsonl()).unwrap();
        let replayed = driver.replay(&cfg).unwrap();
        assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
        assert_eq!(replayed.report, recorded);
    }

    #[test]
    fn replay_rejects_a_mismatching_config() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let other = NeutronConfig::mcu_half_tops();
        let err = ReplayDriver::new(trace).replay(&other).unwrap_err().to_string();
        assert!(err.contains("config mismatch"), "{err}");
    }

    #[test]
    fn tampered_trace_reports_divergence() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, mut trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        // Pretend the recording saw a different finish time.
        trace.completions[0].finish_cycles += 1;
        let out = ReplayDriver::new(trace).replay(&cfg).unwrap();
        assert!(!out.matches_recording());
        assert!(out.divergence.unwrap().contains("diverged"));
    }
}
