//! Trace replay: feed a recorded trace back through the scheduler in
//! place of the synthetic generator, preserving the virtual-clock
//! determinism contract.
//!
//! Because every scheduling decision is a pure function of the request
//! stream, the scheduler knobs and the config (see `serve/mod.rs`),
//! replaying a trace on the config it was recorded against produces a
//! **bit-identical** [`ServeReport`] — including every `f64` percentile —
//! provided the replay compiles cold (the recorded report's cache
//! counters assume a fresh cache, which `neutron serve` and `neutron
//! record` use). The driver also cross-checks the replayed completions
//! and shed set against the recording, so a drifted timing model (code
//! changed since the trace was captured) is detected instead of silently
//! reported.
//!
//! [`ReplayOptions`] bends the faithful replay in two controlled ways:
//! **speed scaling** time-warps the recorded arrival times by a factor
//! (`speed > 1` compresses gaps → higher offered load from the same
//! trace, `speed < 1` stretches them), and a **calibration** recompiles
//! every replayed model under fitted per-op-class cost corrections.
//! Either one changes the timing on purpose, so the recorded-completion
//! cross-check only runs for a faithful replay (`speed == 1`, identity
//! calibration); warped or calibrated replays are still fully
//! deterministic — same trace + same options → bit-identical report.

use anyhow::{bail, Result};

use crate::arch::NeutronConfig;
use crate::compiler::CostCalibration;
use crate::serve::{
    calibration_fingerprint, config_fingerprint, report_from_outcome, run_trace, CompileCache,
    Request, ServeReport,
};

use super::format::Trace;

/// Controlled deviations from a faithful replay (see the module docs).
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Arrival-time warp factor: each recorded arrival cycle is divided
    /// by `speed` (rounded to the nearest cycle), so `speed = 2` offers
    /// the same requests at twice the recorded rate. Must be finite and
    /// positive; `1.0` preserves the recording exactly.
    pub speed: f64,
    /// Cost calibration the replayed models are recompiled under.
    /// Identity reproduces the recorded artifacts bit for bit.
    pub calibration: CostCalibration,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        Self { speed: 1.0, calibration: CostCalibration::identity() }
    }
}

impl ReplayOptions {
    /// A faithful replay reproduces the recorded timing, so the
    /// recorded-completion cross-check applies.
    pub fn is_faithful(&self) -> bool {
        self.speed == 1.0 && self.calibration.is_identity()
    }
}

/// Result of a replay: the rebuilt report plus the recording cross-check.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Report built from the replayed run through the same builder
    /// `serve` uses.
    pub report: ServeReport,
    /// Description of the first divergence from the recorded completions
    /// or shed set; `None` when the replay matches the recording (or the
    /// trace carries no completions to compare against).
    pub divergence: Option<String>,
}

impl ReplayOutcome {
    /// Did the replay reproduce the recording exactly?
    pub fn matches_recording(&self) -> bool {
        self.divergence.is_none()
    }
}

/// Replays a parsed [`Trace`] through the scheduler.
pub struct ReplayDriver {
    trace: Trace,
}

impl ReplayDriver {
    /// Wrap an already-parsed trace.
    pub fn new(trace: Trace) -> Self {
        Self { trace }
    }

    /// Parse a JSONL trace and wrap it.
    pub fn from_jsonl(text: &str) -> Result<Self> {
        Ok(Self::new(Trace::parse(text)?))
    }

    /// The trace being replayed.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Replay on a fresh compile cache — the configuration under which
    /// the report is bit-identical to the recording run's.
    pub fn replay(&self, cfg: &NeutronConfig) -> Result<ReplayOutcome> {
        let mut cache = CompileCache::for_serving(cfg.clone());
        self.replay_with_cache(cfg, &mut cache)
    }

    /// Replay resolving programs through a caller-owned cache. Timing is
    /// identical to [`ReplayDriver::replay`]; only the report's
    /// cache-hit/miss counters differ when the cache is warm.
    pub fn replay_with_cache(
        &self,
        cfg: &NeutronConfig,
        cache: &mut CompileCache,
    ) -> Result<ReplayOutcome> {
        self.replay_with_options_cached(cfg, &ReplayOptions::default(), cache)
    }

    /// Replay under [`ReplayOptions`] on a fresh compile cache built
    /// around `opts.calibration` (calibrated and identity artifacts never
    /// share cache entries — the calibration is part of the cache key).
    pub fn replay_with_options(
        &self,
        cfg: &NeutronConfig,
        opts: &ReplayOptions,
    ) -> Result<ReplayOutcome> {
        let mut cache = CompileCache::for_serving_with(cfg.clone(), opts.calibration.clone());
        self.replay_with_options_cached(cfg, opts, &mut cache)
    }

    /// [`ReplayDriver::replay_with_options`] resolving programs through a
    /// caller-owned cache. The cache must compile under
    /// `opts.calibration` (build it with
    /// [`CompileCache::for_serving_with`]); a cache defaulting to a
    /// different calibration would price the replay against a different
    /// model than the options claim, so the mismatch is an error.
    pub fn replay_with_options_cached(
        &self,
        cfg: &NeutronConfig,
        opts: &ReplayOptions,
        cache: &mut CompileCache,
    ) -> Result<ReplayOutcome> {
        if !(opts.speed.is_finite() && opts.speed > 0.0) {
            bail!("replay speed must be finite and positive, got {}", opts.speed);
        }
        if calibration_fingerprint(cache.default_calibration())
            != calibration_fingerprint(&opts.calibration)
        {
            bail!(
                "replay cache compiles under a different calibration than the replay \
                 options — build it with CompileCache::for_serving_with(cfg, calibration)"
            );
        }
        let meta = &self.trace.meta;
        let live = config_fingerprint(cfg);
        if live != meta.config_fingerprint {
            bail!(
                "config mismatch: trace was recorded on config fingerprint {:#x}, \
                 replaying on {:#x} — the timing would not be comparable",
                meta.config_fingerprint,
                live
            );
        }
        if !self
            .trace
            .requests
            .windows(2)
            .all(|w| w[0].arrival_cycles <= w[1].arrival_cycles)
        {
            bail!("trace request arrivals are not non-decreasing — corrupt or re-ordered file");
        }
        // Time-warp: dividing every arrival by the same positive factor
        // preserves non-decreasing order (rounding a monotone sequence
        // keeps it monotone), so the warped trace is still a valid one.
        let requests: Vec<Request> = if opts.speed == 1.0 {
            self.trace.requests.clone()
        } else {
            self.trace
                .requests
                .iter()
                .map(|r| Request {
                    arrival_cycles: (r.arrival_cycles as f64 / opts.speed).round() as u64,
                    ..*r
                })
                .collect()
        };
        let (hits0, misses0) = (cache.hits, cache.misses);
        let outcome = run_trace(cfg, &requests, &meta.scheduler, cache);
        let report = report_from_outcome(
            cfg,
            &meta.models,
            meta.scheduler.instances,
            &requests,
            &outcome,
            cache.hits - hits0,
            cache.misses - misses0,
        );
        // A warped or calibrated replay deviates from the recorded timing
        // by design — only a faithful replay is held to the recording.
        let divergence = if opts.is_faithful() {
            self.first_divergence(&outcome.completions, &outcome.shed)
        } else {
            None
        };
        Ok(ReplayOutcome { report, divergence })
    }

    /// First difference between the replayed run and the recorded one
    /// (`None` when they agree, or when the trace has nothing recorded to
    /// compare — e.g. a hand-written arrivals-only file).
    fn first_divergence(
        &self,
        completions: &[crate::serve::Completion],
        shed: &[crate::serve::Request],
    ) -> Option<String> {
        let rec = &self.trace;
        if rec.completions.is_empty() && rec.shed_ids.is_empty() {
            return None;
        }
        let replayed_shed: Vec<u64> = shed.iter().map(|r| r.id).collect();
        if replayed_shed != rec.shed_ids {
            return Some(format!(
                "shed set diverged: recorded {:?}, replayed {:?}",
                rec.shed_ids, replayed_shed
            ));
        }
        if completions.len() != rec.completions.len() {
            return Some(format!(
                "completion count diverged: recorded {}, replayed {}",
                rec.completions.len(),
                completions.len()
            ));
        }
        for (a, b) in rec.completions.iter().zip(completions) {
            if a != b {
                return Some(format!(
                    "request {} diverged: recorded finish {} on instance {}, \
                     replayed finish {} on instance {}",
                    a.id, a.finish_cycles, a.instance, b.finish_cycles, b.instance
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{SchedulerOptions, ServeOptions};
    use crate::trace::serve_recorded;
    use crate::zoo::ModelId;

    fn small_opts() -> ServeOptions {
        ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 12,
            mean_gap_cycles: 250_000,
            seed: 5,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        }
    }

    #[test]
    fn replay_reproduces_the_recorded_report_bit_for_bit() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        // Through the serialized form, as the CLI does.
        let driver = ReplayDriver::from_jsonl(&trace.to_jsonl()).unwrap();
        let replayed = driver.replay(&cfg).unwrap();
        assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
        assert_eq!(replayed.report, recorded);
    }

    #[test]
    fn decode_replay_reproduces_the_recorded_report_bit_for_bit() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::GptTiny],
            requests: 5,
            mean_gap_cycles: 150_000,
            seed: 13,
            scheduler: SchedulerOptions {
                instances: 1,
                weight_residency: true,
                continuous_batch: true,
                ..SchedulerOptions::default()
            },
            decode: true,
            prompt_tokens: 5,
            decode_tokens: 4,
            max_context: 16,
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded, trace) = serve_recorded(&cfg, &opts, &mut cache);
        assert!(recorded.decode_requests == 5);
        assert!(recorded.tokens_generated > recorded.completed);
        // Through the serialized v3 form: decode requests, first-token
        // and KV-refetch fields all survive the round trip, and the
        // replayed decode rounds land on identical cycles.
        let driver = ReplayDriver::from_jsonl(&trace.to_jsonl()).unwrap();
        let replayed = driver.replay(&cfg).unwrap();
        assert!(replayed.matches_recording(), "{:?}", replayed.divergence);
        assert_eq!(replayed.report, recorded);
    }

    #[test]
    fn replay_rejects_a_mismatching_config() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let other = NeutronConfig::mcu_half_tops();
        let err = ReplayDriver::new(trace).replay(&other).unwrap_err().to_string();
        assert!(err.contains("config mismatch"), "{err}");
    }

    #[test]
    fn speed_scaling_is_deterministic_and_raises_offered_load() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let driver = ReplayDriver::new(trace);
        let base = driver.replay(&cfg).unwrap();
        assert!(base.report.offered_load_inf_s > 0.0);

        let fast = ReplayOptions { speed: 2.0, ..ReplayOptions::default() };
        let a = driver.replay_with_options(&cfg, &fast).unwrap();
        let b = driver.replay_with_options(&cfg, &fast).unwrap();
        assert_eq!(a.report, b.report, "warped replay must be deterministic");
        // Halving every arrival gap strictly raises the offered load.
        assert!(
            a.report.offered_load_inf_s > base.report.offered_load_inf_s,
            "{} !> {}",
            a.report.offered_load_inf_s,
            base.report.offered_load_inf_s
        );
        assert_eq!(a.report.offered, base.report.offered, "same requests, warped arrivals");
        // A warped replay is not held to the recorded completions.
        assert!(a.matches_recording());

        // speed 1.0 through the options path is the faithful replay.
        let one = driver
            .replay_with_options(&cfg, &ReplayOptions::default())
            .unwrap();
        assert_eq!(one.report, base.report);
        assert!(one.matches_recording());
    }

    #[test]
    fn degenerate_speed_is_rejected() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let driver = ReplayDriver::new(trace);
        for speed in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let opts = ReplayOptions { speed, ..ReplayOptions::default() };
            assert!(driver.replay_with_options(&cfg, &opts).is_err(), "speed {speed}");
        }
    }

    #[test]
    fn calibrated_replay_is_deterministic_and_skips_the_cross_check() {
        use crate::compiler::CostCalibration;
        use crate::ir::OpClass;
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let driver = ReplayDriver::new(trace);
        let opts = ReplayOptions {
            calibration: CostCalibration::from_scales(&[
                (OpClass::Conv, 1.5),
                (OpClass::DepthwiseConv, 1.5),
            ]),
            ..ReplayOptions::default()
        };
        let a = driver.replay_with_options(&cfg, &opts).unwrap();
        let b = driver.replay_with_options(&cfg, &opts).unwrap();
        assert_eq!(a.report, b.report);
        // Calibrated timing deviates from the recording on purpose — the
        // driver must not flag that as divergence.
        assert!(a.matches_recording());
        assert_eq!(a.report.offered, a.report.completed + a.report.shed);
    }

    #[test]
    fn mismatched_cache_calibration_is_rejected() {
        use crate::compiler::CostCalibration;
        use crate::ir::OpClass;
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        let driver = ReplayDriver::new(trace);
        // An identity cache cannot honor calibrated replay options.
        let opts = ReplayOptions {
            calibration: CostCalibration::from_scales(&[(OpClass::Conv, 1.5)]),
            ..ReplayOptions::default()
        };
        let err = driver
            .replay_with_options_cached(&cfg, &opts, &mut cache)
            .unwrap_err()
            .to_string();
        assert!(err.contains("different calibration"), "{err}");
        // An explicit all-1.0 calibration IS the identity: it prices
        // identically, fingerprints identically, and replays faithfully.
        let spelled = ReplayOptions {
            calibration: CostCalibration::from_scales(&[(OpClass::Conv, 1.0)]),
            ..ReplayOptions::default()
        };
        assert!(spelled.is_faithful());
        let out = driver
            .replay_with_options_cached(&cfg, &spelled, &mut cache)
            .unwrap();
        assert!(out.matches_recording(), "{:?}", out.divergence);
    }

    #[test]
    fn tampered_trace_reports_divergence() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (_, mut trace) = serve_recorded(&cfg, &small_opts(), &mut cache);
        // Pretend the recording saw a different finish time.
        trace.completions[0].finish_cycles += 1;
        let out = ReplayDriver::new(trace).replay(&cfg).unwrap();
        assert!(!out.matches_recording());
        assert!(out.divergence.unwrap().contains("diverged"));
    }
}
