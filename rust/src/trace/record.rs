//! Trace capture: a [`TraceRecorder`] hooked into the serving event loop
//! (`serve::run_trace_recorded`) so any serve run can emit a replayable
//! JSONL trace.
//!
//! The recorder is an observer: it never changes a scheduling decision.
//! It captures three things — every offered request at admission time
//! (arrival order), the outcome (completions in dispatch order + the shed
//! set), and, the first time each model's cached program is resolved, that
//! model's per-op predicted-vs-observed cycle profile: predictions from
//! the cost model the artifact was compiled under
//! (`compiler::calibrated_layer_latency_cycles` with the artifact's own
//! `Compiled::calibration`) joined against the executor tick path's
//! attribution (`JobProgram::per_op_tick_cycles`).

use crate::arch::NeutronConfig;
use crate::compiler::calibrated_layer_latency_cycles;
use crate::serve::{
    config_fingerprint, serve_with_cache_recorded, CachedModel, CompileCache, Request,
    SchedulerOptions, ServeOptions, ServeReport, TraceOutcome,
};
use crate::zoo::ModelId;

use super::format::{ModelOps, OpRecord, Trace, TraceMeta, TRACE_FORMAT_VERSION};

/// Records a serving run into a [`Trace`]. Create one per run, pass it to
/// `serve::run_trace_recorded` (or use [`serve_recorded`]), then call
/// [`TraceRecorder::finish`].
pub struct TraceRecorder {
    trace: Trace,
}

impl TraceRecorder {
    /// Start a recording for a run over `models` under `scheduler` on
    /// `cfg`. `seed` is informational (the actual requests are recorded).
    pub fn new(
        cfg: &NeutronConfig,
        models: &[ModelId],
        seed: u64,
        scheduler: &SchedulerOptions,
    ) -> Self {
        Self {
            trace: Trace {
                meta: TraceMeta {
                    version: TRACE_FORMAT_VERSION,
                    config_fingerprint: config_fingerprint(cfg),
                    freq_ghz: cfg.freq_ghz,
                    seed,
                    models: models.to_vec(),
                    scheduler: scheduler.clone(),
                },
                requests: Vec::new(),
                shed_ids: Vec::new(),
                completions: Vec::new(),
                model_ops: Vec::new(),
            },
        }
    }

    /// Record one offered request (called in admission order).
    pub fn record_request(&mut self, request: &Request) {
        self.trace.requests.push(*request);
    }

    /// Record a model's per-op cycle profile the first time its cached
    /// program is dispatched; later calls for the same model are no-ops.
    pub fn record_model_profile(&mut self, cfg: &NeutronConfig, entry: &CachedModel) {
        if self.trace.model_ops.iter().any(|m| m.model == entry.model) {
            return;
        }
        self.trace.model_ops.push(ModelOps {
            model: entry.model,
            ops: profile_model_ops(cfg, entry),
        });
    }

    /// Fold the run's outcome in: completions (dispatch order) and the
    /// ids of every shed request.
    pub fn record_outcome(&mut self, outcome: &TraceOutcome) {
        self.trace.completions.extend(outcome.completions.iter().copied());
        self.trace.shed_ids.extend(outcome.shed.iter().map(|r| r.id));
    }

    /// The finished trace.
    pub fn finish(self) -> Trace {
        self.trace
    }
}

/// Per-op predicted-vs-observed records for one cached model: observed
/// cycles from the tick timing model's per-op attribution, predictions
/// from the layer cost under the format the compiler actually selected
/// **and the calibration the artifact was compiled with**
/// (`Compiled::calibration`) — the join always compares what the compiler
/// believed against what the tick path charged, whether or not a fitted
/// calibration was in force. The sentinel bucket `per_op_tick_cycles`
/// uses for compute-free programs is skipped (real model programs never
/// produce it).
pub fn profile_model_ops(cfg: &NeutronConfig, entry: &CachedModel) -> Vec<OpRecord> {
    let graph = entry.model.build();
    entry
        .program
        .per_op_tick_cycles()
        .into_iter()
        .filter(|(op, _)| op.0 != u32::MAX)
        .map(|(op_id, observed)| {
            let op = graph.op(op_id);
            let format = entry.compiled.formats.format_of(op_id);
            OpRecord {
                op: op_id.0,
                class: op.class(),
                predicted_cycles: calibrated_layer_latency_cycles(
                    &graph,
                    op,
                    cfg,
                    format,
                    &entry.compiled.calibration,
                ),
                observed_cycles: observed,
            }
        })
        .collect()
}

/// [`serve::serve_with_cache`](crate::serve::serve_with_cache) with
/// recording: runs the synthetic trace described by `opts` and returns
/// both the report and the replayable [`Trace`].
///
/// For the replayed report to be bit-identical (`neutron replay`), the
/// recording run must start from a **fresh** cache — the report's
/// cache-hit/miss counters are part of the comparison, and replay always
/// compiles cold.
pub fn serve_recorded(
    cfg: &NeutronConfig,
    opts: &ServeOptions,
    cache: &mut CompileCache,
) -> (ServeReport, Trace) {
    let mut recorder = TraceRecorder::new(cfg, &opts.models, opts.seed, &opts.scheduler);
    let report = serve_with_cache_recorded(cfg, opts, cache, Some(&mut recorder));
    (report, recorder.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::serve_with_cache;

    #[test]
    fn recording_is_an_observer_and_captures_the_run() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 16,
            mean_gap_cycles: 300_000,
            seed: 21,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        let (recorded_report, trace) = serve_recorded(&cfg, &opts, &mut cache);
        // An unrecorded run of the same scenario is unchanged by the
        // recorder (fresh cache so the hit/miss deltas match too).
        let mut cache2 = CompileCache::for_serving(cfg.clone());
        let plain = serve_with_cache(&cfg, &opts, &mut cache2);
        assert_eq!(recorded_report, plain, "recording must not perturb the run");

        assert_eq!(trace.requests.len(), 16);
        assert_eq!(trace.completions.len() + trace.shed_ids.len(), 16);
        assert_eq!(trace.meta.models, opts.models);
        assert_eq!(trace.meta.scheduler, opts.scheduler);
        assert_eq!(trace.meta.config_fingerprint, config_fingerprint(&cfg));
        // Every dispatched model carries an op profile whose observed
        // cycles sum to the program's tick service time.
        assert!(!trace.model_ops.is_empty() && trace.model_ops.len() <= 2);
        for m in &trace.model_ops {
            let entry = cache.get(m.model);
            let total: u64 = m.ops.iter().map(|o| o.observed_cycles).sum();
            assert_eq!(total, entry.program.service_cycles_where(|_| true));
            assert!(m.ops.iter().all(|o| o.predicted_cycles > 0));
        }
    }

    #[test]
    fn model_profile_recorded_once_per_model() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut cache = CompileCache::for_serving(cfg.clone());
        let entry = cache.get(ModelId::MobileNetV3Min);
        let mut rec = TraceRecorder::new(
            &cfg,
            &[ModelId::MobileNetV3Min],
            0,
            &SchedulerOptions::default(),
        );
        rec.record_model_profile(&cfg, &entry);
        rec.record_model_profile(&cfg, &entry);
        assert_eq!(rec.finish().model_ops.len(), 1);
    }
}
