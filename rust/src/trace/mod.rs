//! Trace capture, replay and timing-model calibration.
//!
//! The serving layer simulates heavy multi-tenant traffic, and the
//! compiler's CP formulation optimizes against an analytic per-operator
//! cost model — but nothing in the base stack ever checks either against
//! the other. This subsystem closes the loop, following the
//! measure-then-model methodology of edge-AI benchmarking:
//!
//! * [`format`] — a versioned, self-describing JSONL trace format
//!   (hand-rolled serializer/parser, zero new dependencies) recording
//!   offered requests, completions, the shed set and per-operator
//!   observed cycles;
//! * [`record`] — a [`TraceRecorder`] hooked into the serving event loop
//!   (`serve::run_trace_recorded`), so any `neutron serve` run can emit a
//!   replayable trace (`--record`, or the `neutron record` subcommand);
//! * [`replay`] — a [`ReplayDriver`] that feeds a recorded trace back
//!   through the scheduler in place of the synthetic generator. Same
//!   trace file + same config → **bit-identical** `ServeReport`
//!   (cross-checked against the recorded completions, so timing-model
//!   drift is detected);
//! * [`validate`] — a calibration pass joining compiler-predicted per-op
//!   cycles against the executor tick path's observations, reporting
//!   per-op-class MAPE/bias tables and fitting the linear corrections
//!   `compiler::CostCalibration` applies (`neutron validate`);
//! * [`calibration`] — a versioned single-line JSON file format for
//!   fitted calibrations, so a fit travels from `neutron validate
//!   --save-calibration` to `neutron compile|serve|replay --calibration`;
//! * [`tune`] — the closed record → fit → recompile → replay loop
//!   (`neutron tune`): fit a guarded calibration from a recorded trace,
//!   recompile every model under it, replay the same requests and report
//!   per-op-class MAPE and makespan before vs after.
//!
//! The same loop calibrates the energy model: a trace recorded with
//! `--energy` carries per-completion femtojoule attribution, `neutron
//! validate --energy` fits the per-channel [`EnergyFitReport`] /
//! `energy::EnergyCalibration` (saved in its own strict single-line JSON
//! format, fingerprint-pinned like the timing calibration), and `neutron
//! tune --energy` reports the energy MAPE before vs after the guarded
//! fit — no recompile leg, because the energy calibration corrects
//! analytic predictions only and replay stays bit-identical.

#![warn(missing_docs)]

pub mod calibration;
pub mod format;
pub mod record;
pub mod replay;
pub mod tune;
pub mod validate;

pub use calibration::{CalibrationFile, CALIBRATION_FORMAT_NAME, CALIBRATION_FORMAT_VERSION};
pub use format::{Json, ModelOps, OpRecord, Trace, TraceMeta, TRACE_FORMAT_NAME, TRACE_FORMAT_VERSION};
pub use record::{profile_model_ops, serve_recorded, TraceRecorder};
pub use replay::{ReplayDriver, ReplayOptions, ReplayOutcome};
pub use tune::{tune_energy_from_trace, tune_from_trace, EnergyTuneOutcome, TuneOutcome};
pub use validate::{
    energy_pairs_from_trace, ClassCalibrationRow, DecodeCurveReport, EnergyChannelRow,
    EnergyFitReport, ValidationReport,
};
