//! Timing-model calibration: join compiler-predicted per-op cycles
//! against the sim-observed per-op tick cycles of a recorded trace (or of
//! freshly compiled models), report per-op-class error statistics, and
//! fit the per-class linear corrections `compiler::CostCalibration`
//! applies.
//!
//! Statistics per [`OpClass`], in `OpClass::all()` order (classes with no
//! ops are omitted):
//!
//! * **MAPE** — mean over ops of `|predicted − observed| / observed`, as
//!   a percentage (ops whose observed cycles are 0 are excluded from the
//!   mean; they cannot be scored multiplicatively);
//! * **bias** — `(Σ observed / Σ predicted − 1)` as a percentage:
//!   positive means the cost model under-predicts the class;
//! * **scale** — least-squares fit through the origin of
//!   `observed ≈ scale · predicted` (`Σ pred·obs / Σ pred²`), the
//!   correction [`ValidationReport::calibration`] hands to the compiler.
//!   Degenerate fits (no predicted cycles, non-finite or non-positive
//!   slope) fall back to 1.0, and every fit is clamped into
//!   `[CostCalibration::MIN_SCALE, CostCalibration::MAX_SCALE]`, so a
//!   degenerate trace can never hand compilation a wild correction.
//!
//! [`ValidationReport::calibration_guarded`] additionally drops any class
//! whose fitted scale does not improve that class's MAPE on the joined
//! data (a single least-squares slope minimizes squared error, not MAPE,
//! so a heterogeneous class can fit a slope that makes its MAPE worse) —
//! the form the tune loop feeds back into compilation.
//!
//! [`EnergyFitReport`] runs the same machinery over energy instead of
//! cycles: per-[`EnergyChannel`] least-squares scales joining the
//! analytic joules predictor against the per-completion energy a trace
//! recorded with `--energy` observed, with the same clamp and
//! improve-only guard feeding [`EnergyCalibration`].

use anyhow::{bail, Result};

use crate::arch::NeutronConfig;
use crate::compiler::{ContextCurve, CostCalibration};
use crate::energy::{EnergyBreakdown, EnergyCalibration, EnergyChannel, EnergyModel};
use crate::ir::OpClass;
use crate::serve::CompileCache;
use crate::util::table::Table;
use crate::zoo::{decoder_decode_step, ModelId};

use super::format::Trace;
use super::record::profile_model_ops;

/// Per-class predicted-vs-observed statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassCalibrationRow {
    /// The op class this row describes.
    pub class: OpClass,
    /// Ops of this class that were joined.
    pub ops: usize,
    /// Total compiler-predicted cycles across those ops.
    pub predicted_cycles: u64,
    /// Total sim-observed (tick-attributed) cycles across those ops.
    pub observed_cycles: u64,
    /// Mean absolute percentage error of the raw cost model.
    pub mape_pct: f64,
    /// MAPE of this class after applying its own fitted scale — compare
    /// against [`ClassCalibrationRow::mape_pct`] to see whether the fit
    /// helps this class (the guarded calibration keeps only scales that
    /// do).
    pub post_fit_mape_pct: f64,
    /// Aggregate bias: positive = the model under-predicts this class.
    pub bias_pct: f64,
    /// Fitted linear correction (`observed ≈ scale · predicted`),
    /// clamped into `[CostCalibration::MIN_SCALE, MAX_SCALE]`.
    pub scale: f64,
}

/// The calibration pass's result: per-class rows plus the overall error
/// before and after applying the fitted corrections.
#[derive(Debug, Clone, PartialEq)]
pub struct ValidationReport {
    /// One row per op class with at least one joined op.
    pub rows: Vec<ClassCalibrationRow>,
    /// MAPE over every scored op, raw cost model.
    pub overall_mape_pct: f64,
    /// MAPE over every scored op after applying the fitted per-class
    /// scales — the number that shows the fit helped.
    pub post_fit_mape_pct: f64,
}

impl ValidationReport {
    /// Build from raw `(class, predicted, observed)` tuples.
    pub fn from_pairs(pairs: &[(OpClass, u64, u64)]) -> Self {
        let mut rows = Vec::new();
        for class in OpClass::all() {
            let of_class: Vec<&(OpClass, u64, u64)> =
                pairs.iter().filter(|(c, _, _)| *c == class).collect();
            if of_class.is_empty() {
                continue;
            }
            let predicted: u64 = of_class.iter().map(|(_, p, _)| p).sum();
            let observed: u64 = of_class.iter().map(|(_, _, o)| o).sum();
            let scale = fit_scale(of_class.iter().map(|&&(_, p, o)| (p, o)));
            rows.push(ClassCalibrationRow {
                class,
                ops: of_class.len(),
                predicted_cycles: predicted,
                observed_cycles: observed,
                mape_pct: mape(of_class.iter().map(|&&(_, p, o)| (p as f64, o))),
                post_fit_mape_pct: mape(
                    of_class.iter().map(|&&(_, p, o)| (p as f64 * scale, o)),
                ),
                bias_pct: if predicted == 0 {
                    0.0
                } else {
                    (observed as f64 / predicted as f64 - 1.0) * 100.0
                },
                scale,
            });
        }
        let scale_of = |class: OpClass| {
            rows.iter().find(|r| r.class == class).map(|r| r.scale).unwrap_or(1.0)
        };
        ValidationReport {
            overall_mape_pct: mape(pairs.iter().map(|&(_, p, o)| (p as f64, o))),
            post_fit_mape_pct: mape(
                pairs.iter().map(|&(c, p, o)| (p as f64 * scale_of(c), o)),
            ),
            rows,
        }
    }

    /// Build from a recorded trace's per-model op profiles. Fails when
    /// the trace carries no `ops` events (nothing was dispatched, or the
    /// file was stripped).
    pub fn from_trace(trace: &Trace) -> Result<Self> {
        let pairs: Vec<(OpClass, u64, u64)> = trace
            .model_ops
            .iter()
            .flat_map(|m| {
                m.ops
                    .iter()
                    .map(|o| (o.class, o.predicted_cycles, o.observed_cycles))
            })
            .collect();
        if pairs.is_empty() {
            bail!("trace carries no per-op profiles (no model was ever dispatched)");
        }
        Ok(Self::from_pairs(&pairs))
    }

    /// Compile `models` under the deterministic serving options and
    /// validate their cost predictions directly (no trace needed).
    /// Duplicate entries collapse onto their first occurrence (matching
    /// the serve report builder), so repeating a model never double-counts
    /// its ops.
    pub fn from_models(models: &[ModelId], cfg: &NeutronConfig) -> Self {
        let mut cache = CompileCache::for_serving(cfg.clone());
        let mut seen: Vec<ModelId> = Vec::new();
        let mut pairs: Vec<(OpClass, u64, u64)> = Vec::new();
        for &model in models {
            if seen.contains(&model) {
                continue;
            }
            seen.push(model);
            let entry = cache.get(model);
            pairs.extend(
                profile_model_ops(cfg, &entry)
                    .into_iter()
                    .map(|o| (o.class, o.predicted_cycles, o.observed_cycles)),
            );
        }
        Self::from_pairs(&pairs)
    }

    /// The fitted per-class corrections, ready for
    /// `compiler::calibrated_layer_latency_cycles`.
    pub fn calibration(&self) -> CostCalibration {
        CostCalibration::from_scales(
            &self.rows.iter().map(|r| (r.class, r.scale)).collect::<Vec<_>>(),
        )
    }

    /// The fitted corrections with the improve-only guard applied: a
    /// class keeps its scale only when the fit does not worsen that
    /// class's MAPE on the joined data (see the module docs). This is the
    /// calibration the tune loop compiles under and the calibration-file
    /// writer saves — on the data it was fitted from, applying it can
    /// only lower (or keep) every class's MAPE. No-op scales (exactly
    /// 1.0) are dropped, so an ineffective fit is exactly the identity
    /// calibration.
    pub fn calibration_guarded(&self) -> CostCalibration {
        CostCalibration::from_scales(
            &self
                .rows
                .iter()
                .filter(|r| r.scale != 1.0 && r.post_fit_mape_pct <= r.mape_pct)
                .map(|r| (r.class, r.scale))
                .collect::<Vec<_>>(),
        )
    }

    /// Render the paper-style predicted-vs-observed table plus the
    /// overall MAPE before/after calibration.
    pub fn table(&self) -> String {
        let mut t = Table::new(&[
            "op class",
            "ops",
            "predicted cyc",
            "observed cyc",
            "MAPE %",
            "fit MAPE %",
            "bias %",
            "fit scale",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.class.name().to_string(),
                r.ops.to_string(),
                r.predicted_cycles.to_string(),
                r.observed_cycles.to_string(),
                format!("{:.1}", r.mape_pct),
                format!("{:.1}", r.post_fit_mape_pct),
                format!("{:+.1}", r.bias_pct),
                format!("{:.3}", r.scale),
            ]);
        }
        format!(
            "{}overall MAPE: {:.1}%  →  {:.1}% after per-class calibration\n",
            t.render(),
            self.overall_mape_pct,
            self.post_fit_mape_pct
        )
    }
}

/// Context-length cost-curve validation for one decode-capable model:
/// the per-bucket `(kv_len, predicted, observed)` samples of its compiled
/// decode ladder, the [`ContextCurve`] OLS-fitted to the observed tick
/// cycles, and the error of both the fitted line and the compiler's
/// per-bucket predictions against the observations. This is the decode
/// analogue of [`ValidationReport`]: where the per-op join scores the
/// cost model op by op, this scores the `base + slope·kv` abstraction the
/// serving layer uses to reason about growing contexts.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeCurveReport {
    /// The decode-capable model the ladder belongs to.
    pub model: ModelId,
    /// `(kv_len, predicted, observed)` per compiled bucket, ascending
    /// KV length (see `coordinator::DecodeJob::curve_samples`).
    pub samples: Vec<(u32, u64, u64)>,
    /// Line fitted to the observed cycles; `None` when the ladder is
    /// degenerate (a single bucket fits no slope).
    pub curve: Option<ContextCurve>,
    /// MAPE of the fitted line against the observed samples (0 without a
    /// curve).
    pub fit_mape_pct: f64,
    /// MAPE of the compiler's per-bucket predictions against the
    /// observed tick cycles.
    pub predicted_mape_pct: f64,
}

impl DecodeCurveReport {
    /// Compile `model`'s decode ladder up to `max_context` under the
    /// deterministic serving options and validate its context curve.
    /// Panics (inside the compile cache) when the model has no decode
    /// configuration.
    pub fn from_model(model: ModelId, max_context: u32, cfg: &NeutronConfig) -> Self {
        let mut cache = CompileCache::for_serving(cfg.clone());
        let job = cache.get_decode(model, max_context);
        Self::from_samples(model, &job.curve_samples())
    }

    /// Build from already-collected per-bucket samples.
    pub fn from_samples(model: ModelId, samples: &[(u32, u64, u64)]) -> Self {
        let observed: Vec<(u32, u64)> = samples.iter().map(|&(kv, _, o)| (kv, o)).collect();
        let curve = ContextCurve::fit(&observed);
        DecodeCurveReport {
            model,
            samples: samples.to_vec(),
            fit_mape_pct: curve.as_ref().map(|c| c.mape_pct(&observed)).unwrap_or(0.0),
            predicted_mape_pct: mape(samples.iter().map(|&(_, p, o)| (p as f64, o))),
            curve,
        }
    }

    /// Render the per-bucket table plus the fitted-curve summary line.
    pub fn table(&self) -> String {
        let mut t = Table::new(&["kv len", "predicted cyc", "observed cyc", "curve cyc"]);
        for &(kv, predicted, observed) in &self.samples {
            t.row(vec![
                kv.to_string(),
                predicted.to_string(),
                observed.to_string(),
                self.curve
                    .as_ref()
                    .map(|c| c.step_cycles(kv).to_string())
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        match &self.curve {
            Some(c) => format!(
                "{}context curve [{}]: {:.0} + {:.1}/kv cycles  fit MAPE {:.1}%  \
                 (compiler predictions {:.1}%)\n",
                t.render(),
                self.model.slug(),
                c.base_cycles,
                c.cycles_per_kv,
                self.fit_mape_pct,
                self.predicted_mape_pct
            ),
            None => format!(
                "{}context curve [{}]: degenerate ladder (no slope to fit)\n",
                t.render(),
                self.model.slug()
            ),
        }
    }
}

/// Per-channel predicted-vs-observed energy statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyChannelRow {
    /// The energy channel this row describes.
    pub channel: EnergyChannel,
    /// Completions that contributed a pair to this channel.
    pub completions: usize,
    /// Total analytically predicted energy across those completions, fJ.
    pub predicted_fj: u64,
    /// Total trace-observed (tick-attributed) energy, fJ.
    pub observed_fj: u64,
    /// Mean absolute percentage error of the raw analytic predictor.
    pub mape_pct: f64,
    /// MAPE after applying this channel's own fitted scale — the guarded
    /// calibration keeps only scales where this is no worse than
    /// [`EnergyChannelRow::mape_pct`].
    pub post_fit_mape_pct: f64,
    /// Fitted linear correction (`observed ≈ scale · predicted`), clamped
    /// into `[EnergyCalibration::MIN_SCALE, MAX_SCALE]`.
    pub scale: f64,
}

/// Energy-model calibration: join the coarse analytic per-request energy
/// prediction ([`EnergyModel::predict_inference`] over the model's MAC
/// and parameter totals; decode requests add `(tokens − 1)` decode steps
/// predicted at their mid-generation KV length) against the
/// tick-attributed energy each completion of a recorded trace actually
/// observed, per [`EnergyChannel`]. The energy analogue of
/// [`ValidationReport`]: same least-squares-through-the-origin fit, same
/// clamp, same improve-only guard — the observed side is raw model
/// output, so the fitted [`EnergyCalibration`] corrects predictions
/// without ever touching replay.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyFitReport {
    /// One row per channel with at least one joined completion.
    pub rows: Vec<EnergyChannelRow>,
    /// MAPE over every scored pair, raw analytic predictor.
    pub overall_mape_pct: f64,
    /// MAPE over every scored pair after the fitted per-channel scales.
    pub post_fit_mape_pct: f64,
}

impl EnergyFitReport {
    /// Build from raw `(channel, predicted_fj, observed_fj)` tuples.
    pub fn from_pairs(pairs: &[(EnergyChannel, u64, u64)]) -> Self {
        let mut rows = Vec::new();
        for channel in EnergyChannel::all() {
            let of_channel: Vec<&(EnergyChannel, u64, u64)> =
                pairs.iter().filter(|(c, _, _)| *c == channel).collect();
            if of_channel.is_empty() {
                continue;
            }
            let predicted: u64 = of_channel.iter().map(|(_, p, _)| p).sum();
            let observed: u64 = of_channel.iter().map(|(_, _, o)| o).sum();
            let scale = fit_energy_scale(of_channel.iter().map(|&&(_, p, o)| (p, o)));
            rows.push(EnergyChannelRow {
                channel,
                completions: of_channel.len(),
                predicted_fj: predicted,
                observed_fj: observed,
                mape_pct: mape(of_channel.iter().map(|&&(_, p, o)| (p as f64, o))),
                post_fit_mape_pct: mape(
                    of_channel.iter().map(|&&(_, p, o)| (p as f64 * scale, o)),
                ),
                scale,
            });
        }
        let scale_of = |channel: EnergyChannel| {
            rows.iter().find(|r| r.channel == channel).map(|r| r.scale).unwrap_or(1.0)
        };
        EnergyFitReport {
            overall_mape_pct: mape(pairs.iter().map(|&(_, p, o)| (p as f64, o))),
            post_fit_mape_pct: mape(
                pairs.iter().map(|&(c, p, o)| (p as f64 * scale_of(c), o)),
            ),
            rows,
        }
    }

    /// Join a recorded trace's per-completion energy against the analytic
    /// predictor for `cfg`. Fails when the trace was recorded without
    /// energy accounting (its completions carry only zeros — there is
    /// nothing to fit).
    pub fn from_trace(trace: &Trace, cfg: &NeutronConfig) -> Result<Self> {
        Ok(Self::from_pairs(&energy_pairs_from_trace(trace, cfg)?))
    }

    /// The fitted per-channel corrections, unguarded.
    pub fn calibration(&self) -> EnergyCalibration {
        EnergyCalibration::from_scales(
            &self.rows.iter().map(|r| (r.channel, r.scale)).collect::<Vec<_>>(),
        )
    }

    /// The fitted corrections with the improve-only guard applied: a
    /// channel keeps its scale only when the fit does not worsen that
    /// channel's MAPE on the joined data, and no-op scales are dropped —
    /// the mirror of [`ValidationReport::calibration_guarded`].
    pub fn calibration_guarded(&self) -> EnergyCalibration {
        EnergyCalibration::from_scales(
            &self
                .rows
                .iter()
                .filter(|r| r.scale != 1.0 && r.post_fit_mape_pct <= r.mape_pct)
                .map(|r| (r.channel, r.scale))
                .collect::<Vec<_>>(),
        )
    }

    /// Render the per-channel table plus the overall MAPE before/after
    /// the fitted scales.
    pub fn table(&self) -> String {
        let mut t = Table::new(&[
            "channel",
            "completions",
            "predicted fJ",
            "observed fJ",
            "MAPE %",
            "fit MAPE %",
            "fit scale",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.channel.name().to_string(),
                r.completions.to_string(),
                r.predicted_fj.to_string(),
                r.observed_fj.to_string(),
                format!("{:.1}", r.mape_pct),
                format!("{:.1}", r.post_fit_mape_pct),
                format!("{:.3}", r.scale),
            ]);
        }
        format!(
            "{}energy MAPE: {:.1}%  →  {:.1}% after per-channel calibration\n",
            t.render(),
            self.overall_mape_pct,
            self.post_fit_mape_pct
        )
    }
}

/// MAPE (%) over `(predicted, observed)` pairs; pairs with zero observed
/// cycles are skipped (0 when nothing is scorable).
fn mape(pairs: impl Iterator<Item = (f64, u64)>) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for (pred, obs) in pairs {
        if obs == 0 {
            continue;
        }
        sum += (pred - obs as f64).abs() / obs as f64;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64 * 100.0
    }
}

/// Least-squares slope through the origin of `observed ≈ scale·predicted`;
/// 1.0 for degenerate fits (non-finite or non-positive slope) and clamped
/// into `[CostCalibration::MIN_SCALE, MAX_SCALE]`, so the resulting
/// calibration is always valid and can never move a cost estimate by more
/// than the clamp range even when the trace joins a handful of
/// pathological ops.
fn fit_scale(pairs: impl Iterator<Item = (u64, u64)>) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (pred, obs) in pairs {
        num += pred as f64 * obs as f64;
        den += (pred as f64) * (pred as f64);
    }
    let scale = num / den;
    if scale.is_finite() && scale > 0.0 {
        CostCalibration::clamp_scale(scale)
    } else {
        1.0
    }
}

/// The `(channel, predicted_fj, observed_fj)` join behind
/// [`EnergyFitReport::from_trace`], exposed so the tune loop can re-score
/// the same pairs under a fitted calibration. Per completion: the
/// analytic prediction is [`EnergyModel::predict_inference`] over the
/// model's MAC/parameter totals; decode completions add `(tokens − 1)`
/// steps predicted at their mid-generation KV length (step cost is
/// linear in KV, so the midpoint is the exact mean). Fails when the
/// trace was recorded without energy accounting or has no completions.
pub fn energy_pairs_from_trace(
    trace: &Trace,
    cfg: &NeutronConfig,
) -> Result<Vec<(EnergyChannel, u64, u64)>> {
    if !trace.meta.scheduler.energy {
        bail!(
            "trace was recorded without energy accounting (re-record with --energy to fit \
             an energy calibration)"
        );
    }
    if trace.completions.is_empty() {
        bail!("trace has no completions to fit an energy calibration from");
    }
    let model = EnergyModel::for_config(cfg);
    // Analytic predictions depend only on (model) resp. (model, kv
    // midpoint), so memoize the graph builds.
    let mut base: Vec<(ModelId, EnergyBreakdown)> = Vec::new();
    let mut steps: Vec<((ModelId, u32), EnergyBreakdown)> = Vec::new();
    let mut pairs: Vec<(EnergyChannel, u64, u64)> = Vec::new();
    for c in &trace.completions {
        let mut predicted = match base.iter().find(|(m, _)| *m == c.model) {
            Some(&(_, b)) => b,
            None => {
                let g = c.model.build();
                let b = model.predict_inference(cfg, g.total_macs(), g.total_params());
                base.push((c.model, b));
                b
            }
        };
        if c.tokens > 1 {
            let tcfg = match c.model.decode_config() {
                Some(t) => t,
                None => bail!(
                    "completion {} decoded {} tokens on non-decode model {}",
                    c.id,
                    c.tokens,
                    c.model.slug()
                ),
            };
            let prompt = trace
                .requests
                .iter()
                .find(|r| r.id == c.id)
                .map(|r| r.prompt_tokens)
                .unwrap_or(0);
            let mid_kv = prompt + c.tokens / 2;
            let step = match steps.iter().find(|(k, _)| *k == (c.model, mid_kv)) {
                Some(&(_, s)) => s,
                None => {
                    let g = decoder_decode_step(tcfg, mid_kv as usize);
                    let s = model.predict_inference(cfg, g.total_macs(), g.total_params());
                    steps.push(((c.model, mid_kv), s));
                    s
                }
            };
            let n = (c.tokens - 1) as u64;
            predicted.compute_fj =
                predicted.compute_fj.saturating_add(step.compute_fj.saturating_mul(n));
            predicted.dma_fj = predicted.dma_fj.saturating_add(step.dma_fj.saturating_mul(n));
            predicted.idle_fj =
                predicted.idle_fj.saturating_add(step.idle_fj.saturating_mul(n));
        }
        pairs.push((EnergyChannel::Compute, predicted.compute_fj, c.energy_compute_fj));
        pairs.push((EnergyChannel::Dma, predicted.dma_fj, c.energy_dma_fj));
        pairs.push((EnergyChannel::Idle, predicted.idle_fj, c.energy_idle_fj));
    }
    Ok(pairs)
}

/// [`fit_scale`] for energy pairs: identical least-squares slope, clamped
/// into the energy calibration's own `[MIN_SCALE, MAX_SCALE]` range.
fn fit_energy_scale(pairs: impl Iterator<Item = (u64, u64)>) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (pred, obs) in pairs {
        num += pred as f64 * obs as f64;
        den += (pred as f64) * (pred as f64);
    }
    let scale = num / den;
    if scale.is_finite() && scale > 0.0 {
        EnergyCalibration::clamp_scale(scale)
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_predictions_fit_identity() {
        let pairs = [
            (OpClass::Conv, 1_000, 1_000),
            (OpClass::Conv, 2_000, 2_000),
            (OpClass::Pool, 500, 500),
        ];
        let v = ValidationReport::from_pairs(&pairs);
        assert_eq!(v.overall_mape_pct, 0.0);
        assert_eq!(v.post_fit_mape_pct, 0.0);
        assert_eq!(v.rows.len(), 2, "only classes with ops get rows");
        for r in &v.rows {
            assert_eq!(r.mape_pct, 0.0);
            assert_eq!(r.bias_pct, 0.0);
            assert!((r.scale - 1.0).abs() < 1e-12);
        }
        assert!(v.calibration().is_identity() || v.calibration().scales().len() == 2);
    }

    #[test]
    fn consistent_underprediction_is_fully_corrected() {
        // Observed is exactly 2× predicted everywhere: the fit must find
        // scale 2 and drive the post-fit MAPE to ~0.
        let pairs = [
            (OpClass::Conv, 1_000, 2_000),
            (OpClass::Conv, 3_000, 6_000),
            (OpClass::DepthwiseConv, 400, 800),
        ];
        let v = ValidationReport::from_pairs(&pairs);
        assert!(v.overall_mape_pct > 99.0);
        assert!(v.post_fit_mape_pct < 1e-9, "{}", v.post_fit_mape_pct);
        for r in &v.rows {
            assert!((r.scale - 2.0).abs() < 1e-9);
            assert!((r.bias_pct - 100.0).abs() < 1e-9);
        }
        let cal = v.calibration();
        assert_eq!(cal.apply(OpClass::Conv, 1_000), 2_000);
    }

    #[test]
    fn degenerate_fits_fall_back_to_identity_scale() {
        // Zero predictions: slope undefined → scale 1.0, calibration valid.
        let v = ValidationReport::from_pairs(&[(OpClass::Softmax, 0, 700)]);
        assert_eq!(v.rows.len(), 1);
        assert_eq!(v.rows[0].scale, 1.0);
        assert_eq!(v.rows[0].bias_pct, 0.0);
        let _ = v.calibration(); // must not panic
        // Zero observed: excluded from MAPE, not from the fit sums.
        let v = ValidationReport::from_pairs(&[(OpClass::Pool, 500, 0)]);
        assert_eq!(v.overall_mape_pct, 0.0);
        assert_eq!(v.rows[0].scale, 1.0, "all-zero observed fits no positive slope");
    }

    #[test]
    fn wild_fits_are_clamped_into_the_sane_range() {
        // Observed is 100× predicted: the raw least-squares slope is 100,
        // but the calibration must never carry more than MAX_SCALE.
        let v = ValidationReport::from_pairs(&[
            (OpClass::Conv, 100, 10_000),
            (OpClass::Conv, 200, 20_000),
        ]);
        assert_eq!(v.rows[0].scale, CostCalibration::MAX_SCALE);
        // And symmetrically for massive over-prediction.
        let v = ValidationReport::from_pairs(&[(OpClass::Pool, 10_000, 100)]);
        assert_eq!(v.rows[0].scale, CostCalibration::MIN_SCALE);
        // Both ends still build a valid calibration.
        let _ = v.calibration();
    }

    #[test]
    fn guarded_calibration_drops_mape_worsening_fits() {
        // A heterogeneous class where the least-squares slope (pulled to
        // ~2 by the large op) makes the class MAPE worse: raw 25%
        // (0% + 50%), post-fit 50% (100% + 0%).
        let v = ValidationReport::from_pairs(&[
            (OpClass::Conv, 1, 1),
            (OpClass::Conv, 100, 200),
            (OpClass::Pool, 500, 1_000),
        ]);
        let conv = v.rows.iter().find(|r| r.class == OpClass::Conv).unwrap();
        assert!(conv.post_fit_mape_pct > conv.mape_pct, "{conv:?}");
        let guarded = v.calibration_guarded();
        assert_eq!(guarded.scale_for(OpClass::Conv), 1.0, "worsening fit must be dropped");
        assert!((guarded.scale_for(OpClass::Pool) - 2.0).abs() < 1e-9, "improving fit kept");
        // The unguarded calibration still carries the raw fit.
        assert!(v.calibration().scale_for(OpClass::Conv) > 1.0);
    }

    #[test]
    fn decode_curve_fits_the_compiled_ladder() {
        let cfg = NeutronConfig::flagship_2tops();
        let v = DecodeCurveReport::from_model(ModelId::GptTiny, 24, &cfg);
        // Ladder 4, 8, 16, 32 (doubling from the minimum until ≥ 24).
        let kvs: Vec<u32> = v.samples.iter().map(|&(kv, _, _)| kv).collect();
        assert_eq!(kvs, vec![4, 8, 16, 32]);
        assert!(
            v.samples.windows(2).all(|w| w[0].2 < w[1].2),
            "observed step cycles must grow with context: {:?}",
            v.samples
        );
        let curve = v.curve.expect("4 distinct KV lengths fit a line");
        assert!(curve.cycles_per_kv > 0.0, "more context must cost more");
        assert!(v.fit_mape_pct < 25.0, "fit MAPE {}", v.fit_mape_pct);
        let s = v.table();
        assert!(s.contains("kv len") && s.contains("context curve"));

        // Degenerate single-bucket ladder: no slope, rendered as such.
        let one = DecodeCurveReport::from_samples(ModelId::GptTiny, &v.samples[..1]);
        assert!(one.curve.is_none());
        assert_eq!(one.fit_mape_pct, 0.0);
        assert!(one.table().contains("degenerate"));
    }

    #[test]
    fn energy_fit_mirrors_the_timing_fit() {
        // Observed is exactly 1.5× predicted on compute, exact on dma:
        // the fit corrects compute fully and leaves dma at identity.
        let pairs = [
            (EnergyChannel::Compute, 1_000, 1_500),
            (EnergyChannel::Compute, 4_000, 6_000),
            (EnergyChannel::Dma, 800, 800),
        ];
        let v = EnergyFitReport::from_pairs(&pairs);
        assert_eq!(v.rows.len(), 2, "only channels with pairs get rows");
        let compute = v.rows.iter().find(|r| r.channel == EnergyChannel::Compute).unwrap();
        assert!((compute.scale - 1.5).abs() < 1e-9);
        assert!(compute.post_fit_mape_pct < 1e-9);
        let cal = v.calibration_guarded();
        assert_eq!(cal.apply(EnergyChannel::Compute, 1_000), 1_500);
        assert_eq!(cal.apply(EnergyChannel::Dma, 777), 777, "no-op scale dropped");
        assert!(v.post_fit_mape_pct <= v.overall_mape_pct, "the guard's invariant");
        let s = v.table();
        assert!(s.contains("compute") && s.contains("energy MAPE"));
    }

    #[test]
    fn energy_fit_clamps_and_guards_like_the_timing_fit() {
        // 100× under-prediction clamps at MAX_SCALE.
        let v = EnergyFitReport::from_pairs(&[(EnergyChannel::Idle, 10, 1_000)]);
        assert_eq!(v.rows[0].scale, EnergyCalibration::MAX_SCALE);
        // A heterogeneous channel whose least-squares slope worsens MAPE
        // is dropped by the guard (same shape as the timing-fit case).
        let v = EnergyFitReport::from_pairs(&[
            (EnergyChannel::Dma, 1, 1),
            (EnergyChannel::Dma, 100, 200),
        ]);
        let dma = v.rows.iter().find(|r| r.channel == EnergyChannel::Dma).unwrap();
        assert!(dma.post_fit_mape_pct > dma.mape_pct, "{dma:?}");
        assert!(v.calibration_guarded().is_identity());
        assert!(!v.calibration().is_identity(), "unguarded keeps the raw fit");
        // Degenerate all-zero predictions fall back to identity.
        let v = EnergyFitReport::from_pairs(&[(EnergyChannel::Compute, 0, 500)]);
        assert_eq!(v.rows[0].scale, 1.0);
    }

    #[test]
    fn table_renders_classes_and_overall_lines() {
        let v = ValidationReport::from_pairs(&[
            (OpClass::Conv, 1_000, 1_100),
            (OpClass::Matmul, 200, 180),
        ]);
        let s = v.table();
        assert!(s.contains("conv") && s.contains("matmul"));
        assert!(s.contains("overall MAPE"));
        assert!(s.contains("after per-class calibration"));
    }
}
