//! The record → fit → recompile → replay tuning loop (`neutron tune`).
//!
//! The paper's thesis is that the CP compiler wins by optimizing against
//! workload reality, not peak TOPS. This module closes that loop in one
//! step: take a recorded trace, fit the per-op-class cost corrections
//! from its predicted-vs-observed profiles (`trace/validate.rs`),
//! recompile every model under the fitted [`CostCalibration`] (the
//! corrections now steer format selection, the scheduling objective and
//! the emitted job cycles — see `compiler::CostModel`), replay the same
//! recorded requests against the recompiled artifacts, and score the
//! calibrated cost model the same way the uncalibrated one was scored.
//!
//! The fit is **guarded and clamped** (see
//! `ValidationReport::calibration_guarded`): on the data it was fitted
//! from, applying it can only improve every class's MAPE, and no scale
//! leaves `[CostCalibration::MIN_SCALE, MAX_SCALE]`. The post-tune MAPE
//! reported here is measured on the *recompiled, replayed* run — the
//! honest number — so it can differ from the first-order
//! `post_fit_mape_pct` the validation table prints.

use anyhow::{bail, Result};

use crate::arch::NeutronConfig;
use crate::compiler::CostCalibration;
use crate::energy::EnergyCalibration;
use crate::serve::{CompileCache, ServeReport};
use crate::zoo::ModelId;

use super::format::Trace;
use super::record::profile_model_ops;
use super::replay::{ReplayDriver, ReplayOptions};
use super::validate::{energy_pairs_from_trace, EnergyFitReport, ValidationReport};

/// Result of one tuning iteration over a recorded trace.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The guarded, clamped calibration the loop fed back into
    /// compilation.
    pub calibration: CostCalibration,
    /// Predicted-vs-observed scoring of the recorded (uncalibrated) run.
    pub before: ValidationReport,
    /// Scoring of the calibrated recompile on the replayed trace:
    /// predictions from the calibrated cost model, observations from the
    /// recompiled programs' tick timing.
    pub after: ValidationReport,
    /// Faithful replay of the recorded run (the before-makespan
    /// reference — bit-identical to the recording).
    pub report_before: ServeReport,
    /// The same requests served by the calibrated artifacts.
    pub report_after: ServeReport,
}

impl TuneOutcome {
    /// Overall per-op MAPE of the uncalibrated cost model on the
    /// recorded run, percent.
    pub fn mape_before_pct(&self) -> f64 {
        self.before.overall_mape_pct
    }

    /// Overall per-op MAPE of the calibrated cost model on the replayed
    /// (recompiled) run, percent.
    pub fn mape_after_pct(&self) -> f64 {
        self.after.overall_mape_pct
    }

    /// One machine-greppable line (`ci.sh` asserts on it): the overall
    /// MAPE and makespan before vs after the tune iteration.
    pub fn summary_line(&self) -> String {
        format!(
            "tune: mape_before_pct={:.3} mape_after_pct={:.3} \
             makespan_before_cycles={} makespan_after_cycles={}",
            self.mape_before_pct(),
            self.mape_after_pct(),
            self.report_before.makespan_cycles,
            self.report_after.makespan_cycles,
        )
    }

    /// Human-readable report: both scoring tables, the fitted scales and
    /// the makespan comparison, ending with [`TuneOutcome::summary_line`].
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        writeln!(s, "== recorded run (uncalibrated cost model) ==").unwrap();
        s.push_str(&self.before.table());
        writeln!(s, "\n== fitted calibration (guarded, clamped) ==").unwrap();
        if self.calibration.is_identity() {
            writeln!(s, "identity — no class fit improved its recorded MAPE").unwrap();
        } else {
            for &(class, scale) in self.calibration.scales() {
                writeln!(s, "  {:<14} × {:.3}", class.name(), scale).unwrap();
            }
        }
        writeln!(s, "\n== calibrated recompile, replayed ==").unwrap();
        s.push_str(&self.after.table());
        let (mb, ma) = (
            self.report_before.makespan_cycles,
            self.report_after.makespan_cycles,
        );
        let delta_pct = if mb == 0 {
            0.0
        } else {
            (ma as f64 / mb as f64 - 1.0) * 100.0
        };
        writeln!(
            s,
            "\nmakespan: {mb} -> {ma} cycles ({delta_pct:+.1}% — the calibrated model \
             re-prices the virtual clock, so this moves with the corrections)"
        )
        .unwrap();
        writeln!(s, "{}", self.summary_line()).unwrap();
        s
    }
}

/// Run one tuning iteration over a recorded trace: fit (guarded +
/// clamped), recompile under the fit, replay the recorded requests, and
/// score the calibrated model. Fails when the trace carries no per-op
/// profiles (nothing was ever dispatched) or was recorded on a different
/// config.
pub fn tune_from_trace(cfg: &NeutronConfig, trace: &Trace) -> Result<TuneOutcome> {
    let before = ValidationReport::from_trace(trace)?;
    let calibration = before.calibration_guarded();
    let driver = ReplayDriver::new(trace.clone());
    // Faithful replay: the before-makespan reference, and the guard that
    // the recorded observations still describe this build — a trace
    // captured before a timing-model change would make the before/after
    // comparison meaningless.
    let base = driver.replay(cfg)?;
    if let Some(divergence) = &base.divergence {
        bail!(
            "recorded trace does not replay faithfully on this build (timing model \
             changed since capture?) — re-record before tuning: {divergence}"
        );
    }
    // Calibrated recompile + replay of the same requests. The cache is
    // built around the fitted calibration, so its entries are the
    // calibrated artifacts (distinct cache keys from the identity ones).
    let opts = ReplayOptions { calibration: calibration.clone(), ..ReplayOptions::default() };
    let mut cache = CompileCache::for_serving_with(cfg.clone(), calibration.clone());
    let tuned = driver.replay_with_options_cached(cfg, &opts, &mut cache)?;
    // Score the calibrated model: calibrated predictions (the entries
    // carry their own calibration) vs the recompiled programs' tick
    // observations.
    let mut pairs = Vec::new();
    let mut seen: Vec<ModelId> = Vec::new();
    for &model in &trace.meta.models {
        if seen.contains(&model) {
            continue;
        }
        seen.push(model);
        if let Some(entry) = cache.peek(model) {
            pairs.extend(
                profile_model_ops(cfg, entry)
                    .into_iter()
                    .map(|o| (o.class, o.predicted_cycles, o.observed_cycles)),
            );
        }
    }
    if pairs.is_empty() {
        bail!("calibrated replay never dispatched a model — nothing to score");
    }
    let after = ValidationReport::from_pairs(&pairs);
    Ok(TuneOutcome {
        calibration,
        before,
        after,
        report_before: base.report,
        report_after: tuned.report,
    })
}

/// Result of one energy-tuning iteration over a recorded trace.
///
/// Unlike the timing tune, there is no recompile/replay leg: the energy
/// calibration corrects *analytic predictions* only (the per-completion
/// observations are raw model output and never change), so the honest
/// after-score is simply the joined pairs re-scored under the guarded
/// fit.
#[derive(Debug, Clone)]
pub struct EnergyTuneOutcome {
    /// The guarded, clamped per-channel calibration.
    pub calibration: EnergyCalibration,
    /// Predicted-vs-observed scoring of the raw analytic predictor.
    pub before: EnergyFitReport,
    /// The same pairs re-scored with the guarded calibration applied to
    /// every prediction.
    pub after: EnergyFitReport,
}

impl EnergyTuneOutcome {
    /// Overall energy MAPE of the raw analytic predictor, percent.
    pub fn mape_before_pct(&self) -> f64 {
        self.before.overall_mape_pct
    }

    /// Overall energy MAPE under the guarded calibration, percent.
    pub fn mape_after_pct(&self) -> f64 {
        self.after.overall_mape_pct
    }

    /// One machine-greppable line (`ci.sh` asserts on it).
    pub fn summary_line(&self) -> String {
        format!(
            "tune-energy: mape_before_pct={:.3} mape_after_pct={:.3}",
            self.mape_before_pct(),
            self.mape_after_pct(),
        )
    }

    /// Human-readable report: both scoring tables and the fitted scales,
    /// ending with [`EnergyTuneOutcome::summary_line`].
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        writeln!(s, "== recorded run (uncalibrated energy model) ==").unwrap();
        s.push_str(&self.before.table());
        writeln!(s, "\n== fitted energy calibration (guarded, clamped) ==").unwrap();
        if self.calibration.is_identity() {
            writeln!(s, "identity — no channel fit improved its recorded MAPE").unwrap();
        } else {
            for &(channel, scale) in self.calibration.scales() {
                writeln!(s, "  {:<8} × {:.3}", channel.name(), scale).unwrap();
            }
        }
        writeln!(s, "\n== calibrated predictions, re-scored ==").unwrap();
        s.push_str(&self.after.table());
        writeln!(s, "{}", self.summary_line()).unwrap();
        s
    }
}

/// Run one energy-tuning iteration over a recorded trace: join the
/// analytic predictions against the recorded per-completion energy, fit
/// the guarded per-channel calibration, and re-score the same pairs under
/// it. Because the guard keeps only improving scales, the after-MAPE can
/// never exceed the before-MAPE on the fitted data. Fails when the trace
/// was recorded without `--energy`.
pub fn tune_energy_from_trace(cfg: &NeutronConfig, trace: &Trace) -> Result<EnergyTuneOutcome> {
    let pairs = energy_pairs_from_trace(trace, cfg)?;
    let before = EnergyFitReport::from_pairs(&pairs);
    let calibration = before.calibration_guarded();
    let scaled: Vec<_> = pairs
        .iter()
        .map(|&(c, p, o)| (c, calibration.apply(c, p), o))
        .collect();
    let after = EnergyFitReport::from_pairs(&scaled);
    Ok(EnergyTuneOutcome { calibration, before, after })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{SchedulerOptions, ServeOptions};
    use crate::trace::serve_recorded;
    use crate::zoo::ModelId;

    fn recorded_trace(cfg: &NeutronConfig) -> Trace {
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 10,
            mean_gap_cycles: 300_000,
            seed: 13,
            scheduler: SchedulerOptions { instances: 2, ..SchedulerOptions::default() },
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        serve_recorded(cfg, &opts, &mut cache).1
    }

    #[test]
    fn tune_loop_runs_and_scores_both_sides() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = recorded_trace(&cfg);
        let outcome = tune_from_trace(&cfg, &trace).unwrap();
        assert!(outcome.mape_before_pct().is_finite());
        assert!(outcome.mape_after_pct().is_finite());
        assert!(outcome.report_before.makespan_cycles > 0);
        assert!(outcome.report_after.makespan_cycles > 0);
        assert!(!outcome.after.rows.is_empty());
        // The guard holds first-order: on the recorded data, the kept
        // scales can only improve each class.
        for row in &outcome.before.rows {
            let s = outcome.calibration.scale_for(row.class);
            if s != 1.0 {
                assert!(
                    row.post_fit_mape_pct <= row.mape_pct,
                    "guard kept a worsening fit for {:?}",
                    row.class
                );
            }
        }
        let line = outcome.summary_line();
        assert!(line.starts_with("tune: mape_before_pct="), "{line}");
        let table = outcome.table();
        assert!(table.contains("calibrated recompile"), "{table}");
        assert!(table.contains(&outcome.summary_line()), "{table}");
    }

    #[test]
    fn tune_is_deterministic() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = recorded_trace(&cfg);
        let a = tune_from_trace(&cfg, &trace).unwrap();
        let b = tune_from_trace(&cfg, &trace).unwrap();
        assert_eq!(a.calibration, b.calibration);
        assert_eq!(a.report_after, b.report_after);
        assert_eq!(a.summary_line(), b.summary_line());
    }

    #[test]
    fn tune_refuses_a_profile_free_trace() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut trace = recorded_trace(&cfg);
        trace.model_ops.clear();
        assert!(tune_from_trace(&cfg, &trace).is_err());
    }

    fn recorded_energy_trace(cfg: &NeutronConfig) -> Trace {
        let opts = ServeOptions {
            models: vec![ModelId::MobileNetV3Min, ModelId::MobileNetV1],
            requests: 10,
            mean_gap_cycles: 300_000,
            seed: 13,
            scheduler: SchedulerOptions {
                instances: 2,
                energy: true,
                ..SchedulerOptions::default()
            },
            ..ServeOptions::default()
        };
        let mut cache = CompileCache::for_serving(cfg.clone());
        serve_recorded(cfg, &opts, &mut cache).1
    }

    #[test]
    fn energy_tune_never_worsens_mape_and_is_deterministic() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = recorded_energy_trace(&cfg);
        let a = tune_energy_from_trace(&cfg, &trace).unwrap();
        assert!(a.mape_before_pct().is_finite());
        // Improve-only guard: re-scoring under the kept scales can only
        // lower (or hold) the joined MAPE. The microscopic epsilon covers
        // integer-femtojoule rounding in EnergyCalibration::apply.
        assert!(
            a.mape_after_pct() <= a.mape_before_pct() + 1e-6,
            "after {} vs before {}",
            a.mape_after_pct(),
            a.mape_before_pct()
        );
        let line = a.summary_line();
        assert!(line.starts_with("tune-energy: mape_before_pct="), "{line}");
        let table = a.table();
        assert!(table.contains("energy MAPE") && table.contains(&line), "{table}");

        let b = tune_energy_from_trace(&cfg, &trace).unwrap();
        assert_eq!(a.calibration, b.calibration);
        assert_eq!(a.summary_line(), b.summary_line());
    }

    #[test]
    fn energy_tune_refuses_an_unmetered_trace() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = recorded_trace(&cfg);
        let err = tune_energy_from_trace(&cfg, &trace).unwrap_err().to_string();
        assert!(err.contains("--energy"), "{err}");
    }
}
