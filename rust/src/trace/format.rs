//! Versioned, self-describing JSONL trace format (hand-rolled, no deps).
//!
//! A trace file is a sequence of JSON objects, **one per line**. The first
//! non-empty line must be the header; every other line carries an `event`
//! discriminator:
//!
//! | `event`   | meaning                                                    |
//! |-----------|------------------------------------------------------------|
//! | `header`  | format name + version, config fingerprint, scheduler knobs |
//! | `request` | one offered request (arrival order == line order)          |
//! | `shed`    | id of a request admission control shed                     |
//! | `complete`| one completion record (dispatch order)                     |
//! | `ops`     | per-op predicted vs tick-observed cycles for one model     |
//!
//! ## Versioning rules
//!
//! The header carries `"format": "eiq-neutron-trace"` and an integer
//! `"version"`. A reader accepts **exactly** the versions it knows
//! (currently only [`TRACE_FORMAT_VERSION`]) and rejects everything else —
//! adding, removing or re-interpreting any field requires bumping the
//! version. Unknown event types and malformed lines are hard errors (a
//! trace is evidence; silently skipping lines would corrupt it), and every
//! parse error names the offending line.
//!
//! The JSON subset is hand-rolled (see [`Json`]) so the trace subsystem
//! adds no dependencies: objects, arrays, strings, booleans, null,
//! unsigned 64-bit integers (cycle counts round-trip exactly) and floats
//! (written in Rust's shortest round-trip form).

use anyhow::{anyhow, bail, Result};

use crate::energy::EnergyMode;
use crate::ir::OpClass;
use crate::serve::{AdmissionPolicy, Completion, Priority, Request, SchedulerOptions};
use crate::zoo::ModelId;

/// The trace format version this build reads and writes.
///
/// Version history:
/// - **1** — initial format (PR 4).
/// - **2** — pipelining + TCM weight residency (PR 7): the header gains
///   the `pipeline`, `weight_residency`, `warm_routing` and
///   `residency_capacity_bytes` scheduler knobs, and completion records
///   gain `overlap_cycles` and `residency_hit_cycles`. Version-1 files
///   are rejected (their completions cannot carry the per-request
///   overlap/residency attribution a v2 reader reports).
/// - **3** — autoregressive GenAI serving (PR 8): the header gains the
///   `continuous_batch` and `residency_quota_bytes` scheduler knobs,
///   request records gain `prompt_tokens` / `decode_tokens` (0/0 for
///   single-shot inference), and completion records gain
///   `first_token_cycles`, `tokens` and `kv_refetch_cycles` — the fields
///   TTFT/TPOT reporting and decode replay reconcile against. Version-2
///   files are rejected (their completions cannot distinguish a prefill
///   from a full decode).
/// - **4** — energy accounting (PR 9): the header gains the `energy`,
///   `energy_mode` and `energy_budget_fj` scheduler knobs, and completion
///   records gain `energy_compute_fj`, `energy_dma_fj` and
///   `energy_idle_fj` — the exactly-conserved femtojoule attribution
///   replay reconciles bit for bit (all three are 0 when the recording
///   run had energy accounting off). Version-3 files are rejected (their
///   completions carry no energy attribution to validate against).
pub const TRACE_FORMAT_VERSION: u64 = 4;

/// The format name stamped into (and required from) every header.
pub const TRACE_FORMAT_NAME: &str = "eiq-neutron-trace";

// ---------------------------------------------------------------------------
// Minimal JSON value
// ---------------------------------------------------------------------------

/// Minimal JSON value for the trace format. Integers are kept as `u64`
/// (never coerced through `f64`), so virtual-clock cycle counts round-trip
/// bit-exactly; object key order is preserved, so serialization is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A non-negative integer literal (no `.`, `e` or sign).
    UInt(u64),
    /// Any other numeric literal.
    Float(f64),
    /// A string.
    Str(String),
    /// An array.
    Array(Vec<Json>),
    /// An object, in insertion order.
    Object(Vec<(String, Json)>),
}

impl Json {
    /// Serialize (compact, no whitespace).
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::UInt(v) => out.push_str(&v.to_string()),
            Json::Float(v) => {
                // Rust's shortest round-trip form; JSON has no NaN/Inf.
                assert!(v.is_finite(), "cannot serialize non-finite float {v}");
                out.push_str(&v.to_string());
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Object(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Serialize to a fresh string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse one complete JSON value; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field, with a named error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    /// As `u64` (strict: only integer literals qualify).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(v) => Some(*v),
            _ => None,
        }
    }

    /// As `f64` (integer literals widen losslessly where they fit).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::UInt(v) => Some(*v as f64),
            Json::Float(v) => Some(*v),
            _ => None,
        }
    }

    /// As `&str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As `bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(items) => Some(items),
            _ => None,
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursion bound for nested arrays/objects: the parser recurses once
/// per nesting level, so a corrupt (or hostile) line of thousands of `[`s
/// must produce a parse error, not a stack overflow. Real trace lines
/// nest 3 levels deep.
const MAX_NESTING_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_NESTING_DEPTH {
            bail!(
                "nesting deeper than {MAX_NESTING_DEPTH} levels at byte {}",
                self.pos
            );
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => bail!("unexpected byte {:?} at {}", b as char, self.pos),
            None => bail!("unexpected end of input at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Object(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Array(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u escape {code:#x}"))?,
                            );
                            self.pos += 4;
                        }
                        _ => bail!("invalid escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                // ASCII fast path — everything a real trace contains.
                Some(b) if b < 0x80 => {
                    if b < 0x20 {
                        bail!("unescaped control character at byte {}", self.pos);
                    }
                    out.push(b as char);
                    self.pos += 1;
                }
                Some(first) => {
                    // Multi-byte UTF-8: decode just this character (the
                    // sequence length comes from the leading byte, so
                    // parsing stays linear in the line length).
                    let len = match first {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => 1, // invalid leading byte; from_utf8 rejects it
                    };
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8 at byte {}", self.pos))?;
                    let c = std::str::from_utf8(chunk)?.chars().next().unwrap();
                    out.push(c);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        let mut is_float = false;
        if self.peek() == Some(b'-') {
            is_float = true; // we never write negatives; parse as float
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        if is_float {
            let v: f64 = text.parse().map_err(|e| anyhow!("bad number {text:?}: {e}"))?;
            // f64::from_str saturates overflow to ±inf; JSON has no
            // non-finite numbers, and Json::write asserts finiteness —
            // reject here so a corrupt line is a parse error, not a
            // panic at re-serialization time.
            if !v.is_finite() {
                bail!("non-finite number {text:?}");
            }
            Ok(Json::Float(v))
        } else {
            Ok(Json::UInt(text.parse::<u64>().map_err(|e| anyhow!("bad integer {text:?}: {e}"))?))
        }
    }
}

// ---------------------------------------------------------------------------
// Trace model
// ---------------------------------------------------------------------------

/// Header metadata: everything needed to replay the trace without the
/// original command line.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    /// Format version the file was parsed from (informational — the
    /// writer always stamps [`TRACE_FORMAT_VERSION`], and the parser
    /// accepts only that version, so this always equals the constant).
    pub version: u64,
    /// FNV-1a fingerprint of the `NeutronConfig` the run simulated
    /// (replay refuses a mismatching config — the timing would differ).
    pub config_fingerprint: u64,
    /// Core clock of the recording run, GHz (informational; replay uses
    /// the live config, which the fingerprint pins).
    pub freq_ghz: f64,
    /// Trace PRNG seed of the recording run (informational for replays —
    /// the requests themselves are recorded).
    pub seed: u64,
    /// Tenant model list, in the report's per-model row order.
    pub models: Vec<ModelId>,
    /// Scheduler knobs the run used (replay re-applies them).
    pub scheduler: SchedulerOptions,
}

/// Per-op predicted-vs-observed cycles for one compiled model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpRecord {
    /// Op id inside the model's IR graph.
    pub op: u32,
    /// Calibration class of the op.
    pub class: OpClass,
    /// Compiler-predicted cycles (analytic cost model, `compiler/cost.rs`).
    pub predicted_cycles: u64,
    /// Cycles the tick timing model attributed to this op
    /// (`JobProgram::per_op_tick_cycles`).
    pub observed_cycles: u64,
}

/// The per-op breakdown of one model's cached program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelOps {
    /// The model these rows profile.
    pub model: ModelId,
    /// One record per compute op, in first-execution order.
    pub ops: Vec<OpRecord>,
}

/// A complete recorded serving run: offered requests (arrival order),
/// shed ids, completions (dispatch order) and per-model op profiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Header metadata.
    pub meta: TraceMeta,
    /// Every offered request, in arrival (admission) order — including
    /// requests that were later shed, so a replay reproduces the shedding
    /// decisions itself.
    pub requests: Vec<Request>,
    /// Ids of requests shed by admission control, in shedding order.
    pub shed_ids: Vec<u64>,
    /// Completion records, in dispatch order (batches contiguous).
    pub completions: Vec<Completion>,
    /// Per-model predicted-vs-observed op cycles (one entry per model
    /// that was dispatched at least once).
    pub model_ops: Vec<ModelOps>,
}

impl Trace {
    /// Serialize to JSONL (header first, then requests, shed ids,
    /// completions and model profiles — parse order is free, but this
    /// order keeps files diffable).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut line = |j: &Json, out: &mut String| {
            j.write(out);
            out.push('\n');
        };
        line(&self.header_json(), &mut out);
        for r in &self.requests {
            line(&request_json(r), &mut out);
        }
        for &id in &self.shed_ids {
            line(
                &Json::Object(vec![
                    ("event".into(), Json::Str("shed".into())),
                    ("id".into(), Json::UInt(id)),
                ]),
                &mut out,
            );
        }
        for c in &self.completions {
            line(&completion_json(c), &mut out);
        }
        for m in &self.model_ops {
            line(&model_ops_json(m), &mut out);
        }
        out
    }

    fn header_json(&self) -> Json {
        let m = &self.meta;
        Json::Object(vec![
            ("event".into(), Json::Str("header".into())),
            ("format".into(), Json::Str(TRACE_FORMAT_NAME.into())),
            // Always the constant: a writer can only produce the format
            // this build implements, whatever a caller put in `meta`.
            ("version".into(), Json::UInt(TRACE_FORMAT_VERSION)),
            ("config_fingerprint".into(), Json::UInt(m.config_fingerprint)),
            ("freq_ghz".into(), Json::Float(m.freq_ghz)),
            ("seed".into(), Json::UInt(m.seed)),
            (
                "models".into(),
                Json::Array(m.models.iter().map(|id| Json::Str(id.slug().into())).collect()),
            ),
            ("instances".into(), Json::UInt(m.scheduler.instances as u64)),
            // 0 encodes "unbounded" / "disabled", the CLI convention.
            (
                "queue_capacity".into(),
                Json::UInt(m.scheduler.queue_capacity.unwrap_or(0) as u64),
            ),
            ("policy".into(), Json::Str(m.scheduler.policy.display_name().into())),
            ("max_batch".into(), Json::UInt(m.scheduler.max_batch as u64)),
            ("dynamic_batch".into(), Json::Bool(m.scheduler.dynamic_batch)),
            (
                "age_after_cycles".into(),
                Json::UInt(m.scheduler.age_after_cycles.unwrap_or(0)),
            ),
            ("pipeline".into(), Json::Bool(m.scheduler.pipeline)),
            ("weight_residency".into(), Json::Bool(m.scheduler.weight_residency)),
            ("warm_routing".into(), Json::Bool(m.scheduler.warm_routing)),
            // 0 encodes "use the config's TCM size", the CLI convention.
            (
                "residency_capacity_bytes".into(),
                Json::UInt(m.scheduler.residency_capacity_bytes.unwrap_or(0)),
            ),
            // 0 encodes "no per-owner cap", the CLI convention.
            (
                "residency_quota_bytes".into(),
                Json::UInt(m.scheduler.residency_quota_bytes.unwrap_or(0)),
            ),
            ("continuous_batch".into(), Json::Bool(m.scheduler.continuous_batch)),
            ("energy".into(), Json::Bool(m.scheduler.energy)),
            ("energy_mode".into(), Json::Str(m.scheduler.energy_mode.name().into())),
            // 0 encodes "no budget", the CLI convention.
            (
                "energy_budget_fj".into(),
                Json::UInt(m.scheduler.energy_budget_fj.unwrap_or(0)),
            ),
        ])
    }

    /// Parse a JSONL trace. Strict: the first non-empty line must be a
    /// header with the exact format name and a supported version; every
    /// other line must be a known event with all required fields; any
    /// malformed line fails the whole parse with its line number.
    pub fn parse(text: &str) -> Result<Trace> {
        let mut meta: Option<TraceMeta> = None;
        let mut requests = Vec::new();
        let mut shed_ids = Vec::new();
        let mut completions = Vec::new();
        let mut model_ops = Vec::new();

        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            if raw.trim().is_empty() {
                continue;
            }
            let j = Json::parse(raw).map_err(|e| anyhow!("trace line {lineno}: {e}"))?;
            let event = j
                .req("event")
                .and_then(|e| {
                    e.as_str().ok_or_else(|| anyhow!("field \"event\" must be a string"))
                })
                .map_err(|e| anyhow!("trace line {lineno}: {e}"))?
                .to_string();
            let parsed: Result<()> = (|| {
                match event.as_str() {
                    "header" => {
                        if meta.is_some() {
                            bail!("duplicate header");
                        }
                        meta = Some(parse_header(&j)?);
                    }
                    "request" => requests.push(parse_request(&j)?),
                    "shed" => {
                        reject_unknown_fields(&j, &["event", "id"])?;
                        shed_ids.push(u64_field(&j, "id")?);
                    }
                    "complete" => completions.push(parse_completion(&j)?),
                    "ops" => model_ops.push(parse_model_ops(&j)?),
                    other => bail!("unknown event {other:?}"),
                }
                Ok(())
            })();
            parsed.map_err(|e| anyhow!("trace line {lineno}: {e}"))?;
            if meta.is_none() {
                bail!("trace line {lineno}: first line must be the header");
            }
        }
        let meta = meta.ok_or_else(|| anyhow!("empty trace: no header line"))?;
        Ok(Trace { meta, requests, shed_ids, completions, model_ops })
    }
}

/// Strict field check: an object may carry exactly the keys its format
/// version defines. Tolerating extras would make the versioning rule
/// ("adding a field requires a bump") unenforceable and would break the
/// byte-exact re-render property (`parse(x).to_jsonl() == x`).
fn reject_unknown_fields(j: &Json, known: &[&str]) -> Result<()> {
    if let Json::Object(fields) = j {
        for (k, _) in fields {
            if !known.contains(&k.as_str()) {
                bail!("unknown field {k:?} (adding fields requires a format version bump)");
            }
        }
    }
    Ok(())
}

fn u64_field(j: &Json, key: &str) -> Result<u64> {
    j.req(key)?
        .as_u64()
        .ok_or_else(|| anyhow!("field {key:?} must be an unsigned integer"))
}

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("field {key:?} must be a string"))
}

fn model_field(j: &Json, key: &str) -> Result<ModelId> {
    let name = str_field(j, key)?;
    ModelId::parse(name).ok_or_else(|| anyhow!("unknown model {name:?}"))
}

fn class_field(j: &Json, key: &str) -> Result<Priority> {
    let name = str_field(j, key)?;
    Priority::parse(name).ok_or_else(|| anyhow!("unknown priority class {name:?}"))
}

fn parse_header(j: &Json) -> Result<TraceMeta> {
    reject_unknown_fields(
        j,
        &[
            "event",
            "format",
            "version",
            "config_fingerprint",
            "freq_ghz",
            "seed",
            "models",
            "instances",
            "queue_capacity",
            "policy",
            "max_batch",
            "dynamic_batch",
            "age_after_cycles",
            "pipeline",
            "weight_residency",
            "warm_routing",
            "residency_capacity_bytes",
            "residency_quota_bytes",
            "continuous_batch",
            "energy",
            "energy_mode",
            "energy_budget_fj",
        ],
    )?;
    let format = str_field(j, "format")?;
    if format != TRACE_FORMAT_NAME {
        bail!("not a {TRACE_FORMAT_NAME} file (format {format:?})");
    }
    let version = u64_field(j, "version")?;
    if version != TRACE_FORMAT_VERSION {
        bail!(
            "unsupported trace format version {version} (this build reads only \
             version {TRACE_FORMAT_VERSION})"
        );
    }
    let models = j
        .req("models")?
        .as_array()
        .ok_or_else(|| anyhow!("field \"models\" must be an array"))?
        .iter()
        .map(|m| {
            let name = m.as_str().ok_or_else(|| anyhow!("model entries must be strings"))?;
            ModelId::parse(name).ok_or_else(|| anyhow!("unknown model {name:?}"))
        })
        .collect::<Result<Vec<_>>>()?;
    if models.is_empty() {
        bail!("header must name at least one model");
    }
    let policy_name = str_field(j, "policy")?;
    let policy = AdmissionPolicy::parse(policy_name)
        .ok_or_else(|| anyhow!("unknown admission policy {policy_name:?}"))?;
    let instances = u64_field(j, "instances")? as usize;
    let max_batch = u64_field(j, "max_batch")? as usize;
    if instances == 0 || max_batch == 0 {
        bail!("degenerate scheduler knobs: instances and max_batch must be >= 1");
    }
    let queue_capacity = match u64_field(j, "queue_capacity")? as usize {
        0 => None,
        cap => Some(cap),
    };
    let age_after_cycles = match u64_field(j, "age_after_cycles")? {
        0 => None,
        age => Some(age),
    };
    let bool_field = |key: &str| -> Result<bool> {
        j.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow!("field {key:?} must be a boolean"))
    };
    let dynamic_batch = bool_field("dynamic_batch")?;
    let pipeline = bool_field("pipeline")?;
    let weight_residency = bool_field("weight_residency")?;
    let warm_routing = bool_field("warm_routing")?;
    if warm_routing && !weight_residency {
        bail!("header enables warm_routing without weight_residency");
    }
    let residency_capacity_bytes = match u64_field(j, "residency_capacity_bytes")? {
        0 => None,
        cap => Some(cap),
    };
    if residency_capacity_bytes.is_some() && !weight_residency {
        bail!("header sets residency_capacity_bytes without weight_residency");
    }
    let residency_quota_bytes = match u64_field(j, "residency_quota_bytes")? {
        0 => None,
        quota => Some(quota),
    };
    if residency_quota_bytes.is_some() && !weight_residency {
        bail!("header sets residency_quota_bytes without weight_residency");
    }
    if let (Some(quota), Some(cap)) = (residency_quota_bytes, residency_capacity_bytes) {
        if quota > cap {
            bail!(
                "header residency_quota_bytes ({quota}) exceeds residency_capacity_bytes \
                 ({cap})"
            );
        }
    }
    let continuous_batch = bool_field("continuous_batch")?;
    let energy = bool_field("energy")?;
    let energy_mode = EnergyMode::parse(str_field(j, "energy_mode")?)?;
    if energy_mode != EnergyMode::RaceToIdle && !energy {
        bail!("header sets energy_mode {:?} without energy accounting", energy_mode.name());
    }
    let energy_budget_fj = match u64_field(j, "energy_budget_fj")? {
        0 => None,
        budget => Some(budget),
    };
    if energy_budget_fj.is_some() && !energy {
        bail!("header sets energy_budget_fj without energy accounting");
    }
    Ok(TraceMeta {
        version,
        config_fingerprint: u64_field(j, "config_fingerprint")?,
        freq_ghz: j
            .req("freq_ghz")?
            .as_f64()
            .ok_or_else(|| anyhow!("field \"freq_ghz\" must be a number"))?,
        seed: u64_field(j, "seed")?,
        models,
        scheduler: SchedulerOptions {
            instances,
            queue_capacity,
            policy,
            max_batch,
            dynamic_batch,
            age_after_cycles,
            pipeline,
            weight_residency,
            warm_routing,
            residency_capacity_bytes,
            residency_quota_bytes,
            continuous_batch,
            energy,
            energy_mode,
            energy_budget_fj,
        },
    })
}

fn request_json(r: &Request) -> Json {
    Json::Object(vec![
        ("event".into(), Json::Str("request".into())),
        ("id".into(), Json::UInt(r.id)),
        ("model".into(), Json::Str(r.model.slug().into())),
        ("class".into(), Json::Str(r.priority.display_name().into())),
        ("arrival_cycles".into(), Json::UInt(r.arrival_cycles)),
        ("prompt_tokens".into(), Json::UInt(r.prompt_tokens as u64)),
        ("decode_tokens".into(), Json::UInt(r.decode_tokens as u64)),
    ])
}

fn u32_field(j: &Json, key: &str) -> Result<u32> {
    u32::try_from(u64_field(j, key)?).map_err(|_| anyhow!("field {key:?} out of range"))
}

fn parse_request(j: &Json) -> Result<Request> {
    reject_unknown_fields(
        j,
        &["event", "id", "model", "class", "arrival_cycles", "prompt_tokens", "decode_tokens"],
    )?;
    let prompt_tokens = u32_field(j, "prompt_tokens")?;
    let decode_tokens = u32_field(j, "decode_tokens")?;
    // 0/0 is a single-shot inference; a decode request needs both.
    if (decode_tokens > 0) != (prompt_tokens > 0) {
        bail!("request has prompt_tokens {prompt_tokens} but decode_tokens {decode_tokens} (a \
               decode request needs both, single-shot inference neither)");
    }
    Ok(Request {
        id: u64_field(j, "id")?,
        model: model_field(j, "model")?,
        priority: class_field(j, "class")?,
        arrival_cycles: u64_field(j, "arrival_cycles")?,
        prompt_tokens,
        decode_tokens,
    })
}

fn completion_json(c: &Completion) -> Json {
    Json::Object(vec![
        ("event".into(), Json::Str("complete".into())),
        ("id".into(), Json::UInt(c.id)),
        ("model".into(), Json::Str(c.model.slug().into())),
        ("class".into(), Json::Str(c.priority.display_name().into())),
        ("instance".into(), Json::UInt(c.instance as u64)),
        ("batch_index".into(), Json::UInt(c.batch_index as u64)),
        ("arrival_cycles".into(), Json::UInt(c.arrival_cycles)),
        ("start_cycles".into(), Json::UInt(c.start_cycles)),
        ("finish_cycles".into(), Json::UInt(c.finish_cycles)),
        ("overlap_cycles".into(), Json::UInt(c.overlap_cycles)),
        ("residency_hit_cycles".into(), Json::UInt(c.residency_hit_cycles)),
        ("first_token_cycles".into(), Json::UInt(c.first_token_cycles)),
        ("tokens".into(), Json::UInt(c.tokens as u64)),
        ("kv_refetch_cycles".into(), Json::UInt(c.kv_refetch_cycles)),
        ("energy_compute_fj".into(), Json::UInt(c.energy_compute_fj)),
        ("energy_dma_fj".into(), Json::UInt(c.energy_dma_fj)),
        ("energy_idle_fj".into(), Json::UInt(c.energy_idle_fj)),
    ])
}

fn parse_completion(j: &Json) -> Result<Completion> {
    reject_unknown_fields(
        j,
        &[
            "event",
            "id",
            "model",
            "class",
            "instance",
            "batch_index",
            "arrival_cycles",
            "start_cycles",
            "finish_cycles",
            "overlap_cycles",
            "residency_hit_cycles",
            "first_token_cycles",
            "tokens",
            "kv_refetch_cycles",
            "energy_compute_fj",
            "energy_dma_fj",
            "energy_idle_fj",
        ],
    )?;
    let first_token_cycles = u64_field(j, "first_token_cycles")?;
    let finish_cycles = u64_field(j, "finish_cycles")?;
    if first_token_cycles > finish_cycles {
        bail!("completion first_token_cycles ({first_token_cycles}) exceeds finish_cycles \
               ({finish_cycles})");
    }
    let tokens = u32_field(j, "tokens")?;
    if tokens == 0 {
        bail!("completion produced 0 tokens (single-shot inference counts as 1)");
    }
    Ok(Completion {
        id: u64_field(j, "id")?,
        model: model_field(j, "model")?,
        priority: class_field(j, "class")?,
        instance: u64_field(j, "instance")? as usize,
        batch_index: u32_field(j, "batch_index")?,
        arrival_cycles: u64_field(j, "arrival_cycles")?,
        start_cycles: u64_field(j, "start_cycles")?,
        finish_cycles,
        overlap_cycles: u64_field(j, "overlap_cycles")?,
        residency_hit_cycles: u64_field(j, "residency_hit_cycles")?,
        first_token_cycles,
        tokens,
        kv_refetch_cycles: u64_field(j, "kv_refetch_cycles")?,
        energy_compute_fj: u64_field(j, "energy_compute_fj")?,
        energy_dma_fj: u64_field(j, "energy_dma_fj")?,
        energy_idle_fj: u64_field(j, "energy_idle_fj")?,
    })
}

fn model_ops_json(m: &ModelOps) -> Json {
    Json::Object(vec![
        ("event".into(), Json::Str("ops".into())),
        ("model".into(), Json::Str(m.model.slug().into())),
        (
            "ops".into(),
            Json::Array(
                m.ops
                    .iter()
                    .map(|o| {
                        Json::Object(vec![
                            ("op".into(), Json::UInt(o.op as u64)),
                            ("class".into(), Json::Str(o.class.name().into())),
                            ("predicted_cycles".into(), Json::UInt(o.predicted_cycles)),
                            ("observed_cycles".into(), Json::UInt(o.observed_cycles)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn parse_model_ops(j: &Json) -> Result<ModelOps> {
    reject_unknown_fields(j, &["event", "model", "ops"])?;
    let ops = j
        .req("ops")?
        .as_array()
        .ok_or_else(|| anyhow!("field \"ops\" must be an array"))?
        .iter()
        .map(|o| {
            reject_unknown_fields(o, &["op", "class", "predicted_cycles", "observed_cycles"])?;
            let class_name = str_field(o, "class")?;
            Ok(OpRecord {
                op: u32::try_from(u64_field(o, "op")?)
                    .map_err(|_| anyhow!("op id out of range"))?,
                class: OpClass::parse(class_name)
                    .ok_or_else(|| anyhow!("unknown op class {class_name:?}"))?,
                predicted_cycles: u64_field(o, "predicted_cycles")?,
                observed_cycles: u64_field(o, "observed_cycles")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelOps { model: model_field(j, "model")?, ops })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips_values() {
        let v = Json::Object(vec![
            ("a".into(), Json::UInt(u64::MAX)),
            ("b".into(), Json::Float(0.8)),
            ("c".into(), Json::Str("q\"\\\n\u{1}ü".into())),
            ("d".into(), Json::Array(vec![Json::Null, Json::Bool(true), Json::UInt(0)])),
            ("e".into(), Json::Object(vec![])),
        ]);
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
        // u64::MAX survives exactly (would be lossy through f64).
        assert_eq!(
            Json::parse(&s).unwrap().get("a").unwrap().as_u64(),
            Some(u64::MAX)
        );
    }

    #[test]
    fn json_rejects_garbage() {
        for bad in
            ["", "{", "{\"a\":}", "[1,]", "nul", "\"open", "{}extra", "{\"a\" 1}", "1e999"]
        {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn json_rejects_pathological_nesting_without_overflowing() {
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
        // Reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(32), "]".repeat(32));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn json_parses_interop_forms() {
        // Whitespace, escapes and floats a foreign writer might produce.
        let j = Json::parse(" { \"x\" : [ 1 , 2.5e1 , \"\\u0041\\t\" ] } ").unwrap();
        let arr = j.get("x").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(25.0));
        assert_eq!(arr[2].as_str(), Some("A\t"));
    }

    #[test]
    fn header_must_be_first_and_unique() {
        let t = tiny_trace();
        let jsonl = t.to_jsonl();
        // Drop the header line entirely.
        let body: String = jsonl.lines().skip(1).map(|l| format!("{l}\n")).collect();
        let err = Trace::parse(&body).unwrap_err().to_string();
        assert!(err.contains("header"), "{err}");
        // Duplicate header.
        let first = jsonl.lines().next().unwrap();
        let dup = format!("{first}\n{jsonl}");
        assert!(Trace::parse(&dup).unwrap_err().to_string().contains("duplicate header"));
    }

    #[test]
    fn unknown_fields_are_rejected() {
        let t = tiny_trace();
        let jsonl = t.to_jsonl();
        // Smuggle an extra field into a request line: a version-1 reader
        // must refuse it (field additions require a version bump).
        let tampered = jsonl.replace(
            "\"event\":\"request\"",
            "\"event\":\"request\",\"extra\":1",
        );
        assert_ne!(tampered, jsonl);
        let err = Trace::parse(&tampered).unwrap_err().to_string();
        assert!(err.contains("unknown field") && err.contains("extra"), "{err}");
    }

    #[test]
    fn writer_always_stamps_the_supported_version() {
        let mut t = tiny_trace();
        t.meta.version = 99; // a caller cannot forge an unparseable file
        let parsed = Trace::parse(&t.to_jsonl()).unwrap();
        assert_eq!(parsed.meta.version, TRACE_FORMAT_VERSION);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let t = tiny_trace();
        let jsonl = t.to_jsonl().replace("\"version\":4", "\"version\":99");
        let err = Trace::parse(&jsonl).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn old_version_3_is_rejected_naming_both_versions() {
        // A v3 file (completions carry no energy attribution) must be
        // refused with an error naming the file's version and ours.
        let t = tiny_trace();
        let jsonl = t.to_jsonl().replace("\"version\":4", "\"version\":3");
        let err = Trace::parse(&jsonl).unwrap_err().to_string();
        assert!(
            err.contains("unsupported trace format version 3")
                && err.contains("version 4"),
            "{err}"
        );
    }

    #[test]
    fn energy_knob_consistency_is_enforced() {
        let t = tiny_trace();
        let jsonl = t.to_jsonl();
        // Stretch mode without the energy meter is contradictory.
        let stretched =
            jsonl.replace("\"energy_mode\":\"race-to-idle\"", "\"energy_mode\":\"stretch\"");
        assert_ne!(stretched, jsonl);
        let err = Trace::parse(&stretched).unwrap_err().to_string();
        assert!(err.contains("energy_mode") && err.contains("without energy"), "{err}");
        // So is a budget without the meter.
        let budgeted = jsonl.replace("\"energy_budget_fj\":0", "\"energy_budget_fj\":5");
        assert_ne!(budgeted, jsonl);
        let err = Trace::parse(&budgeted).unwrap_err().to_string();
        assert!(err.contains("energy_budget_fj") && err.contains("without energy"), "{err}");
        // An unknown mode names the valid ones.
        let unknown =
            jsonl.replace("\"energy_mode\":\"race-to-idle\"", "\"energy_mode\":\"sprint\"");
        let err = Trace::parse(&unknown).unwrap_err().to_string();
        assert!(err.contains("unknown energy mode"), "{err}");
    }

    #[test]
    fn corrupt_line_names_its_number() {
        let t = tiny_trace();
        let mut jsonl = t.to_jsonl();
        jsonl.push_str("this is not json\n");
        let lines = jsonl.lines().count();
        let err = Trace::parse(&jsonl).unwrap_err().to_string();
        assert!(err.contains(&format!("line {lines}")), "{err}");
        // Unknown event type is also a hard error.
        let mut with_unknown = t.to_jsonl();
        with_unknown.push_str("{\"event\":\"mystery\"}\n");
        let err = Trace::parse(&with_unknown).unwrap_err().to_string();
        assert!(err.contains("unknown event"), "{err}");
    }

    fn tiny_trace() -> Trace {
        Trace {
            meta: TraceMeta {
                version: TRACE_FORMAT_VERSION,
                config_fingerprint: 42,
                freq_ghz: 1.0,
                seed: 7,
                models: vec![ModelId::MobileNetV1],
                scheduler: SchedulerOptions::default(),
            },
            requests: vec![
                Request {
                    id: 0,
                    model: ModelId::MobileNetV1,
                    priority: Priority::Standard,
                    arrival_cycles: 5,
                    prompt_tokens: 0,
                    decode_tokens: 0,
                },
                Request {
                    id: 1,
                    model: ModelId::MobileNetV1,
                    priority: Priority::Standard,
                    arrival_cycles: 9,
                    prompt_tokens: 4,
                    decode_tokens: 3,
                },
            ],
            shed_ids: vec![],
            completions: vec![
                Completion {
                    id: 0,
                    model: ModelId::MobileNetV1,
                    priority: Priority::Standard,
                    instance: 0,
                    batch_index: 0,
                    arrival_cycles: 5,
                    start_cycles: 5,
                    finish_cycles: 105,
                    overlap_cycles: 3,
                    residency_hit_cycles: 11,
                    first_token_cycles: 105,
                    tokens: 1,
                    kv_refetch_cycles: 0,
                    energy_compute_fj: 120,
                    energy_dma_fj: 30,
                    energy_idle_fj: 9,
                },
                Completion {
                    id: 1,
                    model: ModelId::MobileNetV1,
                    priority: Priority::Standard,
                    instance: 0,
                    batch_index: 0,
                    arrival_cycles: 9,
                    start_cycles: 105,
                    finish_cycles: 300,
                    overlap_cycles: 0,
                    residency_hit_cycles: 0,
                    first_token_cycles: 160,
                    tokens: 3,
                    kv_refetch_cycles: 7,
                    energy_compute_fj: 0,
                    energy_dma_fj: 0,
                    energy_idle_fj: 0,
                },
            ],
            model_ops: vec![ModelOps {
                model: ModelId::MobileNetV1,
                ops: vec![OpRecord {
                    op: 0,
                    class: OpClass::Conv,
                    predicted_cycles: 90,
                    observed_cycles: 100,
                }],
            }],
        }
    }

    #[test]
    fn trace_round_trips() {
        let t = tiny_trace();
        let parsed = Trace::parse(&t.to_jsonl()).unwrap();
        assert_eq!(parsed, t);
    }
}
