//! Energy calibration: per-channel scale corrections of the analytic
//! energy model, fitted from recorded traces (`trace/validate.rs`) and
//! saved in the same strict single-line JSON shape as the timing
//! calibration file:
//!
//! ```json
//! {"format":"eiq-neutron-energy-calibration","version":1,
//!  "config_fingerprint":1234,"energy_model_version":1,
//!  "scales":[{"channel":"compute","scale":1.31},{"channel":"dma","scale":0.8}]}
//! ```
//!
//! Strictness follows `trace/calibration.rs` exactly: exact format name
//! and version, no unknown fields, known channels only, every scale
//! finite and inside `[EnergyCalibration::MIN_SCALE, MAX_SCALE]`,
//! duplicates rejected. Two pins guard against correcting the wrong
//! model: the config fingerprint (a fit transplanted onto different
//! hardware geometry is wrong) and [`ENERGY_MODEL_VERSION`] (a fit
//! measured against an older coefficient derivation is equally wrong).
//!
//! The calibration corrects *analytic predictions only* — observed
//! per-completion energy in a trace is raw model output and never
//! rescaled, so record → replay bit-identity needs no calibration
//! plumbing.

use anyhow::{anyhow, bail, Result};

use crate::arch::NeutronConfig;
use crate::serve::config_fingerprint;
use crate::trace::Json;

use super::model::ENERGY_MODEL_VERSION;
use super::EnergyChannel;

/// The energy-calibration file format version this build reads and writes.
pub const ENERGY_CALIBRATION_FORMAT_VERSION: u64 = 1;

/// The format name stamped into (and required from) every file.
pub const ENERGY_CALIBRATION_FORMAT_NAME: &str = "eiq-neutron-energy-calibration";

/// Per-channel linear correction of the analytic energy predictor. A
/// channel's corrected estimate is `scale · predicted`;
/// [`EnergyCalibration::identity`] leaves every channel untouched, so
/// carrying a calibration is always optional.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyCalibration {
    scales: Vec<(EnergyChannel, f64)>,
}

impl Default for EnergyCalibration {
    fn default() -> Self {
        Self::identity()
    }
}

impl EnergyCalibration {
    /// Smallest scale a fit may carry — a correction below this claims
    /// the analytic model over-predicts by more than 4×, which no
    /// healthy trace produces (same rationale as the timing clamp).
    pub const MIN_SCALE: f64 = 0.25;

    /// Largest scale a fit may carry (see [`Self::MIN_SCALE`]).
    pub const MAX_SCALE: f64 = 4.0;

    /// Clamp a fitted scale into `[MIN_SCALE, MAX_SCALE]`.
    pub fn clamp_scale(scale: f64) -> f64 {
        scale.clamp(Self::MIN_SCALE, Self::MAX_SCALE)
    }

    /// The no-op calibration: every channel scale is 1.0.
    pub fn identity() -> Self {
        Self { scales: Vec::new() }
    }

    /// Build from explicit `(channel, scale)` pairs (later entries win).
    /// Non-finite or non-positive scales are rejected.
    pub fn from_scales(scales: &[(EnergyChannel, f64)]) -> Self {
        for &(channel, s) in scales {
            assert!(
                s.is_finite() && s > 0.0,
                "energy calibration scale for {channel:?} must be finite and positive, got {s}"
            );
        }
        Self { scales: scales.to_vec() }
    }

    /// The fitted `(channel, scale)` pairs, in insertion order.
    pub fn scales(&self) -> &[(EnergyChannel, f64)] {
        &self.scales
    }

    /// Correction factor for one channel (1.0 when unfitted).
    pub fn scale_for(&self, channel: EnergyChannel) -> f64 {
        self.scales
            .iter()
            .rev()
            .find(|(c, _)| *c == channel)
            .map(|&(_, s)| s)
            .unwrap_or(1.0)
    }

    /// Apply the channel correction to a predicted femtojoule count
    /// (rounded, floored at 1 for non-zero predictions). A scale of
    /// exactly 1.0 passes the prediction through untouched — never via
    /// `f64` — so an identity calibration is bit-transparent.
    pub fn apply(&self, channel: EnergyChannel, predicted_fj: u64) -> u64 {
        if predicted_fj == 0 {
            return 0;
        }
        let scale = self.scale_for(channel);
        if scale == 1.0 {
            return predicted_fj;
        }
        let corrected = (predicted_fj as f64 * scale).round() as u64;
        corrected.max(1)
    }

    /// True when no channel carries an effective correction.
    pub fn is_identity(&self) -> bool {
        EnergyChannel::all().into_iter().all(|c| self.scale_for(c) == 1.0)
    }
}

/// A saved energy calibration: fitted scales plus the config fingerprint
/// and energy-model version they were measured against.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyCalibrationFile {
    /// FNV-1a fingerprint of the `NeutronConfig` the fit was measured on.
    pub config_fingerprint: u64,
    /// The fitted per-channel corrections.
    pub calibration: EnergyCalibration,
}

impl EnergyCalibrationFile {
    /// Wrap a fitted calibration for saving against `cfg`.
    pub fn new(cfg: &NeutronConfig, calibration: EnergyCalibration) -> Self {
        Self { config_fingerprint: config_fingerprint(cfg), calibration }
    }

    /// Serialize to the single-line JSON document (plus a trailing
    /// newline, so the file is a well-formed text file).
    pub fn to_json(&self) -> String {
        let scales = self
            .calibration
            .scales()
            .iter()
            .map(|&(channel, scale)| {
                Json::Object(vec![
                    ("channel".into(), Json::Str(channel.name().into())),
                    ("scale".into(), Json::Float(scale)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("format".into(), Json::Str(ENERGY_CALIBRATION_FORMAT_NAME.into())),
            ("version".into(), Json::UInt(ENERGY_CALIBRATION_FORMAT_VERSION)),
            ("config_fingerprint".into(), Json::UInt(self.config_fingerprint)),
            ("energy_model_version".into(), Json::UInt(ENERGY_MODEL_VERSION)),
            ("scales".into(), Json::Array(scales)),
        ]);
        let mut out = doc.to_string_compact();
        out.push('\n');
        out
    }

    /// Parse an energy-calibration file. Strict: exact format name,
    /// version and energy-model version, no unknown fields, known
    /// channels only, every scale finite and within the clamp range.
    pub fn parse(text: &str) -> Result<EnergyCalibrationFile> {
        let j = Json::parse(text.trim())?;
        if let Json::Object(fields) = &j {
            for (k, _) in fields {
                if !["format", "version", "config_fingerprint", "energy_model_version", "scales"]
                    .contains(&k.as_str())
                {
                    bail!("unknown field {k:?} (adding fields requires a version bump)");
                }
            }
        } else {
            bail!("energy calibration file must be a JSON object");
        }
        let format = j
            .req("format")?
            .as_str()
            .ok_or_else(|| anyhow!("field \"format\" must be a string"))?;
        if format != ENERGY_CALIBRATION_FORMAT_NAME {
            bail!("not a {ENERGY_CALIBRATION_FORMAT_NAME} file (format {format:?})");
        }
        let version = j
            .req("version")?
            .as_u64()
            .ok_or_else(|| anyhow!("field \"version\" must be an unsigned integer"))?;
        if version != ENERGY_CALIBRATION_FORMAT_VERSION {
            bail!(
                "unsupported energy calibration format version {version} (this build reads \
                 only version {ENERGY_CALIBRATION_FORMAT_VERSION})"
            );
        }
        let config_fingerprint = j
            .req("config_fingerprint")?
            .as_u64()
            .ok_or_else(|| anyhow!("field \"config_fingerprint\" must be an unsigned integer"))?;
        let model_version = j
            .req("energy_model_version")?
            .as_u64()
            .ok_or_else(|| anyhow!("field \"energy_model_version\" must be an unsigned integer"))?;
        if model_version != ENERGY_MODEL_VERSION {
            bail!(
                "energy calibration was fitted against energy model version {model_version}; \
                 this build prices with version {ENERGY_MODEL_VERSION} — refit"
            );
        }
        let mut scales: Vec<(EnergyChannel, f64)> = Vec::new();
        for entry in j
            .req("scales")?
            .as_array()
            .ok_or_else(|| anyhow!("field \"scales\" must be an array"))?
        {
            if let Json::Object(fields) = entry {
                for (k, _) in fields {
                    if !["channel", "scale"].contains(&k.as_str()) {
                        bail!("unknown scale field {k:?}");
                    }
                }
            }
            let channel_name = entry
                .req("channel")?
                .as_str()
                .ok_or_else(|| anyhow!("scale field \"channel\" must be a string"))?;
            let channel = EnergyChannel::parse(channel_name)
                .ok_or_else(|| anyhow!("unknown energy channel {channel_name:?}"))?;
            let scale = entry
                .req("scale")?
                .as_f64()
                .ok_or_else(|| anyhow!("scale field \"scale\" must be a number"))?;
            if !scale.is_finite()
                || scale < EnergyCalibration::MIN_SCALE
                || scale > EnergyCalibration::MAX_SCALE
            {
                bail!(
                    "scale {scale} for channel {channel_name:?} outside the sane range \
                     [{}, {}] — corrupt file?",
                    EnergyCalibration::MIN_SCALE,
                    EnergyCalibration::MAX_SCALE
                );
            }
            if scales.iter().any(|&(c, _)| c == channel) {
                bail!("duplicate scale entry for channel {channel_name:?}");
            }
            scales.push((channel, scale));
        }
        Ok(EnergyCalibrationFile {
            config_fingerprint,
            calibration: EnergyCalibration::from_scales(&scales),
        })
    }

    /// The wrapped calibration, after checking the file was measured on
    /// `cfg`.
    pub fn calibration_for(&self, cfg: &NeutronConfig) -> Result<EnergyCalibration> {
        let live = config_fingerprint(cfg);
        if live != self.config_fingerprint {
            bail!(
                "config mismatch: energy calibration was fitted on config fingerprint {:#x}, \
                 pricing on {:#x} — refit on the live config",
                self.config_fingerprint,
                live
            );
        }
        Ok(self.calibration.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EnergyCalibrationFile {
        EnergyCalibrationFile::new(
            &NeutronConfig::flagship_2tops(),
            EnergyCalibration::from_scales(&[
                (EnergyChannel::Compute, 1.3125),
                (EnergyChannel::Dma, 0.875),
                (EnergyChannel::Idle, 2.0 / 3.0), // not exactly representable
            ]),
        )
    }

    #[test]
    fn energy_calibration_file_round_trips_bit_exactly() {
        let f = sample();
        let parsed = EnergyCalibrationFile::parse(&f.to_json()).unwrap();
        assert_eq!(parsed, f);
        for channel in EnergyChannel::all() {
            assert_eq!(
                parsed.calibration.scale_for(channel).to_bits(),
                f.calibration.scale_for(channel).to_bits()
            );
        }
    }

    #[test]
    fn identity_energy_calibration_saves_and_loads() {
        let cfg = NeutronConfig::flagship_2tops();
        let f = EnergyCalibrationFile::new(&cfg, EnergyCalibration::identity());
        let parsed = EnergyCalibrationFile::parse(&f.to_json()).unwrap();
        assert!(parsed.calibration.is_identity());
        assert!(parsed.calibration_for(&cfg).unwrap().is_identity());
    }

    #[test]
    fn identity_apply_is_bit_transparent() {
        let cal = EnergyCalibration::identity();
        for fj in [0u64, 1, 17, u64::MAX - 3] {
            assert_eq!(cal.apply(EnergyChannel::Compute, fj), fj);
        }
        let scaled = EnergyCalibration::from_scales(&[(EnergyChannel::Dma, 0.5)]);
        assert_eq!(scaled.apply(EnergyChannel::Dma, 1000), 500);
        assert_eq!(scaled.apply(EnergyChannel::Dma, 1), 1, "nonzero stays nonzero");
        assert_eq!(scaled.apply(EnergyChannel::Dma, 0), 0);
        assert_eq!(scaled.apply(EnergyChannel::Compute, 1000), 1000, "unfitted channel");
    }

    #[test]
    fn strict_parse_rejects_bad_files() {
        let good = sample().to_json();
        for (bad, why) in [
            (good.replace("eiq-neutron-energy-calibration", "eiq-neutron-calibration"),
             "format name"),
            (good.replace("\"version\":1,", "\"version\":9,"), "version"),
            (good.replace("\"energy_model_version\":1", "\"energy_model_version\":7"),
             "energy model version"),
            (good.replace("\"compute\"", "\"warp-drive\""), "unknown channel"),
            (good.replace("1.3125", "400.0"), "out-of-range scale"),
            (good.replace("1.3125", "0.0"), "non-positive scale"),
            (good.replace("{\"format\"", "{\"extra\":1,\"format\""), "unknown field"),
            ("not json at all".to_string(), "garbage"),
        ] {
            assert!(EnergyCalibrationFile::parse(&bad).is_err(), "{why} should be rejected");
        }
        let dup = good.replace(
            "{\"channel\":\"compute\",\"scale\":1.3125}",
            "{\"channel\":\"compute\",\"scale\":1.3125},{\"channel\":\"compute\",\"scale\":1.5}",
        );
        assert!(EnergyCalibrationFile::parse(&dup).is_err());
    }

    #[test]
    fn config_mismatch_is_refused() {
        let f = sample();
        let err = f
            .calibration_for(&NeutronConfig::mcu_half_tops())
            .unwrap_err()
            .to_string();
        assert!(err.contains("config mismatch"), "{err}");
    }
}
