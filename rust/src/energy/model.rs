//! The energy model proper: per-component fJ/cycle coefficients derived
//! from the architecture geometry, per-tick pricing with an exact integer
//! conservation invariant, and the coarse analytic predictor the
//! calibration fit corrects.

use crate::arch::NeutronConfig;
use crate::coordinator::{Job, JobProgram};

/// Version of the coefficient derivation below. Bump whenever
/// [`EnergyCoefficients::for_config`] changes so a saved energy
/// calibration (fitted against the old rates) cannot silently correct
/// the wrong model — the calibration file carries this next to the
/// config fingerprint.
pub const ENERGY_MODEL_VERSION: u64 = 1;

/// Femtojoules per joule: all internal accounting is integer fJ so
/// attribution sums are exact; joules appear only at the report edge.
pub const FJ_PER_JOULE: f64 = 1e15;

/// Convert integer femtojoules to joules (report edge only).
pub fn fj_to_joules(fj: u64) -> f64 {
    fj as f64 / FJ_PER_JOULE
}

/// Per-component energy rates in femtojoules per cycle, derived
/// deterministically from the [`NeutronConfig`] geometry (version
/// [`ENERGY_MODEL_VERSION`]). Every rate is at least 1 fJ/cycle, so an
/// energy-enabled run never prices a nonempty program at zero joules.
///
/// The absolute numbers are deliberately simple first-order physics —
/// ~0.2 pJ per int8 MAC for the PE array, per-bank TCM access energy,
/// per-byte bus movement for the DMA engines, and a leakage floor
/// proportional to TCM capacity. Their *ratios* carry the scheduling
/// signal (DMA vs compute vs idle); the absolute scale is what the
/// energy calibration fits from hardware traces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnergyCoefficients {
    /// PE array, fJ per cycle of compute (all cores' MAC grids active).
    pub pe_active_fj: u64,
    /// PE array clock/control floor, fJ per cycle it sits idle.
    pub pe_idle_fj: u64,
    /// TCM banks feeding active compute, fJ per compute cycle.
    pub tcm_active_fj: u64,
    /// TCM retention/precharge floor, fJ per non-compute cycle.
    pub tcm_idle_fj: u64,
    /// DMA engines moving counted bytes, fJ per datamover-busy cycle.
    pub dma_active_fj: u64,
    /// DMA engine idle floor, fJ per datamover-idle cycle.
    pub dma_idle_fj: u64,
    /// Always-on leakage across the subsystem, fJ per cycle.
    pub leak_fj: u64,
}

impl EnergyCoefficients {
    /// Derive the rate set for `cfg`. Deterministic: same config, same
    /// coefficients, every build.
    pub fn for_config(cfg: &NeutronConfig) -> Self {
        // ~0.2 pJ per int8 MAC; one cycle runs n·m MACs on each core.
        let macs_per_cycle = (cfg.n * cfg.m * cfg.cores) as u64;
        let pe_active = (200 * macs_per_cycle).max(1);
        // Feeding those MACs streams operands through the banks; banked
        // access energy scales with bank count, not capacity.
        let tcm_active = (400 * cfg.tcm_banks as u64).max(1);
        // Bus movement: ~150 fJ per byte-lane per cycle across the
        // per-core operand/result buses.
        let dma_active =
            (150 * (cfg.bus_bytes * cfg.buses_per_core * cfg.cores) as u64).max(1);
        // Leakage grows with on-chip SRAM: ~1 fJ per KiB per cycle.
        let leak = (cfg.tcm_bytes as u64 / 1024).max(1);
        Self {
            pe_active_fj: pe_active,
            pe_idle_fj: (pe_active / 20).max(1),
            tcm_active_fj: tcm_active,
            tcm_idle_fj: (tcm_active / 10).max(1),
            dma_active_fj: dma_active,
            dma_idle_fj: (dma_active / 20).max(1),
            leak_fj: leak,
        }
    }
}

/// Energy of one tick (or any span of cycles), split along both axes:
/// by component (the seven raw terms) and by channel (the
/// compute/dma/idle accessors used everywhere downstream). Integer fJ
/// throughout, so the channel split sums *exactly* to [`Self::total_fj`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TickEnergy {
    /// PE array active energy, fJ.
    pub pe_active_fj: u64,
    /// PE array idle-floor energy, fJ.
    pub pe_idle_fj: u64,
    /// TCM active (operand-streaming) energy, fJ.
    pub tcm_active_fj: u64,
    /// TCM idle-floor energy, fJ.
    pub tcm_idle_fj: u64,
    /// DMA engine active energy, fJ.
    pub dma_active_fj: u64,
    /// DMA engine idle-floor energy, fJ.
    pub dma_idle_fj: u64,
    /// Leakage energy, fJ.
    pub leak_fj: u64,
}

impl TickEnergy {
    /// The zero-energy tick.
    pub const ZERO: TickEnergy = TickEnergy {
        pe_active_fj: 0,
        pe_idle_fj: 0,
        tcm_active_fj: 0,
        tcm_idle_fj: 0,
        dma_active_fj: 0,
        dma_idle_fj: 0,
        leak_fj: 0,
    };

    /// Compute-channel energy: the PE array plus the TCM banks feeding it.
    pub fn compute_fj(&self) -> u64 {
        self.pe_active_fj + self.tcm_active_fj
    }

    /// DMA-channel energy: the datamover engines moving counted bytes.
    pub fn dma_fj(&self) -> u64 {
        self.dma_active_fj
    }

    /// Idle-channel energy: every idle floor plus leakage.
    pub fn idle_fj(&self) -> u64 {
        self.pe_idle_fj + self.tcm_idle_fj + self.dma_idle_fj + self.leak_fj
    }

    /// Total energy: the sum of all seven component terms. By
    /// construction `compute_fj() + dma_fj() + idle_fj() == total_fj()`
    /// exactly — each component term lands in exactly one channel.
    pub fn total_fj(&self) -> u64 {
        self.pe_active_fj
            + self.pe_idle_fj
            + self.tcm_active_fj
            + self.tcm_idle_fj
            + self.dma_active_fj
            + self.dma_idle_fj
            + self.leak_fj
    }

    /// Component-wise saturating accumulation (saturation is a ~52-day
    /// virtual-clock overflow guard, unreachable in any real run).
    pub fn add(&mut self, other: &TickEnergy) {
        self.pe_active_fj = self.pe_active_fj.saturating_add(other.pe_active_fj);
        self.pe_idle_fj = self.pe_idle_fj.saturating_add(other.pe_idle_fj);
        self.tcm_active_fj = self.tcm_active_fj.saturating_add(other.tcm_active_fj);
        self.tcm_idle_fj = self.tcm_idle_fj.saturating_add(other.tcm_idle_fj);
        self.dma_active_fj = self.dma_active_fj.saturating_add(other.dma_active_fj);
        self.dma_idle_fj = self.dma_idle_fj.saturating_add(other.dma_idle_fj);
        self.leak_fj = self.leak_fj.saturating_add(other.leak_fj);
    }
}

/// Channel-level energy summary (compute / dma / idle), used for
/// analytic predictions and report aggregation where the component split
/// no longer matters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EnergyBreakdown {
    /// Compute-channel energy, fJ.
    pub compute_fj: u64,
    /// DMA-channel energy, fJ.
    pub dma_fj: u64,
    /// Idle-channel energy, fJ.
    pub idle_fj: u64,
}

impl EnergyBreakdown {
    /// Total energy across the three channels.
    pub fn total_fj(&self) -> u64 {
        self.compute_fj + self.dma_fj + self.idle_fj
    }

    /// Collapse a [`TickEnergy`] onto its channels.
    pub fn from_tick(t: &TickEnergy) -> Self {
        Self { compute_fj: t.compute_fj(), dma_fj: t.dma_fj(), idle_fj: t.idle_fj() }
    }
}

/// Prices ticks into femtojoules. Construction is the only place the
/// architecture enters; after that pricing is a pure function of the
/// tick shape, so it can run inside the scheduler without touching the
/// executor's timing path at all.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnergyModel {
    /// The per-component rates this model prices with.
    pub coefficients: EnergyCoefficients,
}

impl EnergyModel {
    /// Model with the rates derived for `cfg`.
    pub fn for_config(cfg: &NeutronConfig) -> Self {
        Self { coefficients: EnergyCoefficients::for_config(cfg) }
    }

    /// Price one tick from its DAE shape: `latency` cycles total, of
    /// which `compute` ran the PE array and `dm` ran the datamover
    /// (`compute ≤ latency`, `dm ≤ latency` — the executor guarantees
    /// `latency = max(compute, dm)`). Components are active for their
    /// own cycles and idle for the remainder; leakage covers every
    /// cycle. `price_tick(cycles, 0, 0)` therefore prices a pure idle
    /// gap, which is how inter-dispatch idle energy is accounted.
    pub fn price_tick(&self, latency: u64, compute: u64, dm: u64) -> TickEnergy {
        debug_assert!(compute <= latency && dm <= latency);
        let c = &self.coefficients;
        TickEnergy {
            pe_active_fj: compute * c.pe_active_fj,
            pe_idle_fj: (latency - compute) * c.pe_idle_fj,
            tcm_active_fj: compute * c.tcm_active_fj,
            tcm_idle_fj: (latency - compute) * c.tcm_idle_fj,
            dma_active_fj: dm * c.dma_active_fj,
            dma_idle_fj: (latency - dm) * c.dma_idle_fj,
            leak_fj: latency * c.leak_fj,
        }
    }

    /// Price a whole program under a DMA filter, replicating the
    /// executor's tick walk exactly: per tick, compute cycles sum, DMA
    /// cycles sum over jobs `count_dma` accepts, latency is their max
    /// (`JobProgram::tick_latency_where`). Because this walks the same
    /// slices with the same filter the scheduler used for timing, the
    /// priced energy is consistent with the charged service cycles.
    pub fn price_program_where(
        &self,
        program: &JobProgram,
        mut count_dma: impl FnMut(&Job) -> bool,
    ) -> TickEnergy {
        let mut total = TickEnergy::ZERO;
        for tick in program.tick_slices() {
            let mut compute = 0u64;
            let mut dm = 0u64;
            for job in tick {
                match job {
                    Job::Compute { cycles, .. } => compute += cycles,
                    Job::Dma { cycles, .. } => {
                        if count_dma(job) {
                            dm += cycles;
                        }
                    }
                    Job::V2p { .. } | Job::Barrier => {}
                }
            }
            total.add(&self.price_tick(compute.max(dm), compute, dm));
        }
        total
    }

    /// Coarse analytic prediction for one single-shot inference of a
    /// model with `total_macs` MACs and `total_param_bytes` parameter
    /// bytes on `cfg`: one ideal DAE tick where the PE array streams
    /// every MAC at full width while the datamover streams every
    /// parameter byte at DDR bandwidth. Deliberately ignorant of tiling,
    /// batching, and residency — the gap between this and the observed
    /// per-completion energy is exactly what the calibration fit
    /// corrects.
    pub fn predict_inference(
        &self,
        cfg: &NeutronConfig,
        total_macs: u64,
        total_param_bytes: u64,
    ) -> EnergyBreakdown {
        let macs_per_cycle = (cfg.n * cfg.m * cfg.cores) as u64;
        let compute = total_macs.div_ceil(macs_per_cycle.max(1));
        let ddr = cfg.ddr_bytes_per_cycle().max(1.0);
        let dm = (total_param_bytes as f64 / ddr).ceil() as u64;
        let latency = compute.max(dm);
        EnergyBreakdown::from_tick(&self.price_tick(latency, compute, dm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Format, TransferKind};
    use crate::compiler::TileId;
    use crate::ir::OpId;

    fn model() -> EnergyModel {
        EnergyModel::for_config(&NeutronConfig::flagship_2tops())
    }

    #[test]
    fn coefficients_are_deterministic_and_nonzero() {
        let cfg = NeutronConfig::flagship_2tops();
        let a = EnergyCoefficients::for_config(&cfg);
        let b = EnergyCoefficients::for_config(&cfg);
        assert_eq!(a, b);
        // Flagship: 16·16·4 MACs/cycle at 200 fJ each.
        assert_eq!(a.pe_active_fj, 204_800);
        assert_eq!(a.dma_active_fj, 150 * 16 * 3 * 4);
        assert_eq!(a.leak_fj, 1024);
        for rate in [
            a.pe_active_fj,
            a.pe_idle_fj,
            a.tcm_active_fj,
            a.tcm_idle_fj,
            a.dma_active_fj,
            a.dma_idle_fj,
            a.leak_fj,
        ] {
            assert!(rate >= 1, "every rate has a 1 fJ/cycle floor");
        }
        // A smaller machine prices compute cheaper per cycle.
        let mcu = EnergyCoefficients::for_config(&NeutronConfig::mcu_half_tops());
        assert!(mcu.pe_active_fj < a.pe_active_fj);
    }

    #[test]
    fn tick_energy_conserves_exactly() {
        let m = model();
        for (latency, compute, dm) in
            [(0u64, 0u64, 0u64), (1, 1, 0), (1000, 1000, 300), (1000, 250, 1000), (7, 3, 5)]
        {
            let latency = latency.max(compute).max(dm);
            let e = m.price_tick(latency, compute, dm);
            assert_eq!(
                e.compute_fj() + e.dma_fj() + e.idle_fj(),
                e.total_fj(),
                "conservation must be exact for ({latency},{compute},{dm})"
            );
        }
    }

    #[test]
    fn idle_gap_pricing_is_pure_idle() {
        let m = model();
        let e = m.price_tick(1000, 0, 0);
        assert_eq!(e.compute_fj(), 0);
        assert_eq!(e.dma_fj(), 0);
        assert!(e.idle_fj() > 0);
        assert_eq!(e.idle_fj(), e.total_fj());
    }

    #[test]
    fn program_pricing_matches_hand_priced_ticks() {
        let m = model();
        // Two ticks: a DMA-bound fetch tick, then a compute-bound tick
        // with a shorter overlapped fetch.
        let program = JobProgram {
            jobs: vec![
                Job::Dma { tile: TileId(9), kind: TransferKind::Fetch, bytes: 64, cycles: 600 },
                Job::Barrier,
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(2),
                    in_tiles: vec![TileId(1)],
                    param_tile: None,
                    format: Format::Depth,
                    cycles: 1000,
                },
                Job::Dma { tile: TileId(1), kind: TransferKind::Fetch, bytes: 32, cycles: 300 },
                Job::Barrier,
            ],
            model: "toy".into(),
        };
        let priced = m.price_program_where(&program, |_| true);
        let mut expect = m.price_tick(600, 0, 600);
        expect.add(&m.price_tick(1000, 1000, 300));
        // The trailing Barrier yields an empty tick, priced at zero.
        expect.add(&m.price_tick(0, 0, 0));
        assert_eq!(priced, expect);
        assert_eq!(priced.compute_fj() + priced.dma_fj() + priced.idle_fj(), priced.total_fj());

        // Filtering out the tile-1 fetch removes its DMA energy and
        // extends the datamover's idle share of the second tick.
        let filtered = m.price_program_where(&program, |j| match j {
            Job::Dma { tile, .. } => *tile != TileId(1),
            _ => true,
        });
        assert!(filtered.dma_fj() < priced.dma_fj());
        assert!(filtered.dma_idle_fj > priced.dma_idle_fj);
        assert_eq!(
            filtered.compute_fj() + filtered.dma_fj() + filtered.idle_fj(),
            filtered.total_fj()
        );
    }

    #[test]
    fn analytic_prediction_scales_with_work() {
        let cfg = NeutronConfig::flagship_2tops();
        let m = model();
        let small = m.predict_inference(&cfg, 1_000_000, 100_000);
        let big = m.predict_inference(&cfg, 10_000_000, 1_000_000);
        assert!(big.total_fj() > small.total_fj());
        assert!(small.compute_fj > 0 && small.dma_fj > 0 && small.idle_fj > 0);
        assert_eq!(small.compute_fj + small.dma_fj + small.idle_fj, small.total_fj());
    }

    #[test]
    fn fj_to_joules_edge() {
        assert_eq!(fj_to_joules(0), 0.0);
        assert!((fj_to_joules(1_000_000_000_000_000) - 1.0).abs() < 1e-12);
    }
}
