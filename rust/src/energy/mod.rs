//! Per-tick energy attribution for the serving simulator.
//!
//! Latency answers "how fast"; at the edge the deciding figure of merit
//! is joules per inference (and joules per token for decode) — both the
//! µNPU benchmarking study (arxiv 2503.22567) and the MCU
//! energy-efficiency study (arxiv 2509.17533) show platform choice is
//! power-bound, not TOPS-bound. This module prices the simulator's
//! existing deterministic tick loop into energy without touching it:
//!
//! - [`EnergyCoefficients`] — a versioned set of per-component fJ/cycle
//!   rates (PE array, TCM banks, DMA engines, leakage floor) derived
//!   from the [`crate::arch::NeutronConfig`] geometry.
//! - [`EnergyModel`] — prices each tick's `(latency, compute, dm)`
//!   triple (exactly the executor's `TickStats`) into a [`TickEnergy`]:
//!   active energy for the cycles a component worked, idle energy for
//!   the rest of the tick, leakage for every cycle. All arithmetic is
//!   integer femtojoules, so `compute + dma + idle == total` holds
//!   *exactly* at every tick (the conservation invariant, mirror of the
//!   PR 4 per-op-tick timing attribution).
//! - [`EnergyCalibration`] / [`EnergyCalibrationFile`] — per-channel
//!   scale corrections fitted from recorded traces through the same
//!   record → fit → replay loop as the timing `CostCalibration`, in the
//!   same strict single-line JSON file format with config-fingerprint
//!   pinning. Calibration corrects *analytic predictions* only — the
//!   observed per-completion joules in a trace are raw model output, so
//!   record → replay stays bit-identical with no calibration plumbing.
//! - [`EnergyMode`] — the scheduling objective: `race-to-idle` (default,
//!   finish fast and let the fleet idle) vs `stretch` (coalesce work
//!   onto fewer instances to elide parameter-fetch DMA, trading makespan
//!   for joules). See `docs/energy.md`.
//!
//! Energy accounting is strictly opt-in: with `SchedulerOptions::energy`
//! off, every completion carries zero energy and no timing field, report
//! byte, or trace byte changes — the property suite in
//! `rust/tests/energy_integration.rs` pins this.

mod calibration;
mod model;

pub use calibration::{
    EnergyCalibration, EnergyCalibrationFile, ENERGY_CALIBRATION_FORMAT_NAME,
    ENERGY_CALIBRATION_FORMAT_VERSION,
};
pub use model::{
    fj_to_joules, EnergyBreakdown, EnergyCoefficients, EnergyModel, TickEnergy,
    ENERGY_MODEL_VERSION, FJ_PER_JOULE,
};

use anyhow::{bail, Result};

/// The three attribution channels every tick's energy is split into.
/// Component-level terms (PE, TCM, DMA, leakage) collapse onto these
/// channels for reporting and calibration: active PE + active TCM form
/// `Compute`, active DMA engines form `Dma`, and everything a stalled or
/// waiting component burns — including leakage — forms `Idle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnergyChannel {
    /// Energy spent while the PE array (and the TCM banks feeding it)
    /// execute compute jobs.
    Compute,
    /// Energy spent by DMA engines moving counted bytes.
    Dma,
    /// Energy burned waiting: idle floors of unoccupied components plus
    /// the leakage every cycle pays regardless of activity.
    Idle,
}

impl EnergyChannel {
    /// Every channel, in canonical (serialization) order.
    pub fn all() -> [EnergyChannel; 3] {
        [EnergyChannel::Compute, EnergyChannel::Dma, EnergyChannel::Idle]
    }

    /// Stable lower-case name used in calibration files and reports.
    pub fn name(self) -> &'static str {
        match self {
            EnergyChannel::Compute => "compute",
            EnergyChannel::Dma => "dma",
            EnergyChannel::Idle => "idle",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(name: &str) -> Option<EnergyChannel> {
        Self::all().into_iter().find(|c| c.name() == name)
    }
}

/// The energy-aware scheduling objective (`neutron serve --energy-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnergyMode {
    /// Finish each request as early as possible and let instances idle
    /// (the classic race-to-idle policy). This is the plain scheduler:
    /// timing is bit-identical to energy accounting switched off.
    RaceToIdle,
    /// Trade makespan for joules: coalesce same-model work into batches
    /// even when idle instances are available, so followers skip their
    /// parameter-fetch DMA. Work stretches out in time but the fleet
    /// moves fewer bytes.
    Stretch,
}

impl EnergyMode {
    /// Stable kebab-case name used by the CLI and the trace header.
    pub fn name(self) -> &'static str {
        match self {
            EnergyMode::RaceToIdle => "race-to-idle",
            EnergyMode::Stretch => "stretch",
        }
    }

    /// Inverse of [`Self::name`]; lists the valid modes on error.
    pub fn parse(name: &str) -> Result<EnergyMode> {
        match name {
            "race-to-idle" => Ok(EnergyMode::RaceToIdle),
            "stretch" => Ok(EnergyMode::Stretch),
            other => bail!("unknown energy mode {other:?} (expected race-to-idle or stretch)"),
        }
    }
}

impl Default for EnergyMode {
    fn default() -> Self {
        EnergyMode::RaceToIdle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_names_round_trip() {
        for c in EnergyChannel::all() {
            assert_eq!(EnergyChannel::parse(c.name()), Some(c));
        }
        assert_eq!(EnergyChannel::parse("warp-drive"), None);
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [EnergyMode::RaceToIdle, EnergyMode::Stretch] {
            assert_eq!(EnergyMode::parse(m.name()).unwrap(), m);
        }
        let err = EnergyMode::parse("sprint").unwrap_err().to_string();
        assert!(err.contains("race-to-idle") && err.contains("stretch"), "{err}");
        assert_eq!(EnergyMode::default(), EnergyMode::RaceToIdle);
    }
}
