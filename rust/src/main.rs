//! `neutron` — the eIQ-Neutron reproduction CLI.
//!
//! Subcommands:
//!   compile   --model <name> [--monolithic]     compile + report stats
//!   simulate  --model <name> [--serialize-dae]  compile + cycle simulation
//!   infer     [--requests N]                    e2e PJRT inference (needs artifacts)
//!   serve     [--requests N] [--instances K] [--models a,b,c] [--seed S]
//!             [--mean-gap-cycles G] [--queue-capacity C] [--policy reject-newest|drop-oldest]
//!             [--max-batch B] [--age-after-cycles A] [--priority-mix R,S,B]
//!                                               multi-tenant serving simulation
//!   report    table1|table2|table3|table4|fig4|fig6|genai
//!   list                                        list zoo models

use anyhow::{bail, Result};

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, CompileOptions};
use eiq_neutron::coordinator::{emit, Executor};
use eiq_neutron::report;
use eiq_neutron::runtime::{literal_i8, literal_to_i32s, Manifest, Runtime};
use eiq_neutron::serve::{
    serve, AdmissionPolicy, PriorityMix, SchedulerOptions, ServeOptions,
};
use eiq_neutron::sim::{simulate, SimOptions};
use eiq_neutron::util::cli::Args;
use eiq_neutron::zoo::ModelId;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("list") => {
            for id in ModelId::all() {
                let (gm, mp) = id.table_iv_reference();
                println!("{:<22} {:>6.2} GMACs  {:>5.1} M params", id.display_name(), gm, mp);
            }
            Ok(())
        }
        Some("compile") => cmd_compile(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("report") => cmd_report(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!(
                "usage: neutron <list|compile|simulate|infer|serve|report> \
                 [--model NAME] [--monolithic] [--requests N] [--instances K] \
                 [--models a,b,c] [--seed S] [--mean-gap-cycles G] \
                 [--queue-capacity C] [--policy reject-newest|drop-oldest] \
                 [--max-batch B] [--age-after-cycles A] [--priority-mix R,S,B]"
            );
            Ok(())
        }
    }
}

fn model_from(args: &Args) -> Result<ModelId> {
    let name = args.opt("model", "mobilenet-v2");
    match ModelId::parse(&name) {
        Some(id) => Ok(id),
        None => bail!("unknown model {name:?} — try `neutron list`"),
    }
}

fn opts_from(args: &Args) -> CompileOptions {
    if args.has_flag("monolithic") {
        CompileOptions::monolithic()
    } else {
        CompileOptions::default_partitioned()
    }
}

fn cmd_compile(args: &Args) -> Result<()> {
    let id = model_from(args)?;
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let c = compile(&g, &cfg, &opts_from(args));
    println!("model:        {}", id.display_name());
    println!("ops / tiles:  {} / {}", g.ops.len(), c.program.tiles.len());
    println!("ticks:        {}", c.schedule.ticks.len());
    println!(
        "compile time: {} ms ({} CP subproblems, {} vars)",
        c.compile_ms, c.schedule.subproblems, c.schedule.variables
    );
    println!("est latency:  {:.2} ms", c.inference_ms);
    println!("eff TOPS:     {:.2}", c.effective_tops(&g));
    println!("LTP:          {:.1}", c.ltp(&cfg));
    println!("DDR traffic:  {:.1} MB", c.schedule.ddr.total_bytes() as f64 / 1e6);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let id = model_from(args)?;
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let c = compile(&g, &cfg, &opts_from(args));
    let sim_opts = SimOptions {
        serialize_dae: args.has_flag("serialize-dae"),
        ..Default::default()
    };
    let r = simulate(&c, &cfg, &sim_opts);
    println!("model:          {}", id.display_name());
    println!("sim latency:    {:.2} ms ({} cycles)", r.latency_ms, r.total_cycles);
    println!("effective TOPS: {:.2}", r.effective_tops(g.total_macs()));
    println!("DDR traffic:    {:.1} MB", r.ddr_bytes as f64 / 1e6);
    println!("peak TCM banks: {} / {}", r.peak_resident_banks, cfg.tcm_banks);
    println!("DM hiding:      {:.0}%", r.hiding_ratio() * 100.0);
    println!("bank conflicts: {}", r.bank_conflicts);
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let requests: usize = args.opt_parse("requests", 4);
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_hlo_text(manifest.artifact_path("model.path")?)?;

    // The quickstart model: simulated timing from the compiler over an
    // equivalent IR graph + real numerics from the AOT artifact.
    let shape: Vec<usize> = manifest
        .get("model.input_shape")?
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();
    let cfg = NeutronConfig::flagship_2tops();
    let g = report::quickstart_graph(shape[0], shape[2]);
    let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let p = emit(&c, "quickstart");
    let mut ex = Executor::new(cfg.clone(), p);

    let n = shape.iter().product::<usize>();
    for req in 0..requests {
        let payload = eiq_neutron::runtime::deterministic_i8(req as u64, n);
        let lit = literal_i8(&payload, &shape)?;
        let run = || -> Result<Vec<i32>> {
            let outs = exe.run(&[lit.clone()])?;
            literal_to_i32s(&outs[0])
        };
        let r = ex.run_request(Some(&run))?;
        let logits = r.logits.as_ref().unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "req {req}: class={argmax} sim={:.3} ms host={} µs logits[0..4]={:?}",
            r.sim_ms,
            r.host_us,
            &logits[..4.min(logits.len())]
        );
    }
    println!("{}", ex.metrics.summary(cfg.freq_ghz));
    Ok(())
}

/// Numeric flag that bails on unparseable input instead of silently
/// falling back to the default (a typo in an overload knob must not
/// silently run a different experiment).
fn strict_parse<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    match args.options.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--{key} wants a number, got {v:?}")),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let models_raw = args.opt("models", "mobilenet-v2,mobilenet-v1,efficientnet-lite0");
    let mut models = Vec::new();
    for name in models_raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match ModelId::parse(name) {
            Some(id) => models.push(id),
            None => bail!("unknown model {name:?} — try `neutron list`"),
        }
    }
    if models.is_empty() {
        bail!("--models needs at least one model");
    }
    // 0 means "unbounded" / "disabled" for the optional knobs, so plain
    // integer flags cover both shapes.
    let queue_capacity = match strict_parse(args, "queue-capacity", 0usize)? {
        0 => None,
        cap => Some(cap),
    };
    let age_after_cycles = match strict_parse(args, "age-after-cycles", 0u64)? {
        0 => None,
        age => Some(age),
    };
    let policy_raw = args.opt("policy", "reject-newest");
    let Some(policy) = AdmissionPolicy::parse(&policy_raw) else {
        bail!("unknown admission policy {policy_raw:?} (reject-newest or drop-oldest)");
    };
    let mix_raw = args.opt("priority-mix", "1,2,1");
    let weights: Vec<u32> = mix_raw
        .split(',')
        .map(|w| w.trim().parse::<u32>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("--priority-mix wants three integers, got {mix_raw:?}"))?;
    let [realtime, standard, batch] = weights[..] else {
        bail!("--priority-mix wants realtime,standard,batch weights, got {mix_raw:?}");
    };
    if realtime as u64 + standard as u64 + batch as u64 == 0 {
        bail!("--priority-mix needs at least one non-zero weight");
    }
    let opts = ServeOptions {
        models,
        requests: strict_parse(args, "requests", 200)?,
        mean_gap_cycles: strict_parse(args, "mean-gap-cycles", 600_000)?,
        seed: strict_parse(args, "seed", 7)?,
        priority_mix: PriorityMix { realtime, standard, batch },
        scheduler: SchedulerOptions {
            instances: strict_parse(args, "instances", 2)?,
            queue_capacity,
            policy,
            max_batch: strict_parse(args, "max-batch", 1)?,
            age_after_cycles,
        },
    };
    let cfg = NeutronConfig::flagship_2tops();
    let report = serve(&cfg, &opts);
    print!("{}", report.summary());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    match args.positionals.first().map(String::as_str) {
        Some("table1") => report::table1(),
        Some("table2") => report::table2(args.has_flag("quick")),
        Some("table3") => report::table3(),
        Some("table4") => report::table4(),
        Some("fig4") => report::fig4(),
        Some("fig6") => report::fig6(),
        Some("genai") => report::genai(),
        other => bail!("unknown report {other:?} (table1..4, fig4, fig6, genai)"),
    }
    Ok(())
}
