//! `neutron` — the eIQ-Neutron reproduction CLI.
//!
//! Subcommands:
//!   compile   --model <name> [--monolithic] [--calibration FILE]
//!             [--save DIR] [--load DIR]         compile + report stats; --save/--load
//!                                               persist/reuse a .npu artifact (pins the
//!                                               deterministic serving budgets)
//!   simulate  --model <name> [--serialize-dae]  compile + cycle simulation
//!   infer     [--requests N]                    e2e PJRT inference (needs artifacts)
//!   serve     [--requests N] [--instances K] [--models a,b,c] [--seed S]
//!             [--mean-gap-cycles G] [--queue-capacity C] [--policy reject-newest|drop-oldest]
//!             [--max-batch B] [--dynamic-batch] [--age-after-cycles A] [--priority-mix R,S,B]
//!             [--pipeline] [--residency] [--warm-routing] [--residency-capacity BYTES]
//!             [--residency-quota BYTES] [--decode] [--prompt-tokens P] [--decode-tokens D]
//!             [--max-context M] [--continuous-batch]
//!             [--energy] [--energy-mode race-to-idle|stretch] [--energy-budget J]
//!             [--record FILE] [--calibration FILE] [--artifact-dir DIR]
//!                                               multi-tenant serving simulation;
//!                                               --decode switches to autoregressive
//!                                               prefill+decode traffic (TTFT/TPOT in the
//!                                               report), --continuous-batch admits new
//!                                               sequences into running decode batches;
//!                                               --energy meters femtojoule attribution
//!                                               (per-inference/per-token joules in the
//!                                               report; --energy-mode and --energy-budget
//!                                               trade makespan for joules);
//!                                               --artifact-dir warms the compile cache
//!                                               from persistent .npu artifacts (and
//!                                               saves what it had to compile cold)
//!   record    FILE [serve options]              serve + write a replayable JSONL trace
//!   replay    FILE [--speed F] [--calibration FILE]
//!                                               replay a recorded trace (bit-identical
//!                                               report; --speed time-warps offered load,
//!                                               --calibration recompiles under a fit)
//!   validate  [FILE | --models a,b,c] [--save-calibration FILE]
//!             [--decode-curve [--max-context M]]
//!             [--energy [--save-energy-calibration FILE]]
//!                                               predicted-vs-observed per-op-class calibration;
//!                                               --decode-curve instead fits the per-token
//!                                               context-length cost curve of each
//!                                               decode-capable model's bucket ladder;
//!                                               --energy fits per-channel energy scales
//!                                               from a trace recorded with --energy
//!   tune      [--trace FILE | serve options] [--save-calibration FILE] [--energy]
//!                                               record → fit → recompile → replay loop;
//!                                               --energy fits the energy calibration
//!                                               instead (no recompile leg)
//!   report    table1|table2|table3|table4|fig4|fig6|genai
//!   list      [--energy-calibration FILE]       list zoo models; with a calibration,
//!                                               adds an estimated J/inference column

use anyhow::{anyhow, bail, Result};

use eiq_neutron::arch::NeutronConfig;
use eiq_neutron::compiler::{compile, compile_with_stats, CompileOptions, CostCalibration};
use eiq_neutron::energy::{
    fj_to_joules, EnergyCalibration, EnergyCalibrationFile, EnergyChannel, EnergyMode,
    EnergyModel, FJ_PER_JOULE,
};
use eiq_neutron::coordinator::{emit, Executor};
use eiq_neutron::report;
use eiq_neutron::runtime::{
    literal_i8, literal_to_i32s, options_fingerprint, ArtifactStore, Manifest, Runtime,
    StoreError,
};
use eiq_neutron::serve::{
    deterministic_compile_options, serve_with_cache, AdmissionPolicy, CompileCache,
    PriorityMix, SchedulerOptions, ServeOptions, MAX_MEAN_GAP_CYCLES,
};
use eiq_neutron::sim::{simulate, SimOptions};
use eiq_neutron::trace::{
    serve_recorded, tune_energy_from_trace, tune_from_trace, CalibrationFile,
    DecodeCurveReport, EnergyFitReport, ReplayDriver, ReplayOptions, Trace, ValidationReport,
};
use eiq_neutron::util::cli::Args;
use eiq_neutron::zoo::ModelId;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(&args),
        Some("compile") => cmd_compile(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("record") => cmd_record(&args),
        Some("replay") => cmd_replay(&args),
        Some("validate") => cmd_validate(&args),
        Some("tune") => cmd_tune(&args),
        Some("report") => cmd_report(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!(
                "usage: neutron <list|compile|simulate|infer|serve|record|replay|validate|tune|report> \
                 [--model NAME] [--monolithic] [--requests N] [--instances K] \
                 [--models a,b,c] [--seed S] [--mean-gap-cycles G] \
                 [--queue-capacity C] [--policy reject-newest|drop-oldest] \
                 [--max-batch B] [--dynamic-batch] [--age-after-cycles A] \
                 [--priority-mix R,S,B] [--pipeline] [--residency] [--warm-routing] \
                 [--residency-capacity BYTES] [--residency-quota BYTES] [--decode] \
                 [--prompt-tokens P] [--decode-tokens D] [--max-context M] \
                 [--continuous-batch] [--energy] [--energy-mode race-to-idle|stretch] \
                 [--energy-budget J] [--energy-calibration FILE] \
                 [--save-energy-calibration FILE] [--record FILE] [--calibration FILE] \
                 [--speed F] [--save-calibration FILE] [--trace FILE] [--decode-curve]"
            );
            Ok(())
        }
    }
}

/// Strict flag surface for the non-serve subcommands: an unknown flag
/// must error, never silently run a different experiment (the serve
/// surface enforces the same rule through `serve_options_from`).
fn reject_unknown_keys(args: &Args, known: &[&str]) -> Result<()> {
    for key in args.options.keys().chain(args.flags.iter()) {
        if !known.contains(&key.as_str()) {
            bail!("unknown flag --{key} (known: --{})", known.join(", --"));
        }
    }
    Ok(())
}

/// Reject the bare-flag spelling of options that need a value — a
/// value-less `--calibration` or `--save-calibration` would otherwise
/// silently behave as if the flag were absent.
fn require_value(args: &Args, keys: &[&str]) -> Result<()> {
    for &key in keys {
        if args.flags.iter().any(|f| f == key) {
            bail!("--{key} wants a value");
        }
    }
    Ok(())
}

/// Load the `--calibration FILE` fit (identity when the flag is absent),
/// refusing a file measured on a different config.
fn calibration_from(args: &Args, cfg: &NeutronConfig) -> Result<CostCalibration> {
    require_value(args, &["calibration"])?;
    match args.options.get("calibration") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read calibration file {path:?}: {e}"))?;
            CalibrationFile::parse(&text)
                .map_err(|e| anyhow!("calibration file {path:?}: {e}"))?
                .calibration_for(cfg)
        }
        None => Ok(CostCalibration::identity()),
    }
}

/// Write a fitted calibration to `path` as a calibration file.
fn save_calibration(path: &str, cfg: &NeutronConfig, calibration: CostCalibration) -> Result<()> {
    let guarded_note = if calibration.is_identity() { " (identity)" } else { "" };
    std::fs::write(path, CalibrationFile::new(cfg, calibration).to_json())
        .map_err(|e| anyhow!("cannot write calibration file {path:?}: {e}"))?;
    eprintln!("saved calibration{guarded_note} to {path}");
    Ok(())
}

/// Load the `--energy-calibration FILE` per-channel fit (identity when
/// the flag is absent), refusing a file measured on a different config.
fn energy_calibration_from(args: &Args, cfg: &NeutronConfig) -> Result<EnergyCalibration> {
    require_value(args, &["energy-calibration"])?;
    match args.options.get("energy-calibration") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read energy calibration file {path:?}: {e}"))?;
            EnergyCalibrationFile::parse(&text)
                .map_err(|e| anyhow!("energy calibration file {path:?}: {e}"))?
                .calibration_for(cfg)
        }
        None => Ok(EnergyCalibration::identity()),
    }
}

/// Write a fitted energy calibration to `path` as a calibration file.
fn save_energy_calibration(
    path: &str,
    cfg: &NeutronConfig,
    calibration: EnergyCalibration,
) -> Result<()> {
    let guarded_note = if calibration.is_identity() { " (identity)" } else { "" };
    std::fs::write(path, EnergyCalibrationFile::new(cfg, calibration).to_json())
        .map_err(|e| anyhow!("cannot write energy calibration file {path:?}: {e}"))?;
    eprintln!("saved energy calibration{guarded_note} to {path}");
    Ok(())
}

/// `neutron list`: the zoo roster. With `--energy-calibration FILE` each
/// row gains the analytic estimated joules per single-shot inference
/// under that fit (the same `EnergyModel::predict_inference` the energy
/// calibration loop scores).
fn cmd_list(args: &Args) -> Result<()> {
    reject_unknown_keys(args, &["energy-calibration"])?;
    require_value(args, &["energy-calibration"])?;
    let with_energy = args.options.contains_key("energy-calibration");
    let cfg = NeutronConfig::flagship_2tops();
    let calibration = energy_calibration_from(args, &cfg)?;
    let model = EnergyModel::for_config(&cfg);
    for id in ModelId::all() {
        let (gm, mp) = id.table_iv_reference();
        let decode = if id.decode_config().is_some() { "  [decode]" } else { "" };
        if with_energy {
            let g = id.build();
            let predicted = model.predict_inference(&cfg, g.total_macs(), g.total_params());
            let fj = calibration.apply(EnergyChannel::Compute, predicted.compute_fj)
                + calibration.apply(EnergyChannel::Dma, predicted.dma_fj)
                + calibration.apply(EnergyChannel::Idle, predicted.idle_fj);
            println!(
                "{:<22} {:>6.2} GMACs  {:>5.1} M params  {:>10.6} J/inf{decode}",
                id.display_name(),
                gm,
                mp,
                fj_to_joules(fj)
            );
        } else {
            println!(
                "{:<22} {:>6.2} GMACs  {:>5.1} M params{decode}",
                id.display_name(),
                gm,
                mp
            );
        }
    }
    Ok(())
}

fn model_from(args: &Args) -> Result<ModelId> {
    let name = args.opt("model", "mobilenet-v2");
    match ModelId::parse(&name) {
        Some(id) => Ok(id),
        None => bail!("unknown model {name:?} — try `neutron list`"),
    }
}

fn opts_from(args: &Args) -> CompileOptions {
    if args.has_flag("monolithic") {
        CompileOptions::monolithic()
    } else {
        CompileOptions::default_partitioned()
    }
}

fn cmd_compile(args: &Args) -> Result<()> {
    reject_unknown_keys(args, &["model", "monolithic", "calibration", "save", "load"])?;
    require_value(args, &["model", "save", "load"])?;
    let id = model_from(args)?;
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let calibration = calibration_from(args, &cfg)?;
    let save_dir = args.options.get("save");
    let load_dir = args.options.get("load");
    if (save_dir.is_some() || load_dir.is_some()) && args.has_flag("monolithic") {
        bail!(
            "--save/--load pin the deterministic serving budgets so on-disk artifacts \
             match what `neutron serve --artifact-dir` expects; they cannot combine \
             with --monolithic"
        );
    }
    let opts = if save_dir.is_some() || load_dir.is_some() {
        CompileOptions { calibration, ..deterministic_compile_options() }
    } else {
        CompileOptions { calibration, ..opts_from(args) }
    };
    let fp = options_fingerprint(&opts);
    let mut loaded_from = None;
    // Solver stats exist only when this invocation actually ran the CP
    // passes — a loaded artifact carries none (they are not persisted).
    let (c, solver_stats) = match load_dir {
        Some(dir) => {
            let store =
                ArtifactStore::open(dir.as_str()).map_err(|e| anyhow!("--load {dir:?}: {e}"))?;
            match store.load(id, &cfg, &opts.calibration, fp) {
                Ok(c) => {
                    loaded_from = Some(store.path_for(id, &cfg, &opts.calibration));
                    (c, None)
                }
                Err(e) => {
                    eprintln!("artifact load failed ({e}); compiling cold");
                    let (c, st) = compile_with_stats(&g, &cfg, &opts);
                    (c, Some(st))
                }
            }
        }
        None => {
            let (c, st) = compile_with_stats(&g, &cfg, &opts);
            (c, Some(st))
        }
    };
    if let Some(dir) = save_dir {
        let store =
            ArtifactStore::open(dir.as_str()).map_err(|e| anyhow!("--save {dir:?}: {e}"))?;
        let path = store.save(id, &cfg, &c, fp).map_err(|e| anyhow!("--save {dir:?}: {e}"))?;
        eprintln!("saved artifact to {}", path.display());
    }
    println!("model:        {}", id.display_name());
    if let Some(p) = &loaded_from {
        println!("artifact:     loaded from {} (0 CP solves)", p.display());
    }
    if !c.calibration.is_identity() {
        println!("calibration:  {} fitted class scale(s)", c.calibration.scales().len());
    }
    println!("ops / tiles:  {} / {}", g.ops.len(), c.program.tiles.len());
    println!("ticks:        {}", c.schedule.ticks.len());
    println!(
        "compile time: {} ms ({} CP subproblems, {} vars)",
        c.compile_ms, c.schedule.subproblems, c.schedule.variables
    );
    if let Some(st) = &solver_stats {
        println!(
            "CP solver:    {} nodes, {} propagations, {} tightenings, {} entailed, \
             {} backtracks, peak trail {}",
            st.nodes, st.propagations, st.tightenings, st.entailments, st.backtracks,
            st.peak_trail
        );
        if st.hints_rejected > 0 {
            println!("warm seeds:   {} rejected (degraded to cold search)", st.hints_rejected);
        }
    }
    println!("est latency:  {:.2} ms", c.inference_ms);
    println!("eff TOPS:     {:.2}", c.effective_tops(&g));
    println!("LTP:          {:.1}", c.ltp(&cfg));
    println!("DDR traffic:  {:.1} MB", c.schedule.ddr.total_bytes() as f64 / 1e6);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let id = model_from(args)?;
    let g = id.build();
    let cfg = NeutronConfig::flagship_2tops();
    let c = compile(&g, &cfg, &opts_from(args));
    let sim_opts = SimOptions {
        serialize_dae: args.has_flag("serialize-dae"),
        ..Default::default()
    };
    let r = simulate(&c, &cfg, &sim_opts);
    println!("model:          {}", id.display_name());
    println!("sim latency:    {:.2} ms ({} cycles)", r.latency_ms, r.total_cycles);
    println!("effective TOPS: {:.2}", r.effective_tops(g.total_macs()));
    println!("DDR traffic:    {:.1} MB", r.ddr_bytes as f64 / 1e6);
    println!("peak TCM banks: {} / {}", r.peak_resident_banks, cfg.tcm_banks);
    println!("DM hiding:      {:.0}%", r.hiding_ratio() * 100.0);
    println!("bank conflicts: {}", r.bank_conflicts);
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let requests: usize = args.opt_parse("requests", 4);
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_hlo_text(manifest.artifact_path("model.path")?)?;

    // The quickstart model: simulated timing from the compiler over an
    // equivalent IR graph + real numerics from the AOT artifact.
    let shape: Vec<usize> = manifest
        .get("model.input_shape")?
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();
    let cfg = NeutronConfig::flagship_2tops();
    let g = report::quickstart_graph(shape[0], shape[2]);
    let c = compile(&g, &cfg, &CompileOptions::default_partitioned());
    let p = emit(&c, "quickstart");
    let mut ex = Executor::new(cfg.clone(), p);

    let n = shape.iter().product::<usize>();
    for req in 0..requests {
        let payload = eiq_neutron::runtime::deterministic_i8(req as u64, n);
        let lit = literal_i8(&payload, &shape)?;
        let run = || -> Result<Vec<i32>> {
            let outs = exe.run(&[lit.clone()])?;
            literal_to_i32s(&outs[0])
        };
        let r = ex.run_request(Some(&run))?;
        let logits = r.logits.as_ref().unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by_key(|&(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "req {req}: class={argmax} sim={:.3} ms host={} µs logits[0..4]={:?}",
            r.sim_ms,
            r.host_us,
            &logits[..4.min(logits.len())]
        );
    }
    println!("{}", ex.metrics.summary(cfg.freq_ghz));
    Ok(())
}

/// Parse the model list shared by `serve`, `record` and `validate`.
fn models_from(args: &Args) -> Result<Vec<ModelId>> {
    let models_raw = args.opt("models", "mobilenet-v2,mobilenet-v1,efficientnet-lite0");
    let mut models = Vec::new();
    for name in models_raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match ModelId::parse(name) {
            Some(id) => models.push(id),
            None => bail!("unknown model {name:?} — try `neutron list`"),
        }
    }
    if models.is_empty() {
        bail!("--models needs at least one model");
    }
    Ok(models)
}

/// Every flag the `serve` / `record` experiment surface understands
/// (`out` is `record`'s alternative to the positional trace path).
const SERVE_KEYS: [&str; 26] = [
    "models",
    "requests",
    "mean-gap-cycles",
    "seed",
    "instances",
    "queue-capacity",
    "policy",
    "max-batch",
    "dynamic-batch",
    "age-after-cycles",
    "priority-mix",
    "pipeline",
    "residency",
    "warm-routing",
    "residency-capacity",
    "residency-quota",
    "decode",
    "prompt-tokens",
    "decode-tokens",
    "max-context",
    "continuous-batch",
    "energy",
    "energy-mode",
    "energy-budget",
    "record",
    "out",
];

/// Build `ServeOptions` from the command line under strict parsing: an
/// unknown flag, a typo'd value or a degenerate knob (`--max-batch 0`,
/// `--instances 0`, contradictory `--dynamic-batch` without batching
/// headroom) is a clear error, never a silently different experiment —
/// especially since `--record` stamps the knobs into the trace header as
/// ground truth. `extra_keys` names subcommand-specific flags that are
/// allowed alongside the serve surface (e.g. `--calibration` on `serve`).
fn serve_options_from(args: &Args, extra_keys: &[&str]) -> Result<ServeOptions> {
    for key in args.options.keys().chain(args.flags.iter()) {
        if !SERVE_KEYS.contains(&key.as_str()) && !extra_keys.contains(&key.as_str()) {
            bail!("unknown flag --{key} (known: --{})", SERVE_KEYS.join(", --"));
        }
    }
    let models = models_from(args)?;
    let strict = |e: String| anyhow!("{e}");
    // 0 means "unbounded" / "disabled" for the optional knobs, so plain
    // integer flags cover both shapes.
    let queue_capacity = match args.opt_strict("queue-capacity", 0usize).map_err(strict)? {
        0 => None,
        cap => Some(cap),
    };
    let age_after_cycles = match args.opt_strict("age-after-cycles", 0u64).map_err(strict)? {
        0 => None,
        age => Some(age),
    };
    let policy_raw = args.opt("policy", "reject-newest");
    let Some(policy) = AdmissionPolicy::parse(&policy_raw) else {
        bail!("unknown admission policy {policy_raw:?} (reject-newest or drop-oldest)");
    };
    let mix_raw = args.opt("priority-mix", "1,2,1");
    let weights: Vec<u32> = mix_raw
        .split(',')
        .map(|w| w.trim().parse::<u32>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow!("--priority-mix wants three integers, got {mix_raw:?}"))?;
    let [realtime, standard, batch] = weights[..] else {
        bail!("--priority-mix wants realtime,standard,batch weights, got {mix_raw:?}");
    };
    if realtime as u64 + standard as u64 + batch as u64 == 0 {
        bail!("--priority-mix needs at least one non-zero weight");
    }
    let mean_gap_cycles = args.opt_strict("mean-gap-cycles", 600_000u64).map_err(strict)?;
    if mean_gap_cycles > MAX_MEAN_GAP_CYCLES {
        bail!("--mean-gap-cycles {mean_gap_cycles} exceeds the maximum {MAX_MEAN_GAP_CYCLES}");
    }
    let max_batch = args.opt_strict_min("max-batch", 1usize, 1).map_err(strict)?;
    let dynamic_batch = args.has_flag("dynamic-batch");
    if dynamic_batch && max_batch < 2 {
        bail!(
            "contradictory knobs: --dynamic-batch needs batching headroom \
             (--max-batch >= 2, got {max_batch})"
        );
    }
    let pipeline = args.has_flag("pipeline");
    let weight_residency = args.has_flag("residency");
    let warm_routing = args.has_flag("warm-routing");
    if warm_routing && !weight_residency {
        bail!(
            "contradictory knobs: --warm-routing needs --residency \
             (there is no warm state to route to)"
        );
    }
    if args.flags.iter().any(|f| f == "residency-capacity") {
        bail!("--residency-capacity wants a byte count");
    }
    let residency_capacity_bytes =
        match args.opt_strict("residency-capacity", 0u64).map_err(strict)? {
            0 => None,
            cap => Some(cap),
        };
    if residency_capacity_bytes.is_some() && !weight_residency {
        bail!("contradictory knobs: --residency-capacity needs --residency");
    }
    if args.flags.iter().any(|f| f == "residency-quota") {
        bail!("--residency-quota wants a byte count");
    }
    let residency_quota_bytes = match args.opt_strict("residency-quota", 0u64).map_err(strict)? {
        0 => None,
        quota => Some(quota),
    };
    if residency_quota_bytes.is_some() && !weight_residency {
        bail!(
            "contradictory knobs: --residency-quota needs --residency \
             (the quota caps per-owner TCM residency, which is off)"
        );
    }
    if let (Some(quota), Some(cap)) = (residency_quota_bytes, residency_capacity_bytes) {
        if quota > cap {
            bail!(
                "contradictory knobs: --residency-quota {quota} exceeds \
                 --residency-capacity {cap} (a per-owner cap above the pool \
                 size can never bind)"
            );
        }
    }
    let energy = args.has_flag("energy");
    for key in ["energy-mode", "energy-budget"] {
        if args.flags.iter().any(|f| f == key) {
            bail!("--{key} wants a value");
        }
    }
    let energy_mode = match args.options.get("energy-mode") {
        Some(raw) => {
            if !energy {
                bail!(
                    "contradictory knobs: --energy-mode needs --energy \
                     (there is no meter to spend differently)"
                );
            }
            EnergyMode::parse(raw)?
        }
        None => EnergyMode::default(),
    };
    let energy_budget_fj = match args.options.get("energy-budget") {
        Some(_) => {
            if !energy {
                bail!(
                    "contradictory knobs: --energy-budget needs --energy \
                     (an unmetered run cannot spend against a budget)"
                );
            }
            let joules = args.opt_strict("energy-budget", 0.0f64).map_err(strict)?;
            if !joules.is_finite() || joules <= 0.0 {
                bail!("--energy-budget wants a positive joule count, got {joules}");
            }
            Some((joules * FJ_PER_JOULE).round() as u64)
        }
        None => None,
    };
    let decode = args.has_flag("decode");
    let continuous_batch = args.has_flag("continuous-batch");
    if continuous_batch && !decode {
        bail!(
            "contradictory knobs: --continuous-batch needs --decode \
             (single-shot inference has no decode rounds to join)"
        );
    }
    for key in ["prompt-tokens", "decode-tokens", "max-context"] {
        if args.flags.iter().any(|f| f == key) {
            bail!("--{key} wants a token count");
        }
        if !decode && args.options.contains_key(key) {
            bail!(
                "contradictory knobs: --{key} needs --decode \
                 (token counts only shape autoregressive traffic)"
            );
        }
    }
    let prompt_tokens = args.opt_strict_min("prompt-tokens", 8u32, 1).map_err(strict)?;
    let decode_tokens = args.opt_strict_min("decode-tokens", 8u32, 1).map_err(strict)?;
    let max_context = args.opt_strict_min("max-context", 32u32, 2).map_err(strict)?;
    if decode {
        if prompt_tokens.saturating_add(decode_tokens) > max_context {
            bail!(
                "contradictory knobs: --prompt-tokens {prompt_tokens} + \
                 --decode-tokens {decode_tokens} exceeds --max-context {max_context}"
            );
        }
        for &model in &models {
            if model.decode_config().is_none() {
                bail!(
                    "--decode needs autoregressive models, but {} has no decode \
                     configuration — try `neutron list` and pick [decode] entries",
                    model.slug()
                );
            }
        }
    }
    Ok(ServeOptions {
        models,
        requests: args.opt_strict("requests", 200usize).map_err(strict)?,
        mean_gap_cycles,
        seed: args.opt_strict("seed", 7u64).map_err(strict)?,
        priority_mix: PriorityMix { realtime, standard, batch },
        decode,
        prompt_tokens,
        decode_tokens,
        max_context,
        scheduler: SchedulerOptions {
            instances: args.opt_strict_min("instances", 2usize, 1).map_err(strict)?,
            queue_capacity,
            policy,
            max_batch,
            dynamic_batch,
            age_after_cycles,
            pipeline,
            weight_residency,
            warm_routing,
            residency_capacity_bytes,
            residency_quota_bytes,
            continuous_batch,
            energy,
            energy_mode,
            energy_budget_fj,
        },
    })
}

/// Run the serve scenario, record it into `path`, and print the report —
/// stdout carries exactly the report summary so `neutron replay` output
/// can be diffed against it.
fn serve_and_record(opts: &ServeOptions, path: &str) -> Result<()> {
    // Fail on an unwritable trace path BEFORE the (possibly long) run, so
    // a typo'd --record never throws the whole simulation away. The probe
    // must not truncate: an existing trace stays intact until the new one
    // is ready to replace it.
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| anyhow!("cannot write trace file {path:?}: {e}"))?;
    let cfg = NeutronConfig::flagship_2tops();
    let mut cache = CompileCache::for_serving(cfg.clone());
    let (report, trace) = serve_recorded(&cfg, opts, &mut cache);
    // Report first: even if the write fails now, the run is not lost.
    print!("{}", report.summary());
    if cache.hints_rejected > 0 {
        eprintln!(
            "warm-start: {} seed(s) rejected by the solver (degraded to cold search)",
            cache.hints_rejected
        );
    }
    std::fs::write(path, trace.to_jsonl())?;
    eprintln!(
        "recorded {} request(s), {} completion(s), {} model profile(s) to {path}",
        trace.requests.len(),
        trace.completions.len(),
        trace.model_ops.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    require_value(args, &["calibration", "artifact-dir"])?;
    let opts = serve_options_from(args, &["calibration", "artifact-dir"])?;
    match args.options.get("record") {
        Some(path) => {
            if args.options.contains_key("calibration") {
                bail!(
                    "--record and --calibration cannot be combined: the trace header \
                     does not carry a calibration, so the recording could never replay \
                     bit-identically — record uncalibrated, then `neutron tune` or \
                     `neutron replay --calibration` against the trace"
                );
            }
            if args.options.contains_key("artifact-dir") {
                bail!(
                    "--record and --artifact-dir cannot be combined: the recorded \
                     compile timings are ground truth for the trace, and a disk-warmed \
                     cache would skip the compiles being measured"
                );
            }
            serve_and_record(&opts, path)
        }
        None if args.has_flag("record") => bail!("--record wants a trace file path"),
        None => {
            let cfg = NeutronConfig::flagship_2tops();
            let calibration = calibration_from(args, &cfg)?;
            let mut cache = CompileCache::for_serving_with(cfg.clone(), calibration.clone());
            if let Some(dir) = args.options.get("artifact-dir") {
                prewarm_from_store(dir, &opts.models, &cfg, &calibration, &mut cache)?;
            }
            print!("{}", serve_with_cache(&cfg, &opts, &mut cache).summary());
            if cache.hints_rejected > 0 {
                eprintln!(
                    "warm-start: {} seed(s) rejected by the solver (degraded to cold search)",
                    cache.hints_rejected
                );
            }
            Ok(())
        }
    }
}

/// Warm the compile cache from a persistent `.npu` store before serving:
/// load every valid artifact, compile-and-save the rest. Runs before
/// `serve_with_cache` snapshots the cache counters, so a fully warmed
/// restart reports zero cold compiles ("/ 0 misses") — a corrupt or
/// mismatched artifact costs one recompile, never a wrong plan.
fn prewarm_from_store(
    dir: &str,
    models: &[ModelId],
    cfg: &NeutronConfig,
    calibration: &CostCalibration,
    cache: &mut CompileCache,
) -> Result<()> {
    let store =
        ArtifactStore::open(dir).map_err(|e| anyhow!("--artifact-dir {dir:?}: {e}"))?;
    let fp = options_fingerprint(&deterministic_compile_options());
    let (mut loaded, mut compiled_cold) = (0usize, 0usize);
    for &model in models {
        match store.load(model, cfg, calibration, fp) {
            Ok(c) => {
                cache.insert_artifact(model, cfg, c);
                loaded += 1;
            }
            Err(e) => {
                let absent = matches!(
                    &e,
                    StoreError::Io(io) if io.kind() == std::io::ErrorKind::NotFound
                );
                if !absent {
                    eprintln!("artifact for {} rejected ({e}); recompiling", model.slug());
                }
                let entry = cache.get_with_calibration(model, cfg, calibration);
                store
                    .save(model, cfg, &entry.compiled, fp)
                    .map_err(|e| anyhow!("--artifact-dir {dir:?}: {e}"))?;
                compiled_cold += 1;
            }
        }
    }
    eprintln!("artifact store {dir}: {loaded} loaded, {compiled_cold} compiled + saved");
    Ok(())
}

fn cmd_record(args: &Args) -> Result<()> {
    if args.has_flag("calibration") {
        bail!(
            "recording is always uncalibrated (the trace header carries no calibration); \
             use `neutron replay --calibration` or `neutron tune` on the recorded trace"
        );
    }
    let Some(path) = args.positionals.first().cloned().or_else(|| args.options.get("out").cloned())
    else {
        bail!("usage: neutron record <trace.jsonl> [serve options]");
    };
    serve_and_record(&serve_options_from(args, &[])?, &path)
}

fn cmd_replay(args: &Args) -> Result<()> {
    reject_unknown_keys(args, &["speed", "calibration"])?;
    require_value(args, &["speed"])?;
    let Some(path) = args.positionals.first() else {
        bail!("usage: neutron replay <trace.jsonl> [--speed F] [--calibration FILE]");
    };
    let text = std::fs::read_to_string(path)?;
    let driver = ReplayDriver::from_jsonl(&text)?;
    let cfg = NeutronConfig::flagship_2tops();
    let opts = ReplayOptions {
        speed: args.opt_strict("speed", 1.0f64).map_err(|e| anyhow!("{e}"))?,
        calibration: calibration_from(args, &cfg)?,
    };
    let faithful = opts.is_faithful();
    let outcome = driver.replay_with_options(&cfg, &opts)?;
    print!("{}", outcome.report.summary());
    if faithful {
        if let Some(divergence) = outcome.divergence {
            bail!(
                "replay DIVERGED from the recording (timing model changed since capture?): \
                 {divergence}"
            );
        }
        eprintln!("replay matches the recorded completions and shed set");
    } else {
        eprintln!(
            "replay deviates from the recording by design (speed {}, {}) — \
             recorded completions not compared",
            opts.speed,
            if opts.calibration.is_identity() { "no calibration" } else { "calibrated" }
        );
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    reject_unknown_keys(
        args,
        &[
            "models",
            "save-calibration",
            "decode-curve",
            "max-context",
            "energy",
            "save-energy-calibration",
        ],
    )?;
    require_value(args, &["models", "save-calibration", "max-context", "save-energy-calibration"])?;
    let cfg = NeutronConfig::flagship_2tops();
    if args.options.contains_key("save-energy-calibration") && !args.has_flag("energy") {
        bail!(
            "contradictory knobs: --save-energy-calibration needs --energy \
             (the per-op-class timing fit saves via --save-calibration)"
        );
    }
    if args.has_flag("energy") {
        if args.has_flag("decode-curve") {
            bail!(
                "contradictory knobs: --energy fits per-channel energy scales, \
                 --decode-curve fits a context-length timing curve — pick one"
            );
        }
        if args.options.contains_key("save-calibration") {
            bail!(
                "contradictory knobs: --energy fits an energy calibration — \
                 save it with --save-energy-calibration, not --save-calibration"
            );
        }
        return cmd_validate_energy(args, &cfg);
    }
    if args.has_flag("decode-curve") {
        return cmd_validate_decode_curve(args, &cfg);
    }
    if args.options.contains_key("max-context") {
        bail!("--max-context only shapes --decode-curve validation");
    }
    let report = match args.positionals.first() {
        Some(path) => {
            if args.options.contains_key("models") {
                bail!(
                    "pass either a trace file or --models, not both — a trace already \
                     names the models it profiled"
                );
            }
            let text = std::fs::read_to_string(path)?;
            let trace = Trace::parse(&text)?;
            ValidationReport::from_trace(&trace)?
        }
        None => ValidationReport::from_models(&models_from(args)?, &cfg),
    };
    print!("{}", report.table());
    if let Some(path) = args.options.get("save-calibration") {
        save_calibration(path, &cfg, report.calibration_guarded())?;
    }
    Ok(())
}

/// `neutron validate --energy`: join the analytic energy predictions
/// against a metered trace's per-completion observations, report the
/// per-channel MAPE table and optionally save the guarded fit.
fn cmd_validate_energy(args: &Args, cfg: &NeutronConfig) -> Result<()> {
    if args.options.contains_key("max-context") {
        bail!("--max-context only shapes --decode-curve validation");
    }
    if args.options.contains_key("models") {
        bail!(
            "--energy fits against a metered trace's observations, which already \
             names its models — pass a trace recorded with --energy, not --models"
        );
    }
    let Some(path) = args.positionals.first() else {
        bail!(
            "usage: neutron validate --energy <trace.jsonl> \
             [--save-energy-calibration FILE] — the trace must be recorded \
             with `neutron record ... --energy`"
        );
    };
    let text = std::fs::read_to_string(path)?;
    let trace = Trace::parse(&text).map_err(|e| anyhow!("trace file {path:?}: {e}"))?;
    let report = EnergyFitReport::from_trace(&trace, cfg)?;
    print!("{}", report.table());
    if let Some(out) = args.options.get("save-energy-calibration") {
        save_energy_calibration(out, cfg, report.calibration_guarded())?;
    }
    Ok(())
}

/// `neutron validate --decode-curve`: compile each decode-capable model's
/// bucket ladder and fit the linear context-length cost curve against the
/// executor's observed per-step cycles — the decode analogue of the
/// per-op-class calibration table.
fn cmd_validate_decode_curve(args: &Args, cfg: &NeutronConfig) -> Result<()> {
    if args.positionals.first().is_some() {
        bail!(
            "--decode-curve fits the compiled ladder directly, not a trace — \
             pass --models (and optionally --max-context), no trace file"
        );
    }
    if args.options.contains_key("save-calibration") {
        bail!(
            "--decode-curve fits a context-length curve, not a per-op-class \
             calibration — --save-calibration does not apply"
        );
    }
    let max_context = args.opt_strict_min("max-context", 32u32, 2).map_err(|e| anyhow!("{e}"))?;
    // Without --models, sweep every decode-capable zoo entry; an explicit
    // list must be decode-capable or the error names the offender.
    let models: Vec<ModelId> = if args.options.contains_key("models") {
        let models = models_from(args)?;
        for &model in &models {
            if model.decode_config().is_none() {
                bail!(
                    "--decode-curve needs autoregressive models, but {} has no decode \
                     configuration — try `neutron list` and pick [decode] entries",
                    model.slug()
                );
            }
        }
        models
    } else {
        ModelId::all().into_iter().filter(|m| m.decode_config().is_some()).collect()
    };
    for model in models {
        print!("{}", DecodeCurveReport::from_model(model, max_context, cfg).table());
    }
    Ok(())
}

/// `neutron tune`: close the record → fit → recompile → replay loop. With
/// `--trace FILE` (or a positional path) an existing recording is tuned;
/// otherwise a synthetic serve run is recorded internally first using the
/// usual serve flags.
fn cmd_tune(args: &Args) -> Result<()> {
    let cfg = NeutronConfig::flagship_2tops();
    require_value(args, &["trace", "save-calibration", "save-energy-calibration"])?;
    if args.has_flag("record") || args.options.contains_key("out") {
        bail!("neutron tune records internally — pass --trace FILE to reuse a recording");
    }
    // `--energy` switches the whole loop to the energy fit: the same
    // trace, per-channel scales instead of per-op-class ones, and no
    // recompile/replay leg (the fit corrects predictions only).
    let energy = args.has_flag("energy");
    if energy && args.options.contains_key("save-calibration") {
        bail!(
            "contradictory knobs: --energy fits an energy calibration — \
             save it with --save-energy-calibration, not --save-calibration"
        );
    }
    if !energy && args.options.contains_key("save-energy-calibration") {
        bail!(
            "contradictory knobs: --save-energy-calibration needs --energy \
             (the per-op-class timing fit saves via --save-calibration)"
        );
    }
    let trace_path = args
        .options
        .get("trace")
        .cloned()
        .or_else(|| args.positionals.first().cloned());
    let trace = match &trace_path {
        Some(path) => {
            // Serve-shape flags describe the recording run; with an
            // existing trace they would be silently ignored — refuse.
            for key in args.options.keys().chain(args.flags.iter()) {
                if !["trace", "save-calibration", "energy", "save-energy-calibration"]
                    .contains(&key.as_str())
                {
                    bail!("--{key} has no effect when tuning an existing trace {path:?}");
                }
            }
            let text = std::fs::read_to_string(path)?;
            Trace::parse(&text).map_err(|e| anyhow!("trace file {path:?}: {e}"))?
        }
        None => {
            // `--energy` is part of the serve surface, so an energy tune
            // without a trace records a metered run automatically.
            let opts = serve_options_from(args, &["save-calibration", "save-energy-calibration"])?;
            let mut cache = CompileCache::for_serving(cfg.clone());
            let (_, trace) = serve_recorded(&cfg, &opts, &mut cache);
            eprintln!(
                "recorded {} request(s) over {} model(s) for tuning",
                trace.requests.len(),
                trace.meta.models.len()
            );
            trace
        }
    };
    if energy {
        let outcome = tune_energy_from_trace(&cfg, &trace)?;
        print!("{}", outcome.table());
        if let Some(path) = args.options.get("save-energy-calibration") {
            save_energy_calibration(path, &cfg, outcome.calibration.clone())?;
        }
        return Ok(());
    }
    let outcome = tune_from_trace(&cfg, &trace)?;
    print!("{}", outcome.table());
    if let Some(path) = args.options.get("save-calibration") {
        save_calibration(path, &cfg, outcome.calibration.clone())?;
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    match args.positionals.first().map(String::as_str) {
        Some("table1") => report::table1(),
        Some("table2") => report::table2(args.has_flag("quick")),
        Some("table3") => report::table3(),
        Some("table4") => report::table4(),
        Some("fig4") => report::fig4(),
        Some("fig6") => report::fig6(),
        Some("genai") => report::genai(),
        other => bail!("unknown report {other:?} (table1..4, fig4, fig6, genai)"),
    }
    Ok(())
}
