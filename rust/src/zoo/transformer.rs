//! Decoder-only transformer workload for the paper's Gen-AI claim (Sec. VI):
//! "tenfold speedups [for matrix-matrix multiplications] compared to
//! execution on four Cortex-A55 cores at 1.8× the clock frequency".
//!
//! Per Sec. IV-A, transformer GEMMs map onto the two tiling strategies by
//! treating the embedding dimension as C and the token dimension as H. The
//! builder emits the per-block GEMMs of a prefill pass (batch of `tokens`
//! tokens) as MatMul ops.

use crate::ir::{Activation, DType, Graph, OpKind, Shape, TensorKind};

/// Configuration of a decoder-only transformer.
#[derive(Debug, Clone, Copy)]
pub struct TransformerConfig {
    pub layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub heads: usize,
    pub tokens: usize,
    pub vocab: usize,
}

impl TransformerConfig {
    /// ~100 M-parameter GPT-style config (12 × 768, ff 3072), 128-token
    /// prefill — the "small real model" scale of the e2e example.
    pub fn gpt_100m(tokens: usize) -> Self {
        Self { layers: 12, d_model: 768, d_ff: 3072, heads: 12, tokens, vocab: 32000 }
    }

    /// Tiny config for tests.
    pub fn tiny(tokens: usize) -> Self {
        Self { layers: 2, d_model: 64, d_ff: 256, heads: 4, tokens, vocab: 512 }
    }
}

/// Build the prefill compute graph: tokens×d_model activations flowing
/// through QKV / attention-out / FFN GEMMs per layer (attention score
/// GEMMs included as MatMul with token-sized operands).
pub fn decoder_prefill(cfg: TransformerConfig) -> Graph {
    let mut g = Graph::new(format!(
        "decoder{}x{}t{}",
        cfg.layers, cfg.d_model, cfg.tokens
    ));
    // Activations are (tokens, d) with H=tokens, C=d per the paper's rule.
    let mut cur = g.add_tensor(
        "embeddings",
        Shape::hwc(cfg.tokens, 1, cfg.d_model),
        DType::Int8,
        TensorKind::Input,
    );
    let gemm = |g: &mut Graph, name: String, inp, in_f: usize, out_f: usize, rows: usize| {
        let w = g.add_tensor(
            format!("{name}.w"),
            Shape(vec![out_f, 1, 1, in_f]),
            DType::Int8,
            TensorKind::Parameter,
        );
        let out = g.add_tensor(
            format!("{name}.out"),
            Shape::hwc(rows, 1, out_f),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            name,
            OpKind::MatMul { out_features: out_f },
            vec![inp],
            Some(w),
            out,
            Activation::None,
        );
        out
    };
    for l in 0..cfg.layers {
        let d = cfg.d_model;
        let q = gemm(&mut g, format!("l{l}.q"), cur, d, d, cfg.tokens);
        let _k = gemm(&mut g, format!("l{l}.k"), cur, d, d, cfg.tokens);
        let _v = gemm(&mut g, format!("l{l}.v"), cur, d, d, cfg.tokens);
        // Attention scores + context: tokens×tokens and tokens×d GEMMs.
        // Modeled as parameter-free MatMuls would misreport params; use
        // MatMul with the K/V tensor as the "parameter" operand shape-wise.
        let scores = g.add_tensor(
            format!("l{l}.scores"),
            Shape::hwc(cfg.tokens, 1, cfg.tokens),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.qk"),
            OpKind::MatMul { out_features: cfg.tokens },
            vec![q, _k],
            None,
            scores,
            Activation::None,
        );
        let smax = g.add_tensor(
            format!("l{l}.smax"),
            Shape::hwc(cfg.tokens, 1, cfg.tokens),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.softmax"),
            OpKind::Softmax,
            vec![scores],
            None,
            smax,
            Activation::None,
        );
        let ctx = g.add_tensor(
            format!("l{l}.ctx"),
            Shape::hwc(cfg.tokens, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.sv"),
            OpKind::MatMul { out_features: d },
            vec![smax, _v],
            None,
            ctx,
            Activation::None,
        );
        let o = gemm(&mut g, format!("l{l}.o"), ctx, d, d, cfg.tokens);
        // Residual add.
        let res1 = g.add_tensor(
            format!("l{l}.res1"),
            Shape::hwc(cfg.tokens, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(format!("l{l}.add1"), OpKind::Add, vec![cur, o], None, res1, Activation::None);
        // FFN.
        let up = gemm(&mut g, format!("l{l}.ffn_up"), res1, d, cfg.d_ff, cfg.tokens);
        let down = gemm(&mut g, format!("l{l}.ffn_down"), up, cfg.d_ff, d, cfg.tokens);
        let res2 = g.add_tensor(
            format!("l{l}.res2"),
            Shape::hwc(cfg.tokens, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(format!("l{l}.add2"), OpKind::Add, vec![res1, down], None, res2, Activation::None);
        cur = res2;
    }
    let logits = gemm(&mut g, "lm_head".into(), cur, cfg.d_model, cfg.vocab, cfg.tokens);
    g.mark_output(logits);
    g
}

/// Bytes of KV-cache state one decoded token appends across every layer
/// (K and V rows of `d_model` Int8 values per layer) — the per-token TCM
/// footprint the serving layer's KV residency accounting charges.
pub fn kv_bytes_per_token(cfg: &TransformerConfig) -> u64 {
    (2 * cfg.layers * cfg.d_model) as u64
}

/// Build the single-token decode-step graph at a given KV-cache length:
/// one new token's activations flow through the per-layer QKV / attention /
/// FFN GEMMs while the layer's K/V caches — `kv_len + 1` rows each,
/// including the step's own freshly appended row — enter as **input
/// tensors**. Streaming those caches is what makes the step's memory
/// traffic (and therefore its cost under the DAE timing model) grow
/// linearly with context length: exactly the causal-attention regime the
/// context cost curves in `compiler::cost` model.
pub fn decoder_decode_step(cfg: TransformerConfig, kv_len: usize) -> Graph {
    let ctx_rows = kv_len + 1;
    let mut g = Graph::new(format!(
        "decode{}x{}kv{}",
        cfg.layers, cfg.d_model, kv_len
    ));
    // One token: H=1, C=d_model per the paper's token-as-H rule.
    let mut cur = g.add_tensor(
        "token",
        Shape::hwc(1, 1, cfg.d_model),
        DType::Int8,
        TensorKind::Input,
    );
    let gemm = |g: &mut Graph, name: String, inp, in_f: usize, out_f: usize| {
        let w = g.add_tensor(
            format!("{name}.w"),
            Shape(vec![out_f, 1, 1, in_f]),
            DType::Int8,
            TensorKind::Parameter,
        );
        let out = g.add_tensor(
            format!("{name}.out"),
            Shape::hwc(1, 1, out_f),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            name,
            OpKind::MatMul { out_features: out_f },
            vec![inp],
            Some(w),
            out,
            Activation::None,
        );
        out
    };
    for l in 0..cfg.layers {
        let d = cfg.d_model;
        let q = gemm(&mut g, format!("l{l}.q"), cur, d, d);
        let _k = gemm(&mut g, format!("l{l}.k"), cur, d, d);
        let _v = gemm(&mut g, format!("l{l}.v"), cur, d, d);
        // The KV caches stream in as inputs sized by the context length.
        let kcache = g.add_tensor(
            format!("l{l}.kcache"),
            Shape::hwc(ctx_rows, 1, d),
            DType::Int8,
            TensorKind::Input,
        );
        let vcache = g.add_tensor(
            format!("l{l}.vcache"),
            Shape::hwc(ctx_rows, 1, d),
            DType::Int8,
            TensorKind::Input,
        );
        // Attention scores over the whole context: 1×ctx_rows GEMM.
        let scores = g.add_tensor(
            format!("l{l}.scores"),
            Shape::hwc(1, 1, ctx_rows),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.qk"),
            OpKind::MatMul { out_features: ctx_rows },
            vec![q, kcache],
            None,
            scores,
            Activation::None,
        );
        let smax = g.add_tensor(
            format!("l{l}.smax"),
            Shape::hwc(1, 1, ctx_rows),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.softmax"),
            OpKind::Softmax,
            vec![scores],
            None,
            smax,
            Activation::None,
        );
        let ctx = g.add_tensor(
            format!("l{l}.ctx"),
            Shape::hwc(1, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(
            format!("l{l}.sv"),
            OpKind::MatMul { out_features: d },
            vec![smax, vcache],
            None,
            ctx,
            Activation::None,
        );
        let o = gemm(&mut g, format!("l{l}.o"), ctx, d, d);
        let res1 = g.add_tensor(
            format!("l{l}.res1"),
            Shape::hwc(1, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(format!("l{l}.add1"), OpKind::Add, vec![cur, o], None, res1, Activation::None);
        let up = gemm(&mut g, format!("l{l}.ffn_up"), res1, d, cfg.d_ff);
        let down = gemm(&mut g, format!("l{l}.ffn_down"), up, cfg.d_ff, d);
        let res2 = g.add_tensor(
            format!("l{l}.res2"),
            Shape::hwc(1, 1, d),
            DType::Int8,
            TensorKind::Activation,
        );
        g.add_op(format!("l{l}.add2"), OpKind::Add, vec![res1, down], None, res2, Activation::None);
        cur = res2;
    }
    let logits = gemm(&mut g, "lm_head".into(), cur, cfg.d_model, cfg.vocab);
    g.mark_output(logits);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt_100m_has_about_100m_params() {
        let g = decoder_prefill(TransformerConfig::gpt_100m(128));
        let mparams = g.total_params() as f64 / 1e6;
        // 12×(4·768² + 2·768·3072) + 768·32000 ≈ 109 M
        assert!((mparams - 109.0).abs() / 109.0 < 0.10, "Mparams={mparams}");
    }

    #[test]
    fn macs_scale_with_tokens() {
        let a = decoder_prefill(TransformerConfig::tiny(16));
        let b = decoder_prefill(TransformerConfig::tiny(32));
        assert!(b.total_macs() > a.total_macs() * 3 / 2);
    }

    #[test]
    fn graph_is_valid_and_topo_sortable() {
        let g = decoder_prefill(TransformerConfig::tiny(8));
        g.validate().unwrap();
        assert_eq!(g.topo_order().len(), g.ops.len());
    }

    #[test]
    fn decode_step_is_valid_and_grows_with_kv_length() {
        let cfg = TransformerConfig::tiny(8);
        let short = decoder_decode_step(cfg, 8);
        let long = decoder_decode_step(cfg, 64);
        short.validate().unwrap();
        long.validate().unwrap();
        assert_eq!(short.topo_order().len(), short.ops.len());
        // Same op structure at every KV length; only operand sizes grow.
        assert_eq!(short.ops.len(), long.ops.len());
        // A longer context means more attention MACs and more streamed
        // bytes — the property the context cost curve models.
        assert!(long.total_macs() > short.total_macs());
        // Weights are context-independent: both steps carry identical
        // parameter footprints.
        assert_eq!(short.total_params(), long.total_params());
    }

    #[test]
    fn kv_bytes_per_token_counts_k_and_v_rows() {
        let cfg = TransformerConfig::tiny(8);
        assert_eq!(kv_bytes_per_token(&cfg), (2 * cfg.layers * cfg.d_model) as u64);
        assert!(kv_bytes_per_token(&TransformerConfig::gpt_100m(128)) > kv_bytes_per_token(&cfg));
    }
}
