//! MobileNet V1 / V2 / V3-Large-Minimalistic builders (Table IV rows 1–3).
//!
//! Architectures follow the public papers/repos; parameters and MAC counts
//! are verified against Table IV by the zoo tests. The V3 variant is the
//! *large minimalistic* one the paper uses ("highest accuracy under
//! quantization"): no squeeze-excite, no hard-swish, 3×3 kernels only.

use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding};

fn dw_sep(
    b: &mut GraphBuilder,
    name: &str,
    out_c: usize,
    stride: usize,
    act: Activation,
) {
    b.dwconv(
        &format!("{name}.dw"),
        ConvGeometry::square(3, stride, Padding::Same),
        act,
    );
    b.conv(&format!("{name}.pw"), out_c, ConvGeometry::unit(), act);
}

/// MobileNetV1 1.0 @ 224 — 13 depthwise-separable blocks.
pub fn mobilenet_v1() -> Graph {
    let mut b = GraphBuilder::with_input("MobileNetV1", 224, 224, 3);
    let a = Activation::Relu6;
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), a);
    // (out_c, stride) per block, standard V1 schedule.
    let blocks = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(c, s)) in blocks.iter().enumerate() {
        dw_sep(&mut b, &format!("b{i}"), c, s, a);
    }
    b.global_avg_pool("gap");
    b.fc("classifier", 1000, Activation::None);
    b.finish()
}

/// One inverted-residual (MBConv) block; returns output tensor.
fn inverted_residual(
    b: &mut GraphBuilder,
    name: &str,
    expand: usize,
    out_c: usize,
    stride: usize,
    kernel: usize,
    act: Activation,
) {
    let input = b.current();
    let in_c = b.current_shape().c();
    let exp_c = in_c * expand;
    if expand != 1 {
        b.conv(&format!("{name}.expand"), exp_c, ConvGeometry::unit(), act);
    }
    b.dwconv(
        &format!("{name}.dw"),
        ConvGeometry::square(kernel, stride, Padding::Same),
        act,
    );
    b.conv(&format!("{name}.project"), out_c, ConvGeometry::unit(), Activation::None);
    if stride == 1 && in_c == out_c {
        let proj = b.current();
        b.add(&format!("{name}.residual"), input, proj);
    }
}

/// MobileNetV2 1.0 @ 224.
pub fn mobilenet_v2() -> Graph {
    let mut b = GraphBuilder::with_input("MobileNetV2", 224, 224, 3);
    let a = Activation::Relu6;
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), a);
    // (expansion t, out channels c, repeats n, first stride s)
    let cfg = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut bi = 0;
    for &(t, c, n, s) in &cfg {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            inverted_residual(&mut b, &format!("ir{bi}"), t, c, stride, 3, a);
            bi += 1;
        }
    }
    b.conv("head", 1280, ConvGeometry::unit(), a);
    b.global_avg_pool("gap");
    b.fc("classifier", 1000, Activation::None);
    b.finish()
}

/// One V3 bneck block with explicit expansion width (not a multiple of
/// input channels, unlike V2).
fn bneck_v3(
    b: &mut GraphBuilder,
    name: &str,
    exp_c: usize,
    out_c: usize,
    stride: usize,
    act: Activation,
) {
    let input = b.current();
    let in_c = b.current_shape().c();
    if exp_c != in_c {
        b.conv(&format!("{name}.expand"), exp_c, ConvGeometry::unit(), act);
    }
    b.dwconv(&format!("{name}.dw"), ConvGeometry::square(3, stride, Padding::Same), act);
    b.conv(&format!("{name}.project"), out_c, ConvGeometry::unit(), Activation::None);
    if stride == 1 && in_c == out_c {
        let proj = b.current();
        b.add(&format!("{name}.residual"), input, proj);
    }
}

/// MobileNetV3-Large *minimalistic* @ 224: ReLU everywhere, all kernels 3×3,
/// no squeeze-excite (the quantization-friendly variant of the V3 paper).
pub fn mobilenet_v3_large_min() -> Graph {
    let mut b = GraphBuilder::with_input("MobileNetV3-LargeMin", 224, 224, 3);
    let a = Activation::Relu;
    b.conv("stem", 16, ConvGeometry::square(3, 2, Padding::Same), a);
    // (expansion width, out channels, stride) — V3-Large schedule with the
    // minimalistic substitutions (k=3 everywhere, no SE).
    let cfg: [(usize, usize, usize); 15] = [
        (16, 16, 1),
        (64, 24, 2),
        (72, 24, 1),
        (72, 40, 2),
        (120, 40, 1),
        (120, 40, 1),
        (240, 80, 2),
        (200, 80, 1),
        (184, 80, 1),
        (184, 80, 1),
        (480, 112, 1),
        (672, 112, 1),
        (672, 160, 2),
        (960, 160, 1),
        (960, 160, 1),
    ];
    for (i, &(e, c, s)) in cfg.iter().enumerate() {
        bneck_v3(&mut b, &format!("bneck{i}"), e, c, s, a);
    }
    b.conv("head", 960, ConvGeometry::unit(), a);
    b.global_avg_pool("gap");
    b.conv("head2", 1280, ConvGeometry::unit(), a);
    b.fc("classifier", 1000, Activation::None);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_matches_table_iv() {
        let g = mobilenet_v1();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 0.57).abs() / 0.57 < 0.10, "V1 GMACs={gmacs}");
        assert!((mparams - 4.2).abs() / 4.2 < 0.10, "V1 Mparams={mparams}");
    }

    #[test]
    fn v2_matches_table_iv() {
        let g = mobilenet_v2();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 0.30).abs() / 0.30 < 0.10, "V2 GMACs={gmacs}");
        assert!((mparams - 3.4).abs() / 3.4 < 0.10, "V2 Mparams={mparams}");
    }

    #[test]
    fn v3_min_matches_table_iv() {
        let g = mobilenet_v3_large_min();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 0.21).abs() / 0.21 < 0.15, "V3 GMACs={gmacs}");
        assert!((mparams - 3.9).abs() / 3.9 < 0.15, "V3 Mparams={mparams}");
    }

    #[test]
    fn v2_has_residual_adds() {
        let g = mobilenet_v2();
        let adds = g.ops.iter().filter(|o| matches!(o.kind, crate::ir::OpKind::Add)).count();
        assert_eq!(adds, 10); // V2 has 10 residual connections
    }
}
