//! ResNet50 V1 builder (Table IV).

use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding, PoolKind};

/// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ projection
/// shortcut on the first block of each stage).
fn bottleneck(b: &mut GraphBuilder, name: &str, mid_c: usize, out_c: usize, stride: usize, project: bool) {
    let input = b.current();
    b.conv(&format!("{name}.reduce"), mid_c, ConvGeometry::unit(), Activation::Relu);
    b.conv(
        &format!("{name}.conv3"),
        mid_c,
        ConvGeometry::square(3, stride, Padding::Same),
        Activation::Relu,
    );
    let main = b.conv(&format!("{name}.expand"), out_c, ConvGeometry::unit(), Activation::None);
    let shortcut = if project {
        b.conv_from(
            input,
            &format!("{name}.shortcut"),
            out_c,
            ConvGeometry { stride_h: stride, stride_w: stride, ..ConvGeometry::unit() },
            Activation::None,
        )
    } else {
        input
    };
    b.add(&format!("{name}.add"), main, shortcut);
}

/// ResNet50 V1 @ 224 (stride-2 in the 3×3, post-add ReLU folded into the
/// add's consumer cost — the activation engine applies it for free).
pub fn resnet50_v1() -> Graph {
    let mut b = GraphBuilder::with_input("ResNet50V1", 224, 224, 3);
    b.conv("stem", 64, ConvGeometry::square(7, 2, Padding::Same), Activation::Relu);
    b.pool("maxpool", PoolKind::Max, 3, 2);
    let stages: [(usize, usize, usize, usize); 4] = [
        // (blocks, mid channels, out channels, first stride)
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ];
    for (si, &(n, mid, out, s)) in stages.iter().enumerate() {
        for bi in 0..n {
            let stride = if bi == 0 { s } else { 1 };
            bottleneck(&mut b, &format!("s{si}b{bi}"), mid, out, stride, bi == 0);
        }
    }
    b.global_avg_pool("gap");
    b.fc("classifier", 1000, Activation::None);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_matches_published_counts() {
        let g = resnet50_v1();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        // The TorchVision ResNet-50 the paper cites counts 4.09 G
        // multiply-adds (fvcore). Table IV lists "2.0", i.e. the fvcore
        // number halved — we assert against the architecture's true MAC
        // count and report both in the Table IV bench (see EXPERIMENTS.md).
        assert!((gmacs - 4.09).abs() / 4.09 < 0.10, "ResNet50 GMACs={gmacs}");
        assert!((mparams - 25.6).abs() / 25.6 < 0.10, "ResNet50 Mparams={mparams}");
    }

    #[test]
    fn has_16_bottlenecks() {
        let g = resnet50_v1();
        let adds = g.ops.iter().filter(|o| matches!(o.kind, crate::ir::OpKind::Add)).count();
        assert_eq!(adds, 16);
    }
}
