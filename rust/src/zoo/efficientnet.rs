//! EfficientNet-Lite0 and EfficientDet-Lite0 builders (Table IV).
//!
//! Lite variants (the quantization-friendly family the paper benchmarks):
//! no squeeze-excite, ReLU6 instead of Swish in the -Lite classifier, fixed
//! stem/head widths. EfficientDet-Lite0 = Lite0 backbone @320 + 3×BiFPN
//! (64 ch) + 3-layer box/class heads over 5 pyramid levels.

use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding, TensorId};

/// MBConv block with explicit kernel size; no SE in the Lite family.
fn mbconv(
    b: &mut GraphBuilder,
    name: &str,
    expand: usize,
    out_c: usize,
    kernel: usize,
    stride: usize,
    act: Activation,
) {
    let input = b.current();
    let in_c = b.current_shape().c();
    if expand != 1 {
        b.conv(&format!("{name}.expand"), in_c * expand, ConvGeometry::unit(), act);
    }
    b.dwconv(&format!("{name}.dw"), ConvGeometry::square(kernel, stride, Padding::Same), act);
    b.conv(&format!("{name}.project"), out_c, ConvGeometry::unit(), Activation::None);
    if stride == 1 && in_c == out_c {
        let proj = b.current();
        b.add(&format!("{name}.residual"), input, proj);
    }
}

/// Backbone stage table for Lite0 (== B0 widths/depths, SE removed).
/// (expand, out_c, repeats, first stride, kernel)
const LITE0_STAGES: [(usize, usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
];

fn lite0_backbone(b: &mut GraphBuilder, act: Activation, taps: &mut Vec<TensorId>) {
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), act);
    for (si, &(t, c, n, s, k)) in LITE0_STAGES.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            mbconv(b, &format!("s{si}r{r}"), t, c, k, stride, act);
        }
        // Feature taps at stride 8/16/32 ends (stages 2, 4, 6).
        if matches!(si, 2 | 4 | 6) {
            taps.push(b.current());
        }
    }
}

/// EfficientNet-Lite0 @ 224 classifier.
pub fn efficientnet_lite0() -> Graph {
    let mut b = GraphBuilder::with_input("EfficientNetLite0", 224, 224, 3);
    let act = Activation::Relu6;
    let mut taps = Vec::new();
    lite0_backbone(&mut b, act, &mut taps);
    b.conv("head", 1280, ConvGeometry::unit(), act);
    b.global_avg_pool("gap");
    b.fc("classifier", 1000, Activation::None);
    b.finish()
}

/// One BiFPN-ish fusion node: resize partner to this level, add, then a
/// depthwise-separable conv (the Lite BiFPN uses dw-separable convs).
fn bifpn_fuse(b: &mut GraphBuilder, name: &str, a: TensorId, partner: TensorId, ch: usize) -> TensorId {
    let (ha, wa) = {
        let s = &b.graph.tensor(a).shape;
        (s.h(), s.w())
    };
    let hp = b.graph.tensor(partner).shape.h();
    b.set_current(partner);
    if hp != ha {
        // BiFPN levels have odd sizes (40,20,10,5,3 @320) — resize to the
        // exact partner size rather than by an integer factor.
        b.resize_to(&format!("{name}.rs"), ha, wa);
    }
    let resized = b.current();
    let sum = b.add(&format!("{name}.fuse"), a, resized);
    b.set_current(sum);
    b.dwconv(&format!("{name}.dw"), ConvGeometry::square(3, 1, Padding::Same), Activation::Relu6);
    b.conv(&format!("{name}.pw"), ch, ConvGeometry::unit(), Activation::None)
}

/// EfficientDet-Lite0 @ 320: Lite0 backbone + P3..P7 pyramid, 3 BiFPN
/// repeats at 64 channels, 3-layer dw-separable box + class heads.
pub fn efficientdet_lite0() -> Graph {
    let mut b = GraphBuilder::with_input("EfficientDetLite0", 320, 320, 3);
    let act = Activation::Relu6;
    let mut taps = Vec::new();
    lite0_backbone(&mut b, act, &mut taps);
    let ch = 64usize;
    // Lateral 1×1s to BiFPN width.
    let mut levels: Vec<TensorId> = Vec::new();
    for (i, &t) in taps.iter().enumerate() {
        b.set_current(t);
        levels.push(b.conv(&format!("lat{i}"), ch, ConvGeometry::unit(), Activation::None));
    }
    // P6, P7 from the deepest tap.
    b.set_current(*levels.last().unwrap());
    let p6 = b.conv("p6", ch, ConvGeometry::square(3, 2, Padding::Same), Activation::None);
    b.set_current(p6);
    let p7 = b.conv("p7", ch, ConvGeometry::square(3, 2, Padding::Same), Activation::None);
    levels.push(p6);
    levels.push(p7);

    // 3 BiFPN repeats: top-down then bottom-up fusion per repeat.
    for rep in 0..3 {
        // top-down
        for i in (0..levels.len() - 1).rev() {
            levels[i] = bifpn_fuse(&mut b, &format!("bifpn{rep}.td{i}"), levels[i], levels[i + 1], ch);
        }
        // bottom-up
        for i in 1..levels.len() {
            levels[i] = bifpn_fuse(&mut b, &format!("bifpn{rep}.bu{i}"), levels[i], levels[i - 1], ch);
        }
    }

    // Shared heads: 3 dw-separable layers + prediction convs per level.
    let num_anchors = 9;
    let num_classes = 90;
    let mut outs = Vec::new();
    for (li, &lvl) in levels.iter().enumerate() {
        b.set_current(lvl);
        for d in 0..3 {
            b.dwconv(&format!("boxhead{li}.{d}.dw"), ConvGeometry::square(3, 1, Padding::Same), act);
            b.conv(&format!("boxhead{li}.{d}.pw"), ch, ConvGeometry::unit(), act);
        }
        let box_out = b.conv(&format!("boxpred{li}"), num_anchors * 4, ConvGeometry::unit(), Activation::None);
        b.set_current(lvl);
        for d in 0..3 {
            b.dwconv(&format!("clshead{li}.{d}.dw"), ConvGeometry::square(3, 1, Padding::Same), act);
            b.conv(&format!("clshead{li}.{d}.pw"), ch, ConvGeometry::unit(), act);
        }
        let cls_out = b.conv(&format!("clspred{li}"), num_anchors * num_classes, ConvGeometry::unit(), Activation::None);
        outs.push(box_out);
        outs.push(cls_out);
    }
    b.finish_multi(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lite0_matches_table_iv() {
        let g = efficientnet_lite0();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 0.41).abs() / 0.41 < 0.15, "Lite0 GMACs={gmacs}");
        assert!((mparams - 4.7).abs() / 4.7 < 0.15, "Lite0 Mparams={mparams}");
    }

    #[test]
    fn efficientdet_matches_table_iv() {
        let g = efficientdet_lite0();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 1.27).abs() / 1.27 < 0.25, "EffDet GMACs={gmacs}");
        assert!((mparams - 3.9).abs() / 3.9 < 0.25, "EffDet Mparams={mparams}");
    }

    #[test]
    fn efficientdet_has_five_levels_of_outputs() {
        let g = efficientdet_lite0();
        assert_eq!(g.outputs.len(), 10); // box + class per 5 levels
    }
}
