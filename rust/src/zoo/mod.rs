//! Model zoo: programmatic builders for every model in the paper's Table IV
//! plus the Gen-AI transformer workload of Sec. VI.
//!
//! These builders replace the LiteRT flatbuffer binaries the paper feeds its
//! compiler: the mid-end only consumes shapes, op kinds and quantization
//! metadata, all of which are public for these architectures. The zoo tests
//! assert MACs/params against Table IV.

pub mod efficientnet;
pub mod mobilenet;
pub mod resnet;
pub mod ssd;
pub mod transformer;
pub mod yolo;

use crate::ir::Graph;

pub use transformer::{decoder_prefill, TransformerConfig};

/// Model identifiers matching Table III/IV rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    MobileNetV1,
    MobileNetV2,
    MobileNetV3Min,
    ResNet50V1,
    EfficientNetLite0,
    EfficientDetLite0,
    YoloV8nDet,
    YoloV8s,
    YoloV8nSeg,
    MobileNetV1Ssd,
    MobileNetV2Ssd,
    DamoYoloNl,
}

impl ModelId {
    /// All Table-IV models in the paper's row order.
    pub fn all() -> [ModelId; 12] {
        use ModelId::*;
        [
            MobileNetV1,
            MobileNetV2,
            MobileNetV3Min,
            ResNet50V1,
            EfficientNetLite0,
            EfficientDetLite0,
            YoloV8nDet,
            YoloV8s,
            YoloV8nSeg,
            MobileNetV1Ssd,
            MobileNetV2Ssd,
            DamoYoloNl,
        ]
    }

    /// The Table-III benchmark subset (YOLOv8S appears in Table IV but not
    /// in Table III; the second detection row pairs YOLOv8N-det + YOLOv8S).
    pub fn table3() -> [ModelId; 12] {
        Self::all()
    }

    /// Human-readable name matching the paper's tables.
    pub fn display_name(self) -> &'static str {
        use ModelId::*;
        match self {
            MobileNetV1 => "MobileNet V1",
            MobileNetV2 => "MobileNet V2",
            MobileNetV3Min => "MobileNet V3",
            ResNet50V1 => "ResNet 50V1",
            EfficientNetLite0 => "EfficientNet Lite0",
            EfficientDetLite0 => "EfficientDet Lite0",
            YoloV8nDet => "YOLOv8 N-det.",
            YoloV8s => "YOLOv8 S",
            YoloV8nSeg => "YOLOv8 N-seg.",
            MobileNetV1Ssd => "MobileNet V1 SSD",
            MobileNetV2Ssd => "MobileNet V2 SSD",
            DamoYoloNl => "DAMO YOLO-NL",
        }
    }

    /// Stable machine-readable name (kebab-case): the CLI and trace-format
    /// spelling. [`ModelId::parse`] accepts every slug, so
    /// `parse(slug()) == Some(self)` round-trips (tested below).
    pub fn slug(self) -> &'static str {
        use ModelId::*;
        match self {
            MobileNetV1 => "mobilenet-v1",
            MobileNetV2 => "mobilenet-v2",
            MobileNetV3Min => "mobilenet-v3",
            ResNet50V1 => "resnet50",
            EfficientNetLite0 => "efficientnet-lite0",
            EfficientDetLite0 => "efficientdet-lite0",
            YoloV8nDet => "yolov8n",
            YoloV8s => "yolov8s",
            YoloV8nSeg => "yolov8n-seg",
            MobileNetV1Ssd => "mobilenet-v1-ssd",
            MobileNetV2Ssd => "mobilenet-v2-ssd",
            DamoYoloNl => "damo-yolo",
        }
    }

    /// Parse from a CLI string (kebab-case).
    pub fn parse(s: &str) -> Option<ModelId> {
        use ModelId::*;
        Some(match s.to_ascii_lowercase().as_str() {
            "mobilenet-v1" | "mobilenetv1" => MobileNetV1,
            "mobilenet-v2" | "mobilenetv2" => MobileNetV2,
            "mobilenet-v3" | "mobilenetv3" | "mobilenet-v3-min" => MobileNetV3Min,
            "resnet50" | "resnet50v1" => ResNet50V1,
            "efficientnet-lite0" => EfficientNetLite0,
            "efficientdet-lite0" => EfficientDetLite0,
            "yolov8n" | "yolov8n-det" => YoloV8nDet,
            "yolov8s" => YoloV8s,
            "yolov8n-seg" => YoloV8nSeg,
            "mobilenet-v1-ssd" => MobileNetV1Ssd,
            "mobilenet-v2-ssd" | "mobilenet-v2-ssdlite" => MobileNetV2Ssd,
            "damo-yolo" | "damo-yolo-nl" => DamoYoloNl,
            _ => return None,
        })
    }

    /// Build the IR graph.
    pub fn build(self) -> Graph {
        use ModelId::*;
        match self {
            MobileNetV1 => mobilenet::mobilenet_v1(),
            MobileNetV2 => mobilenet::mobilenet_v2(),
            MobileNetV3Min => mobilenet::mobilenet_v3_large_min(),
            ResNet50V1 => resnet::resnet50_v1(),
            EfficientNetLite0 => efficientnet::efficientnet_lite0(),
            EfficientDetLite0 => efficientnet::efficientdet_lite0(),
            YoloV8nDet => yolo::yolov8n_det(),
            YoloV8s => yolo::yolov8s_det(),
            YoloV8nSeg => yolo::yolov8n_seg(),
            MobileNetV1Ssd => ssd::mobilenet_v1_ssd(),
            MobileNetV2Ssd => ssd::mobilenet_v2_ssdlite(),
            DamoYoloNl => yolo::damo_yolo_nl(),
        }
    }

    /// (GMACs, M params) reference values from Table IV.
    pub fn table_iv_reference(self) -> (f64, f64) {
        use ModelId::*;
        match self {
            MobileNetV1 => (0.57, 4.2),
            MobileNetV2 => (0.30, 3.4),
            MobileNetV3Min => (0.21, 3.9),
            ResNet50V1 => (2.0, 25.6),
            EfficientNetLite0 => (0.41, 4.7),
            EfficientDetLite0 => (1.27, 3.9),
            YoloV8nDet => (4.35, 3.2),
            YoloV8s => (14.3, 11.2),
            YoloV8nSeg => (6.3, 3.4),
            MobileNetV1Ssd => (1.3, 5.1),
            MobileNetV2Ssd => (0.8, 4.3),
            DamoYoloNl => (3.0, 5.7),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        for id in ModelId::all() {
            let g = id.build();
            g.validate().unwrap_or_else(|e| panic!("{:?}: {e}", id));
            assert!(g.total_macs() > 0, "{id:?} has no MACs");
            assert_eq!(g.topo_order().len(), g.ops.len(), "{id:?} topo");
        }
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(ModelId::parse("yolov8n-det"), Some(ModelId::YoloV8nDet));
        assert_eq!(ModelId::parse("nope"), None);
    }

    #[test]
    fn slug_round_trips_through_parse() {
        for id in ModelId::all() {
            assert_eq!(ModelId::parse(id.slug()), Some(id), "{id:?}");
        }
    }
}
