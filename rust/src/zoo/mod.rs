//! Model zoo: programmatic builders for every model in the paper's Table IV
//! plus the Gen-AI transformer workload of Sec. VI.
//!
//! These builders replace the LiteRT flatbuffer binaries the paper feeds its
//! compiler: the mid-end only consumes shapes, op kinds and quantization
//! metadata, all of which are public for these architectures. The zoo tests
//! assert MACs/params against Table IV.

pub mod efficientnet;
pub mod mobilenet;
pub mod resnet;
pub mod ssd;
pub mod transformer;
pub mod yolo;

use crate::ir::Graph;

pub use transformer::{
    decoder_decode_step, decoder_prefill, kv_bytes_per_token, TransformerConfig,
};

/// Model identifiers matching Table III/IV rows, plus the Sec. VI Gen-AI
/// decoder ([`ModelId::GptTiny`]) the autoregressive serving layer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    MobileNetV1,
    MobileNetV2,
    MobileNetV3Min,
    ResNet50V1,
    EfficientNetLite0,
    EfficientDetLite0,
    YoloV8nDet,
    YoloV8s,
    YoloV8nSeg,
    MobileNetV1Ssd,
    MobileNetV2Ssd,
    DamoYoloNl,
    /// Tiny decoder-only transformer (2 × 64, canonical 32-token prompt):
    /// the decode-capable model the GenAI serving path schedules
    /// token-by-token. Appended after the Table-IV rows so existing
    /// owner indices ([`crate::serve::Scheduler`]'s residency accounting)
    /// are unchanged.
    GptTiny,
}

impl ModelId {
    /// Every servable model: the Table-IV rows in the paper's order plus
    /// the Gen-AI decoder appended at the end.
    pub fn all() -> [ModelId; 13] {
        use ModelId::*;
        [
            MobileNetV1,
            MobileNetV2,
            MobileNetV3Min,
            ResNet50V1,
            EfficientNetLite0,
            EfficientDetLite0,
            YoloV8nDet,
            YoloV8s,
            YoloV8nSeg,
            MobileNetV1Ssd,
            MobileNetV2Ssd,
            DamoYoloNl,
            GptTiny,
        ]
    }

    /// The Table-IV models in the paper's row order (the rows
    /// [`ModelId::table_iv_reference`] describes).
    pub fn table_iv() -> [ModelId; 12] {
        use ModelId::*;
        [
            MobileNetV1,
            MobileNetV2,
            MobileNetV3Min,
            ResNet50V1,
            EfficientNetLite0,
            EfficientDetLite0,
            YoloV8nDet,
            YoloV8s,
            YoloV8nSeg,
            MobileNetV1Ssd,
            MobileNetV2Ssd,
            DamoYoloNl,
        ]
    }

    /// The Table-III benchmark subset (YOLOv8S appears in Table IV but not
    /// in Table III; the second detection row pairs YOLOv8N-det + YOLOv8S).
    pub fn table3() -> [ModelId; 12] {
        Self::table_iv()
    }

    /// Human-readable name matching the paper's tables.
    pub fn display_name(self) -> &'static str {
        use ModelId::*;
        match self {
            MobileNetV1 => "MobileNet V1",
            MobileNetV2 => "MobileNet V2",
            MobileNetV3Min => "MobileNet V3",
            ResNet50V1 => "ResNet 50V1",
            EfficientNetLite0 => "EfficientNet Lite0",
            EfficientDetLite0 => "EfficientDet Lite0",
            YoloV8nDet => "YOLOv8 N-det.",
            YoloV8s => "YOLOv8 S",
            YoloV8nSeg => "YOLOv8 N-seg.",
            MobileNetV1Ssd => "MobileNet V1 SSD",
            MobileNetV2Ssd => "MobileNet V2 SSD",
            DamoYoloNl => "DAMO YOLO-NL",
            GptTiny => "GPT Tiny",
        }
    }

    /// Stable machine-readable name (kebab-case): the CLI and trace-format
    /// spelling. [`ModelId::parse`] accepts every slug, so
    /// `parse(slug()) == Some(self)` round-trips (tested below).
    pub fn slug(self) -> &'static str {
        use ModelId::*;
        match self {
            MobileNetV1 => "mobilenet-v1",
            MobileNetV2 => "mobilenet-v2",
            MobileNetV3Min => "mobilenet-v3",
            ResNet50V1 => "resnet50",
            EfficientNetLite0 => "efficientnet-lite0",
            EfficientDetLite0 => "efficientdet-lite0",
            YoloV8nDet => "yolov8n",
            YoloV8s => "yolov8s",
            YoloV8nSeg => "yolov8n-seg",
            MobileNetV1Ssd => "mobilenet-v1-ssd",
            MobileNetV2Ssd => "mobilenet-v2-ssd",
            DamoYoloNl => "damo-yolo",
            GptTiny => "gpt-tiny",
        }
    }

    /// Parse from a CLI string (kebab-case).
    pub fn parse(s: &str) -> Option<ModelId> {
        use ModelId::*;
        Some(match s.to_ascii_lowercase().as_str() {
            "mobilenet-v1" | "mobilenetv1" => MobileNetV1,
            "mobilenet-v2" | "mobilenetv2" => MobileNetV2,
            "mobilenet-v3" | "mobilenetv3" | "mobilenet-v3-min" => MobileNetV3Min,
            "resnet50" | "resnet50v1" => ResNet50V1,
            "efficientnet-lite0" => EfficientNetLite0,
            "efficientdet-lite0" => EfficientDetLite0,
            "yolov8n" | "yolov8n-det" => YoloV8nDet,
            "yolov8s" => YoloV8s,
            "yolov8n-seg" => YoloV8nSeg,
            "mobilenet-v1-ssd" => MobileNetV1Ssd,
            "mobilenet-v2-ssd" | "mobilenet-v2-ssdlite" => MobileNetV2Ssd,
            "damo-yolo" | "damo-yolo-nl" => DamoYoloNl,
            "gpt-tiny" | "gpttiny" => GptTiny,
            _ => return None,
        })
    }

    /// Build the IR graph.
    pub fn build(self) -> Graph {
        use ModelId::*;
        match self {
            MobileNetV1 => mobilenet::mobilenet_v1(),
            MobileNetV2 => mobilenet::mobilenet_v2(),
            MobileNetV3Min => mobilenet::mobilenet_v3_large_min(),
            ResNet50V1 => resnet::resnet50_v1(),
            EfficientNetLite0 => efficientnet::efficientnet_lite0(),
            EfficientDetLite0 => efficientnet::efficientdet_lite0(),
            YoloV8nDet => yolo::yolov8n_det(),
            YoloV8s => yolo::yolov8s_det(),
            YoloV8nSeg => yolo::yolov8n_seg(),
            MobileNetV1Ssd => ssd::mobilenet_v1_ssd(),
            MobileNetV2Ssd => ssd::mobilenet_v2_ssdlite(),
            DamoYoloNl => yolo::damo_yolo_nl(),
            GptTiny => decoder_prefill(Self::GPT_TINY_CONFIG),
        }
    }

    /// The [`ModelId::GptTiny`] transformer shape: 2 × 64 decoder with a
    /// canonical 32-token prompt (prefill compiles at this length; decode
    /// steps grow the KV cache from each request's own prompt length).
    pub const GPT_TINY_CONFIG: TransformerConfig = TransformerConfig {
        layers: 2,
        d_model: 64,
        d_ff: 256,
        heads: 4,
        tokens: 32,
        vocab: 512,
    };

    /// The transformer shape of a decode-capable model; `None` for the
    /// single-shot CNN zoo. A `Some` here is what lets the serving layer
    /// build per-token decode-step programs for the model.
    pub fn decode_config(self) -> Option<TransformerConfig> {
        match self {
            ModelId::GptTiny => Some(Self::GPT_TINY_CONFIG),
            _ => None,
        }
    }

    /// (GMACs, M params) reference values from Table IV. Only meaningful
    /// for [`ModelId::table_iv`] rows; the Gen-AI decoder reports its own
    /// builder-derived footprint.
    pub fn table_iv_reference(self) -> (f64, f64) {
        use ModelId::*;
        match self {
            MobileNetV1 => (0.57, 4.2),
            MobileNetV2 => (0.30, 3.4),
            MobileNetV3Min => (0.21, 3.9),
            ResNet50V1 => (2.0, 25.6),
            EfficientNetLite0 => (0.41, 4.7),
            EfficientDetLite0 => (1.27, 3.9),
            YoloV8nDet => (4.35, 3.2),
            YoloV8s => (14.3, 11.2),
            YoloV8nSeg => (6.3, 3.4),
            MobileNetV1Ssd => (1.3, 5.1),
            MobileNetV2Ssd => (0.8, 4.3),
            DamoYoloNl => (3.0, 5.7),
            // Not a Table-IV row: builder-derived footprint of the tiny
            // decoder (prefill at the canonical 32-token prompt).
            GptTiny => (0.005, 0.14),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        for id in ModelId::all() {
            let g = id.build();
            g.validate().unwrap_or_else(|e| panic!("{:?}: {e}", id));
            assert!(g.total_macs() > 0, "{id:?} has no MACs");
            assert_eq!(g.topo_order().len(), g.ops.len(), "{id:?} topo");
        }
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(ModelId::parse("yolov8n-det"), Some(ModelId::YoloV8nDet));
        assert_eq!(ModelId::parse("nope"), None);
    }

    #[test]
    fn slug_round_trips_through_parse() {
        for id in ModelId::all() {
            assert_eq!(ModelId::parse(id.slug()), Some(id), "{id:?}");
        }
    }

    #[test]
    fn gpt_tiny_is_decode_capable_and_appended_last() {
        // Appending (not inserting) keeps every Table-IV owner index
        // stable — the serving residency accounting depends on it.
        assert_eq!(*ModelId::all().last().unwrap(), ModelId::GptTiny);
        assert_eq!(ModelId::table_iv().len(), 12);
        assert!(!ModelId::table_iv().contains(&ModelId::GptTiny));
        let cfg = ModelId::GptTiny.decode_config().expect("decode-capable");
        assert_eq!(cfg.tokens, 32);
        for id in ModelId::table_iv() {
            assert!(id.decode_config().is_none(), "{id:?} is single-shot");
        }
        // The decode-step graph at the canonical prompt length validates.
        decoder_decode_step(cfg, cfg.tokens).validate().unwrap();
    }
}
