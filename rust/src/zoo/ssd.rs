//! MobileNetV1-SSD and MobileNetV2-SSDLite builders (Table IV).
//!
//! Standard TF Object Detection API configurations at 300×300: V1-SSD uses
//! full conv prediction heads over 6 feature levels; V2-SSDLite uses
//! depthwise-separable heads (the "Lite" part) — which is why it has fewer
//! MACs despite the deeper backbone.

use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding, TensorId};

const NUM_CLASSES: usize = 91; // COCO + background, TF-ODAPI convention

fn dw_sep(b: &mut GraphBuilder, name: &str, out_c: usize, stride: usize, act: Activation) -> TensorId {
    b.dwconv(&format!("{name}.dw"), ConvGeometry::square(3, stride, Padding::Same), act);
    b.conv(&format!("{name}.pw"), out_c, ConvGeometry::unit(), act)
}

/// MobileNetV1 backbone @300 returning the two SSD taps (conv11, conv13).
fn mnv1_backbone_300(b: &mut GraphBuilder) -> (TensorId, TensorId) {
    let a = Activation::Relu6;
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), a);
    let blocks = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut tap11 = None;
    let mut tap13 = None;
    for (i, &(c, s)) in blocks.iter().enumerate() {
        let t = dw_sep(b, &format!("b{i}"), c, s, a);
        if i == 10 {
            tap11 = Some(t);
        }
        if i == 12 {
            tap13 = Some(t);
        }
    }
    (tap11.unwrap(), tap13.unwrap())
}

/// SSD extra feature layers: 1×1 reduce + 3×3 stride-2, four times. The
/// `lite` flavour (SSDLite) replaces the 3×3 with a depthwise-separable
/// pair, matching the TF-ODAPI ssdlite config.
fn ssd_extras(
    b: &mut GraphBuilder,
    from: TensorId,
    chans: &[(usize, usize)],
    lite: bool,
) -> Vec<TensorId> {
    let a = Activation::Relu6;
    let mut taps = Vec::new();
    b.set_current(from);
    for (i, &(mid, out)) in chans.iter().enumerate() {
        b.conv(&format!("extra{i}.reduce"), mid, ConvGeometry::unit(), a);
        let t = if lite {
            b.dwconv(&format!("extra{i}.dw"), ConvGeometry::square(3, 2, Padding::Same), a);
            b.conv(&format!("extra{i}.pw"), out, ConvGeometry::unit(), a)
        } else {
            b.conv(&format!("extra{i}.conv"), out, ConvGeometry::square(3, 2, Padding::Same), a)
        };
        taps.push(t);
    }
    taps
}

/// SSD prediction heads (V1 flavour): 1×1 convolutional predictors, the
/// configuration of the quantized TFLite detection models the paper runs.
fn ssd_heads(b: &mut GraphBuilder, levels: &[TensorId], anchors: &[usize], outs: &mut Vec<TensorId>) {
    for (i, (&lvl, &na)) in levels.iter().zip(anchors).enumerate() {
        b.set_current(lvl);
        let box_out = b.conv(&format!("box{i}"), na * 4, ConvGeometry::unit(), Activation::None);
        b.set_current(lvl);
        let cls_out = b.conv(
            &format!("cls{i}"),
            na * NUM_CLASSES,
            ConvGeometry::unit(),
            Activation::None,
        );
        outs.push(box_out);
        outs.push(cls_out);
    }
}

/// Depthwise-separable SSDLite heads (V2 flavour).
fn ssdlite_heads(b: &mut GraphBuilder, levels: &[TensorId], anchors: &[usize], outs: &mut Vec<TensorId>) {
    for (i, (&lvl, &na)) in levels.iter().zip(anchors).enumerate() {
        b.set_current(lvl);
        b.dwconv(&format!("box{i}.dw"), ConvGeometry::square(3, 1, Padding::Same), Activation::Relu6);
        let box_out = b.conv(&format!("box{i}.pw"), na * 4, ConvGeometry::unit(), Activation::None);
        b.set_current(lvl);
        b.dwconv(&format!("cls{i}.dw"), ConvGeometry::square(3, 1, Padding::Same), Activation::Relu6);
        let cls_out = b.conv(&format!("cls{i}.pw"), na * NUM_CLASSES, ConvGeometry::unit(), Activation::None);
        outs.push(box_out);
        outs.push(cls_out);
    }
}

/// MobileNetV1-SSD @ 300.
pub fn mobilenet_v1_ssd() -> Graph {
    let mut b = GraphBuilder::with_input("MobileNetV1-SSD", 300, 300, 3);
    let (c11, c13) = mnv1_backbone_300(&mut b);
    let extras = ssd_extras(
        &mut b,
        c13,
        &[(256, 512), (128, 256), (128, 256), (64, 128)],
        false,
    );
    let mut levels = vec![c11, c13];
    levels.extend(extras);
    let anchors = [3, 6, 6, 6, 6, 6];
    let mut outs = Vec::new();
    ssd_heads(&mut b, &levels, &anchors, &mut outs);
    b.finish_multi(outs)
}

/// Inverted-residual helper (duplicated from mobilenet.rs at the widths
/// SSDLite taps need — the tap is the *expansion* output of block 13).
fn ir_block(b: &mut GraphBuilder, name: &str, t: usize, out_c: usize, stride: usize) -> (TensorId, TensorId) {
    let a = Activation::Relu6;
    let input = b.current();
    let in_c = b.current_shape().c();
    let mut expand_out = input;
    if t != 1 {
        expand_out = b.conv(&format!("{name}.expand"), in_c * t, ConvGeometry::unit(), a);
    }
    b.dwconv(&format!("{name}.dw"), ConvGeometry::square(3, stride, Padding::Same), a);
    let proj = b.conv(&format!("{name}.project"), out_c, ConvGeometry::unit(), Activation::None);
    let out = if stride == 1 && in_c == out_c {
        b.add(&format!("{name}.residual"), input, proj)
    } else {
        proj
    };
    b.set_current(out);
    (expand_out, out)
}

/// MobileNetV2-SSDLite @ 300.
pub fn mobilenet_v2_ssdlite() -> Graph {
    let mut b = GraphBuilder::with_input("MobileNetV2-SSD", 300, 300, 3);
    let a = Activation::Relu6;
    b.conv("stem", 32, ConvGeometry::square(3, 2, Padding::Same), a);
    let cfg = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut tap_expand13 = None;
    let mut bi = 0;
    for &(t, c, n, s) in &cfg {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let (expand, _) = ir_block(&mut b, &format!("ir{bi}"), t, c, stride);
            // SSDLite taps the expansion of the first stride-2 block of the
            // 160-channel stage (block index 13 in the standard numbering).
            if bi == 13 {
                tap_expand13 = Some(expand);
            }
            bi += 1;
        }
    }
    let head = b.conv("head", 1280, ConvGeometry::unit(), a);
    let extras =
        ssd_extras(&mut b, head, &[(256, 512), (128, 256), (128, 256), (64, 128)], true);
    let mut levels = vec![tap_expand13.unwrap(), head];
    levels.extend(extras);
    let anchors = [3, 6, 6, 6, 6, 6];
    let mut outs = Vec::new();
    ssdlite_heads(&mut b, &levels, &anchors, &mut outs);
    b.finish_multi(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_ssd_matches_published_counts() {
        let g = mobilenet_v1_ssd();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 1.3).abs() / 1.3 < 0.20, "V1-SSD GMACs={gmacs}");
        // The public TF-ODAPI ssd_mobilenet_v1 checkpoint has 6.8 M params;
        // the paper's Table IV lists 5.1 M (likely a trimmed predictor
        // variant). We assert the architecture we actually built and report
        // both values in the Table IV bench.
        assert!((mparams - 6.8).abs() / 6.8 < 0.15, "V1-SSD Mparams={mparams}");
    }

    #[test]
    fn v2_ssdlite_matches_table_iv() {
        let g = mobilenet_v2_ssdlite();
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!((gmacs - 0.8).abs() / 0.8 < 0.25, "V2-SSD GMACs={gmacs}");
        assert!((mparams - 4.3).abs() / 4.3 < 0.25, "V2-SSD Mparams={mparams}");
    }

    #[test]
    fn both_emit_six_levels() {
        assert_eq!(mobilenet_v1_ssd().outputs.len(), 12);
        assert_eq!(mobilenet_v2_ssdlite().outputs.len(), 12);
    }
}
