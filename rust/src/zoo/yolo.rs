//! YOLOv8 (N-det / S-det / N-seg) and DAMO-YOLO-NL builders (Table IV).
//!
//! YOLOv8 structure per the Ultralytics repo: CSP-style backbone with C2f
//! blocks, SPPF, PAN neck, anchor-free decoupled head with DFL (reg_max=16).
//! Scaling: n → depth 1/3, width 1/4 of the base-64 channel schedule;
//! s → depth 1/3, width 1/2. The seg variant adds a prototype-mask branch
//! and per-level mask-coefficient heads.
//!
//! DAMO-YOLO-NL is approximated at graph level (TinyNAS-style CSP backbone,
//! GFPN-like neck, ZeroHead) with widths chosen to land on the published
//! 3.0 GMACs / 5.7 M params budget — the compiler consumes only shapes.

use crate::ir::{Activation, ConvGeometry, Graph, GraphBuilder, Padding, PoolKind, TensorId};

const ACT: Activation = Activation::Swish;

/// Conv-BN-SiLU (BN folds into the conv at INT8 deploy time).
fn cbs(b: &mut GraphBuilder, name: &str, c: usize, k: usize, s: usize) -> TensorId {
    b.conv(name, c, ConvGeometry::square(k, s, Padding::Same), b.act_override())
}

/// C2f block: split, n bottleneck(3×3,3×3) with residual, concat, fuse 1×1.
fn c2f(b: &mut GraphBuilder, name: &str, out_c: usize, n: usize, shortcut: bool) -> TensorId {
    let hidden = out_c / 2;
    // Entry 1×1 producing 2*hidden, conceptually split into two halves.
    cbs(b, &format!("{name}.cv1"), 2 * hidden, 1, 1);
    // Model the split as a reshape-free slice: two half-channel tensors.
    // For cost purposes we materialize the halves via 1×1 "slice" convs is
    // wrong (adds MACs); instead track the full tensor and let bottlenecks
    // run at `hidden` width from the second half.
    let split_src = b.current();
    let mut parts: Vec<TensorId> = vec![split_src];
    // Each bottleneck consumes the previous part at `hidden` channels. We
    // approximate the half-width view with a Reshape (zero-MAC) op.
    let half = {
        let h = b.graph.tensor(split_src).shape.h();
        let w = b.graph.tensor(split_src).shape.w();
        let t = b.graph.add_tensor(
            format!("{name}.half"),
            crate::ir::Shape::hwc(h, w, hidden),
            crate::ir::DType::Int8,
            crate::ir::TensorKind::Activation,
        );
        b.graph.add_op(
            format!("{name}.split"),
            crate::ir::OpKind::Reshape,
            vec![split_src],
            None,
            t,
            Activation::None,
        );
        t
    };
    let mut cur = half;
    for i in 0..n {
        b.set_current(cur);
        cbs(b, &format!("{name}.m{i}.cv1"), hidden, 3, 1);
        let y = cbs(b, &format!("{name}.m{i}.cv2"), hidden, 3, 1);
        cur = if shortcut { b.add(&format!("{name}.m{i}.add"), half, y) } else { y };
        parts.push(cur);
    }
    let cat = b.concat(&format!("{name}.cat"), parts);
    b.set_current(cat);
    cbs(b, &format!("{name}.cv2"), out_c, 1, 1)
}

/// SPPF: 1×1 reduce, 3 chained 5×5 maxpools, concat, 1×1 fuse.
fn sppf(b: &mut GraphBuilder, name: &str, c: usize) -> TensorId {
    let hidden = c / 2;
    cbs(b, &format!("{name}.cv1"), hidden, 1, 1);
    let x0 = b.current();
    let x1 = b.pool(&format!("{name}.p1"), PoolKind::Max, 5, 1);
    b.set_current(x1);
    let x2 = b.pool(&format!("{name}.p2"), PoolKind::Max, 5, 1);
    b.set_current(x2);
    let x3 = b.pool(&format!("{name}.p3"), PoolKind::Max, 5, 1);
    let cat = b.concat(&format!("{name}.cat"), vec![x0, x1, x2, x3]);
    b.set_current(cat);
    cbs(b, &format!("{name}.cv2"), c, 1, 1)
}

/// YOLOv8 channel schedule for a width multiple. Base (=1.0): 64,128,256,
/// 512,1024(capped per variant); depth base 3.
struct V8Scale {
    w: f64,
    d: f64,
    max_c: usize,
}

impl V8Scale {
    fn n() -> Self {
        Self { w: 0.25, d: 1.0 / 3.0, max_c: 1024 }
    }
    fn s() -> Self {
        Self { w: 0.50, d: 1.0 / 3.0, max_c: 1024 }
    }
    fn c(&self, base: usize) -> usize {
        ((base.min(self.max_c)) as f64 * self.w).round() as usize
    }
    fn d(&self, base: usize) -> usize {
        ((base as f64) * self.d).ceil() as usize
    }
}

/// Backbone; returns (p3, p4, p5) taps.
fn v8_backbone(b: &mut GraphBuilder, s: &V8Scale) -> (TensorId, TensorId, TensorId) {
    cbs(b, "stem", s.c(64), 3, 2); // P1
    cbs(b, "down2", s.c(128), 3, 2); // P2
    c2f(b, "c2f_2", s.c(128), s.d(3), true);
    cbs(b, "down3", s.c(256), 3, 2); // P3
    let p3 = c2f(b, "c2f_3", s.c(256), s.d(6), true);
    cbs(b, "down4", s.c(512), 3, 2); // P4
    let p4 = c2f(b, "c2f_4", s.c(512), s.d(6), true);
    cbs(b, "down5", s.c(1024), 3, 2); // P5
    c2f(b, "c2f_5", s.c(1024), s.d(3), true);
    let p5 = sppf(b, "sppf", s.c(1024));
    (p3, p4, p5)
}

/// PAN neck; returns per-level feature maps (n3, n4, n5).
fn v8_neck(
    b: &mut GraphBuilder,
    s: &V8Scale,
    p3: TensorId,
    p4: TensorId,
    p5: TensorId,
) -> (TensorId, TensorId, TensorId) {
    // top-down
    b.set_current(p5);
    b.resize("up5", 2);
    let cat4 = b.concat("cat_td4", vec![b.current(), p4]);
    b.set_current(cat4);
    let td4 = c2f(b, "c2f_td4", s.c(512), s.d(3), false);
    b.set_current(td4);
    b.resize("up4", 2);
    let cat3 = b.concat("cat_td3", vec![b.current(), p3]);
    b.set_current(cat3);
    let n3 = c2f(b, "c2f_td3", s.c(256), s.d(3), false);
    // bottom-up
    b.set_current(n3);
    cbs(b, "down_bu3", s.c(256), 3, 2);
    let cat_bu4 = b.concat("cat_bu4", vec![b.current(), td4]);
    b.set_current(cat_bu4);
    let n4 = c2f(b, "c2f_bu4", s.c(512), s.d(3), false);
    b.set_current(n4);
    cbs(b, "down_bu4", s.c(512), 3, 2);
    let cat_bu5 = b.concat("cat_bu5", vec![b.current(), p5]);
    b.set_current(cat_bu5);
    let n5 = c2f(b, "c2f_bu5", s.c(1024), s.d(3), false);
    (n3, n4, n5)
}

/// Decoupled detect head (anchor-free, DFL reg_max=16) over 3 levels.
fn v8_detect_head(
    b: &mut GraphBuilder,
    s: &V8Scale,
    levels: [(TensorId, &str); 3],
    num_classes: usize,
    outs: &mut Vec<TensorId>,
) {
    let reg_ch = (16 * 4usize).max(s.c(256) / 4); // c2 in ultralytics
    let cls_ch = s.c(256).max(num_classes);
    for (t, name) in levels {
        b.set_current(t);
        cbs(b, &format!("{name}.reg0"), reg_ch, 3, 1);
        cbs(b, &format!("{name}.reg1"), reg_ch, 3, 1);
        let reg = b.conv(&format!("{name}.regp"), 64, ConvGeometry::unit(), Activation::None);
        b.set_current(t);
        cbs(b, &format!("{name}.cls0"), cls_ch, 3, 1);
        cbs(b, &format!("{name}.cls1"), cls_ch, 3, 1);
        let cls = b.conv(&format!("{name}.clsp"), num_classes, ConvGeometry::unit(), Activation::None);
        outs.push(reg);
        outs.push(cls);
    }
}

fn yolov8(name: &str, scale: V8Scale, seg: bool) -> Graph {
    let mut b = GraphBuilder::with_input(name, 640, 640, 3);
    b.set_default_activation(ACT);
    let (p3, p4, p5) = v8_backbone(&mut b, &scale);
    let (n3, n4, n5) = v8_neck(&mut b, &scale, p3, p4, p5);
    let mut outs = Vec::new();
    v8_detect_head(&mut b, &scale, [(n3, "det3"), (n4, "det4"), (n5, "det5")], 80, &mut outs);
    if seg {
        // Prototype branch from n3: upsample ×2 with convs to 32 protos.
        let proto_c = scale.c(256);
        b.set_current(n3);
        cbs(&mut b, "proto.cv1", proto_c, 3, 1);
        b.resize("proto.up", 2);
        cbs(&mut b, "proto.cv2", proto_c, 3, 1);
        let protos = b.conv("proto.out", 32, ConvGeometry::unit(), ACT);
        outs.push(protos);
        // Mask-coefficient heads per level (32 coeffs).
        for (t, nm) in [(n3, "seg3"), (n4, "seg4"), (n5, "seg5")] {
            b.set_current(t);
            let mc = scale.c(256).max(32);
            cbs(&mut b, &format!("{nm}.cv0"), mc, 3, 1);
            cbs(&mut b, &format!("{nm}.cv1"), mc, 3, 1);
            let m = b.conv(&format!("{nm}.mc"), 32, ConvGeometry::unit(), Activation::None);
            outs.push(m);
        }
    }
    b.finish_multi(outs)
}

/// YOLOv8N detection @ 640.
pub fn yolov8n_det() -> Graph {
    yolov8("YOLOv8N-det", V8Scale::n(), false)
}

/// YOLOv8S detection @ 640.
pub fn yolov8s_det() -> Graph {
    yolov8("YOLOv8S", V8Scale::s(), false)
}

/// YOLOv8N segmentation @ 640.
pub fn yolov8n_seg() -> Graph {
    yolov8("YOLOv8N-seg", V8Scale::n(), true)
}

/// DAMO-YOLO-NL @ 416 — graph-level approximation of the Nano-Large
/// variant (published: 6.09 GFLOPs ≈ 3.05 GMACs, 5.69 M params at 416²).
/// The edge deployment of DAMO-YOLO ships ReLU activations (the repo's
/// "industry" models), unlike YOLOv8's SiLU — relevant to the eNPU's host
/// fallback behaviour in Table III.
pub fn damo_yolo_nl() -> Graph {
    let mut b = GraphBuilder::with_input("DAMO-YOLO-NL", 416, 416, 3);
    b.set_default_activation(Activation::Relu);
    // TinyNAS-ish CSP backbone.
    cbs(&mut b, "stem", 24, 3, 2);
    cbs(&mut b, "down2", 48, 3, 2);
    c2f(&mut b, "csp2", 48, 1, true);
    cbs(&mut b, "down3", 96, 3, 2);
    let p3 = c2f(&mut b, "csp3", 96, 2, true);
    cbs(&mut b, "down4", 192, 3, 2);
    let p4 = c2f(&mut b, "csp4", 192, 2, true);
    cbs(&mut b, "down5", 384, 3, 2);
    c2f(&mut b, "csp5", 384, 1, true);
    let p5 = sppf(&mut b, "sppf", 384);
    // GFPN-like neck (c(256)=96, c(512)=192, c(1024)=384).
    let (n3, n4, n5) =
        v8_neck(&mut b, &V8Scale { w: 0.375, d: 1.0 / 3.0, max_c: 1024 }, p3, p4, p5);
    let mut outs = Vec::new();
    // ZeroHead: one conv per level per branch + 1×1 predictors.
    for (t, nm) in [(n3, "h3"), (n4, "h4"), (n5, "h5")] {
        b.set_current(t);
        cbs(&mut b, &format!("{nm}.c"), 96, 3, 1);
        let reg = b.conv(&format!("{nm}.reg"), 68, ConvGeometry::unit(), Activation::None);
        b.set_current(t);
        cbs(&mut b, &format!("{nm}.c2"), 96, 3, 1);
        let cls = b.conv(&format!("{nm}.cls"), 80, ConvGeometry::unit(), Activation::None);
        outs.push(reg);
        outs.push(cls);
    }
    b.finish_multi(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(g: &Graph, gmacs_ref: f64, mparams_ref: f64, tol: f64) {
        g.validate().unwrap();
        let gmacs = g.total_macs() as f64 / 1e9;
        let mparams = g.total_params() as f64 / 1e6;
        assert!(
            (gmacs - gmacs_ref).abs() / gmacs_ref < tol,
            "{}: GMACs={gmacs} ref={gmacs_ref}",
            g.name
        );
        assert!(
            (mparams - mparams_ref).abs() / mparams_ref < tol,
            "{}: Mparams={mparams} ref={mparams_ref}",
            g.name
        );
    }

    #[test]
    fn yolov8n_det_matches_table_iv() {
        check(&yolov8n_det(), 4.35, 3.2, 0.25);
    }

    #[test]
    fn yolov8s_matches_table_iv() {
        check(&yolov8s_det(), 14.3, 11.2, 0.25);
    }

    #[test]
    fn yolov8n_seg_matches_table_iv() {
        check(&yolov8n_seg(), 6.3, 3.4, 0.30);
    }

    #[test]
    fn damo_yolo_matches_table_iv() {
        check(&damo_yolo_nl(), 3.0, 5.7, 0.35);
    }

    #[test]
    fn det_head_emits_six_outputs() {
        let g = yolov8n_det();
        assert_eq!(g.outputs.len(), 6);
    }

    #[test]
    fn seg_adds_proto_and_mask_outputs() {
        let g = yolov8n_seg();
        assert_eq!(g.outputs.len(), 6 + 1 + 3);
    }
}
