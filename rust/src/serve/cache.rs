//! Compile cache: memoizes the CP mid-end per
//! `(model, config fingerprint, calibration fingerprint)`.
//!
//! Compilation dominates request cost by orders of magnitude (Table II:
//! seconds of CP solving vs milliseconds of inference), so a multi-tenant
//! server must never re-run the solver for a model it has already planned.
//! Entries are `Arc`-shared: every virtual NPU instance replays the same
//! immutable [`JobProgram`] without copying it. Because a
//! [`CostCalibration`] changes every cost the mid-end prices, calibrated
//! and uncalibrated artifacts for the same model coexist as distinct
//! entries — the calibration is part of the key, never an invalidation.

use std::collections::HashMap;
use std::sync::Arc;

use crate::arch::NeutronConfig;
use crate::compiler::{
    calibrated_layer_latency_cycles, compile_with_stats, CompileOptions, Compiled, CostCalibration,
};
use crate::coordinator::{emit, DecodeBucket, DecodeJob, JobProgram};
use crate::cp::SearchConfig;
use crate::ir::OpClass;
use crate::zoo::{decoder_decode_step, ModelId};

/// Smallest decode KV-length bucket. The ladder doubles from here, so a
/// `max_context` of `C` compiles `⌈log2(C/4)⌉ + 1` decode-step programs.
pub const DECODE_BUCKET_MIN_KV: u32 = 4;

/// FNV-1a over a sequence of 64-bit words — the one hash both
/// fingerprints below share.
fn fnv1a_words(words: impl IntoIterator<Item = u64>) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for w in words {
        for b in w.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    h
}

/// FNV-1a over every architecture parameter. Two configs with equal
/// fingerprints compile identically, so the fingerprint is the cache-key
/// component that isolates tenants on different NPU configurations.
pub fn config_fingerprint(cfg: &NeutronConfig) -> u64 {
    fnv1a_words([
        cfg.n as u64,
        cfg.m as u64,
        cfg.a as u64,
        cfg.wc_bytes as u64,
        cfg.cores as u64,
        cfg.freq_ghz.to_bits(),
        cfg.tcm_bytes as u64,
        cfg.tcm_banks as u64,
        cfg.ddr_gbps.to_bits(),
        cfg.bus_bytes as u64,
        cfg.buses_per_core as u64,
        cfg.job_overhead_cycles,
    ])
}

/// FNV-1a over the *effective* per-class scales of a calibration: for
/// every [`OpClass`] in `OpClass::all()` order, the scale
/// [`CostCalibration::scale_for`] resolves (1.0 when unfitted). Two
/// calibrations that price every class identically — whatever the
/// insertion order or redundant entries behind them — fingerprint
/// identically, and the identity calibration always hashes to the same
/// stable value, so pre-refactor cache keys are simply "identity" keys.
pub fn calibration_fingerprint(calibration: &CostCalibration) -> u64 {
    fnv1a_words(OpClass::all().map(|class| calibration.scale_for(class).to_bits()))
}

/// L1 distance between the effective per-class scales of two calibrations
/// — the "nearest neighbor" metric for warm-start seeding: the closer two
/// calibrations price every op class, the more of the neighbor's CP
/// solution survives as the new search's incumbent.
pub fn calibration_l1_distance(a: &CostCalibration, b: &CostCalibration) -> f64 {
    OpClass::all()
        .iter()
        .map(|&c| (a.scale_for(c) - b.scale_for(c)).abs())
        .sum()
}

/// Compile options for serving: identical inputs must yield bit-identical
/// job programs across runs, so every CP budget is a **node limit**
/// (deterministic) rather than a wall-clock limit. The branch-and-bound
/// search itself is deterministic (smallest-domain/lowest-index selection),
/// so with node budgets the whole mid-end is a pure function of
/// `(graph, config, calibration)`.
pub fn deterministic_compile_options() -> CompileOptions {
    let solver = |nodes: u64| SearchConfig {
        node_limit: Some(nodes),
        time_limit_ms: None,
        ..SearchConfig::default()
    };
    let mut opts = CompileOptions::default_partitioned();
    opts.tiling.solver = solver(200_000);
    opts.scheduling.solver = solver(60_000);
    opts.allocation_solver = solver(60_000);
    opts
}

/// One cached compile: the mid-end artifact plus the emitted job program.
#[derive(Debug, Clone)]
pub struct CachedModel {
    /// The model this entry was compiled from.
    pub model: ModelId,
    /// The CP mid-end artifact (tiling, schedule, allocation).
    pub compiled: Compiled,
    /// The emitted job program the virtual NPU instances replay.
    pub program: JobProgram,
}

/// Memoizes `compile` + `emit` per
/// `(ModelId, config fingerprint, calibration fingerprint)` so repeat
/// requests skip the CP solver.
#[derive(Debug)]
pub struct CompileCache {
    cfg: NeutronConfig,
    opts: CompileOptions,
    entries: HashMap<(ModelId, u64, u64), Arc<CachedModel>>,
    /// Decode artifacts, keyed
    /// `(model, max_context, config fp, calibration fp)` — one
    /// [`DecodeJob`] covers every KV length up to its `max_context`
    /// through its bucket ladder, so the KV length is *not* part of the
    /// key.
    decode_entries: HashMap<(ModelId, u32, u64, u64), Arc<DecodeJob>>,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that ran a cold compile.
    pub misses: u64,
    /// Warm-start seeds the CP solver rejected as invalid across every
    /// compile this cache ran (see [`crate::cp::SolveStats::hints_rejected`]).
    /// A systematically stale seed source shows up here instead of as a
    /// silent cold-search regression.
    pub hints_rejected: u64,
}

impl CompileCache {
    /// Build an empty cache that compiles under `opts` for `cfg` by
    /// default (see [`CompileCache::get`]). `opts.calibration` is the
    /// cache's default calibration.
    pub fn new(cfg: NeutronConfig, opts: CompileOptions) -> Self {
        Self {
            cfg,
            opts,
            entries: HashMap::new(),
            decode_entries: HashMap::new(),
            hits: 0,
            misses: 0,
            hints_rejected: 0,
        }
    }

    /// Serving default: deterministic solver budgets, identity
    /// calibration.
    pub fn for_serving(cfg: NeutronConfig) -> Self {
        Self::new(cfg, deterministic_compile_options())
    }

    /// Serving default with a fitted calibration: deterministic solver
    /// budgets, every compile priced under `calibration`. The calibrated
    /// mid-end is still a pure function of
    /// `(graph, config, calibration)`, so the determinism contract holds
    /// unchanged.
    pub fn for_serving_with(cfg: NeutronConfig, calibration: CostCalibration) -> Self {
        let opts = CompileOptions { calibration, ..deterministic_compile_options() };
        Self::new(cfg, opts)
    }

    /// Resolve a model's compiled program under the cache's default
    /// config and calibration, compiling on the first request (miss) and
    /// returning the shared entry afterwards (hit).
    pub fn get(&mut self, model: ModelId) -> Arc<CachedModel> {
        let cfg = self.cfg.clone();
        self.get_for(model, &cfg)
    }

    /// Resolve under an explicit config (mixed per-tenant configurations):
    /// entries for different fingerprints coexist in one cache.
    pub fn get_for(&mut self, model: ModelId, cfg: &NeutronConfig) -> Arc<CachedModel> {
        let calibration = self.opts.calibration.clone();
        self.get_with_calibration(model, cfg, &calibration)
    }

    /// Resolve under an explicit config *and* calibration: artifacts for
    /// the same model compiled with and without a fitted calibration
    /// coexist as separate entries, keyed by the calibration's effective
    /// per-class scales.
    pub fn get_with_calibration(
        &mut self,
        model: ModelId,
        cfg: &NeutronConfig,
        calibration: &CostCalibration,
    ) -> Arc<CachedModel> {
        let key = (model, config_fingerprint(cfg), calibration_fingerprint(calibration));
        if let Some(entry) = self.entries.get(&key) {
            self.hits += 1;
            return Arc::clone(entry);
        }
        self.misses += 1;
        let graph = model.build();
        let warm_start = self
            .nearest_neighbor(model, key.1, calibration)
            .map(|n| Arc::new(n.compiled.clone()));
        let opts = CompileOptions {
            calibration: calibration.clone(),
            warm_start,
            ..self.opts.clone()
        };
        let (compiled, stats) = compile_with_stats(&graph, cfg, &opts);
        self.hints_rejected += stats.hints_rejected;
        let program = emit(&compiled, &graph.name);
        let entry = Arc::new(CachedModel { model, compiled, program });
        self.entries.insert(key, Arc::clone(&entry));
        entry
    }

    /// Resolve a model's autoregressive decode artifact: its prefill
    /// program plus one compiled decode-step program per KV-length bucket
    /// (powers of two from [`DECODE_BUCKET_MIN_KV`] up to the first
    /// bucket ≥ `max_context`). Bucketing keeps the compile count
    /// `O(log max_context)` while the per-bucket programs still price the
    /// causal-attention and KV-streaming cost of their context length —
    /// the KV caches are Input tensors of the decode-step graph, so their
    /// DDR traffic is in the emitted program, not bolted on afterwards.
    ///
    /// Panics for models without a decode configuration (CNN classifiers)
    /// and for `max_context == 0`; the CLI validates both before calling.
    pub fn get_decode(&mut self, model: ModelId, max_context: u32) -> Arc<DecodeJob> {
        assert!(max_context >= 1, "max_context must be at least 1");
        let key = (
            model,
            max_context,
            config_fingerprint(&self.cfg),
            calibration_fingerprint(&self.opts.calibration),
        );
        if let Some(entry) = self.decode_entries.get(&key) {
            self.hits += 1;
            return Arc::clone(entry);
        }
        self.misses += 1;
        let dcfg = model.decode_config().unwrap_or_else(|| {
            panic!(
                "model {} has no decode configuration (it is not an autoregressive model)",
                model.slug()
            )
        });
        // The prefill is the model's ordinary artifact (the zoo builds
        // decode-capable models as their prefill graph), resolved through
        // the regular entry map so prefill and single-shot serving share
        // one compile.
        let prefill = self.get(model).program.clone();
        let mut buckets = Vec::new();
        let mut kv_len = DECODE_BUCKET_MIN_KV;
        loop {
            buckets.push(self.build_decode_bucket(&dcfg, kv_len));
            if kv_len >= max_context {
                break;
            }
            kv_len = kv_len.saturating_mul(2);
        }
        let job = Arc::new(DecodeJob::new(model.slug().to_string(), prefill, buckets));
        self.decode_entries.insert(key, Arc::clone(&job));
        job
    }

    /// Compile one decode-step bucket: the step graph at `kv_len` cached
    /// rows through the same deterministic mid-end as every other model,
    /// plus the derived KV-tile set (the tiles of the `*.kcache` /
    /// `*.vcache` Input tensors — the ones whose streaming a resident KV
    /// cache elides) and the analytic calibrated cost prediction the
    /// context-curve fit joins against.
    fn build_decode_bucket(
        &mut self,
        dcfg: &crate::zoo::TransformerConfig,
        kv_len: u32,
    ) -> DecodeBucket {
        let graph = decoder_decode_step(*dcfg, kv_len as usize);
        let opts = CompileOptions {
            calibration: self.opts.calibration.clone(),
            warm_start: None,
            ..self.opts.clone()
        };
        let (compiled, stats) = compile_with_stats(&graph, &self.cfg, &opts);
        self.hints_rejected += stats.hints_rejected;
        let program = emit(&compiled, &graph.name);
        let kv_tiles = compiled
            .program
            .tiles
            .iter()
            .filter(|t| {
                let name = &graph.tensors[t.tensor.0 as usize].name;
                name.ends_with(".kcache") || name.ends_with(".vcache")
            })
            .map(|t| t.id)
            .collect();
        let predicted_cycles = graph
            .ops
            .iter()
            .map(|op| {
                calibrated_layer_latency_cycles(
                    &graph,
                    op,
                    &self.cfg,
                    compiled.formats.format_of(op.id),
                    &compiled.calibration,
                )
            })
            .sum();
        DecodeBucket { kv_len, program, kv_tiles, predicted_cycles }
    }

    /// Nearest cached warm-start neighbor for a miss: same model and
    /// config fingerprint, smallest L1 distance between the effective
    /// per-class calibration scales. The calibration changes *costs* but
    /// not the candidate structure of the CPs (tiling and capacity depend
    /// only on bytes/banks, transfer pricing is never class-corrected), so
    /// the neighbor's solution maps onto the new problem 1:1 and seeds the
    /// anytime search. Ties break toward the smallest calibration
    /// fingerprint for determinism.
    fn nearest_neighbor(
        &self,
        model: ModelId,
        config_fp: u64,
        calibration: &CostCalibration,
    ) -> Option<&Arc<CachedModel>> {
        self.entries
            .iter()
            .filter(|(&(m, cfp, _), _)| m == model && cfp == config_fp)
            .min_by(|(&(_, _, fa), a), (&(_, _, fb), b)| {
                let da = calibration_l1_distance(&a.compiled.calibration, calibration);
                let db = calibration_l1_distance(&b.compiled.calibration, calibration);
                da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal).then(fa.cmp(&fb))
            })
            .map(|(_, e)| e)
    }

    /// Insert an externally produced artifact (e.g. loaded from a
    /// persistent [`crate::runtime::ArtifactStore`]) without counting a
    /// hit or a miss. The job program is re-emitted from the artifact —
    /// emission is a cheap pure function of the compile result, so a
    /// disk-warmed entry is bit-identical to the one a cold compile would
    /// have produced. Returns the shared entry.
    pub fn insert_artifact(
        &mut self,
        model: ModelId,
        cfg: &NeutronConfig,
        compiled: Compiled,
    ) -> Arc<CachedModel> {
        let key = (
            model,
            config_fingerprint(cfg),
            calibration_fingerprint(&compiled.calibration),
        );
        let graph_name = model.build().name;
        let program = emit(&compiled, &graph_name);
        let entry = Arc::new(CachedModel { model, compiled, program });
        self.entries.insert(key, Arc::clone(&entry));
        entry
    }

    /// The calibration this cache compiles under by default — the one
    /// [`CompileCache::get`] and [`CompileCache::get_for`] resolve with.
    pub fn default_calibration(&self) -> &CostCalibration {
        &self.opts.calibration
    }

    /// Look up under the cache's default config and calibration without
    /// compiling (and without counting a hit/miss).
    pub fn peek(&self, model: ModelId) -> Option<&Arc<CachedModel>> {
        self.entries.get(&(
            model,
            config_fingerprint(&self.cfg),
            calibration_fingerprint(&self.opts.calibration),
        ))
    }

    /// Number of cached `(model, config, calibration)` entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the cache cold (no entries yet)?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of `get` calls served from the cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_distinguishes_configs() {
        let a = NeutronConfig::flagship_2tops();
        let b = NeutronConfig::mcu_half_tops();
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        assert_eq!(config_fingerprint(&a), config_fingerprint(&a.clone()));
        let c = NeutronConfig { cores: 2, ..a.clone() };
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
    }

    #[test]
    fn miss_then_hits_share_one_compile() {
        let mut cache = CompileCache::for_serving(NeutronConfig::flagship_2tops());
        assert_eq!(cache.hit_rate(), 0.0);
        assert!(cache.is_empty());
        let a = cache.get(ModelId::MobileNetV3Min);
        let b = cache.get(ModelId::MobileNetV3Min);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached entry");
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
        assert!(cache.peek(ModelId::MobileNetV3Min).is_some());
        assert!(cache.peek(ModelId::MobileNetV1).is_none());
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(a.model, ModelId::MobileNetV3Min);
        assert!(!a.program.jobs.is_empty());
    }

    #[test]
    fn calibration_fingerprint_is_canonical() {
        use crate::ir::OpClass;
        let id = CostCalibration::identity();
        // Redundant explicit 1.0 entries price identically → same key.
        let explicit_identity = CostCalibration::from_scales(&[(OpClass::Conv, 1.0)]);
        assert_eq!(calibration_fingerprint(&id), calibration_fingerprint(&explicit_identity));
        // Insertion order does not matter; the effective scales do.
        let a = CostCalibration::from_scales(&[(OpClass::Conv, 1.5), (OpClass::Pool, 0.5)]);
        let b = CostCalibration::from_scales(&[(OpClass::Pool, 0.5), (OpClass::Conv, 1.5)]);
        assert_eq!(calibration_fingerprint(&a), calibration_fingerprint(&b));
        assert_ne!(calibration_fingerprint(&a), calibration_fingerprint(&id));
    }

    #[test]
    fn per_calibration_entries_coexist_and_hit() {
        use crate::ir::OpClass;
        let cfg = NeutronConfig::flagship_2tops();
        let cal = CostCalibration::from_scales(&[(OpClass::Conv, 1.5)]);
        let mut cache = CompileCache::for_serving(cfg.clone());
        let plain = cache.get(ModelId::MobileNetV3Min);
        let tuned = cache.get_with_calibration(ModelId::MobileNetV3Min, &cfg, &cal);
        assert!(!Arc::ptr_eq(&plain, &tuned), "distinct calibrations must compile separately");
        assert_eq!(cache.len(), 2);
        assert_eq!((cache.hits, cache.misses), (0, 2));
        assert_eq!(tuned.compiled.calibration, cal);
        assert!(plain.compiled.calibration.is_identity());
        // Identical calibration → hit; a cache built *around* the same
        // calibration resolves the same key through plain get().
        let again = cache.get_with_calibration(ModelId::MobileNetV3Min, &cfg, &cal);
        assert!(Arc::ptr_eq(&tuned, &again));
        assert_eq!(cache.hits, 1);
        let mut calibrated_cache = CompileCache::for_serving_with(cfg.clone(), cal.clone());
        let via_default = calibrated_cache.get(ModelId::MobileNetV3Min);
        assert_eq!(via_default.compiled.calibration, cal);
        assert!(calibrated_cache.peek(ModelId::MobileNetV3Min).is_some());
    }

    #[test]
    fn decode_job_bucket_ladder_covers_max_context_and_hits() {
        let mut cache = CompileCache::for_serving(NeutronConfig::flagship_2tops());
        let job = cache.get_decode(ModelId::GptTiny, 24);
        // 4, 8, 16, 32: doubles until the last bucket covers max_context.
        let kv: Vec<u32> = job.buckets.iter().map(|b| b.kv_len).collect();
        assert_eq!(kv, vec![4, 8, 16, 32]);
        assert!(job.max_kv() >= 24);
        for b in &job.buckets {
            assert!(!b.program.jobs.is_empty());
            assert!(!b.kv_tiles.is_empty(), "kv={} bucket must stream KV tiles", b.kv_len);
            assert!(b.predicted_cycles > 0);
        }
        // Larger contexts cost more: the ladder's analytic predictions
        // are strictly increasing in KV length.
        for w in job.buckets.windows(2) {
            assert!(w[0].predicted_cycles < w[1].predicted_cycles);
        }
        assert!(!job.prefill.jobs.is_empty());
        // Second resolve is a pure hit sharing the same Arc; the prefill
        // compile counted as one extra miss on the ordinary entry map.
        let again = cache.get_decode(ModelId::GptTiny, 24);
        assert!(Arc::ptr_eq(&job, &again));
        assert_eq!((cache.hits, cache.misses), (1, 2));
        // A different max_context is a distinct artifact, but its prefill
        // is now a hit.
        let wider = cache.get_decode(ModelId::GptTiny, 64);
        assert!(!Arc::ptr_eq(&job, &wider));
        assert_eq!(wider.buckets.last().unwrap().kv_len, 64);
    }

    #[test]
    fn per_config_entries_coexist() {
        let flagship = NeutronConfig::flagship_2tops();
        let mcu = NeutronConfig::mcu_half_tops();
        let mut cache = CompileCache::for_serving(flagship.clone());
        let a = cache.get(ModelId::MobileNetV3Min);
        let b = cache.get_for(ModelId::MobileNetV3Min, &mcu);
        assert!(!Arc::ptr_eq(&a, &b), "different configs must compile separately");
        assert_eq!(cache.len(), 2);
        assert_eq!((cache.hits, cache.misses), (0, 2));
        // The default-config entry is still a hit afterwards.
        let c = cache.get_for(ModelId::MobileNetV3Min, &flagship);
        assert!(Arc::ptr_eq(&a, &c));
        assert_eq!(cache.hits, 1);
    }
}
