//! Admission queue + request scheduler over N virtual NPU instances.
//!
//! Event-driven simulation on the shared virtual clock (see the module doc
//! in `serve/mod.rs` for the determinism contract): requests are admitted
//! FIFO and dispatched onto the instance that goes idle earliest; a
//! request's latency is its queueing delay plus the simulated latency of
//! its job program.

use std::collections::VecDeque;

use crate::arch::NeutronConfig;
use crate::coordinator::{Executor, JobProgram, Metrics};
use crate::util::prop::Rng;
use crate::zoo::ModelId;

/// One admitted inference request on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub model: ModelId,
    /// Arrival time in NPU core cycles on the shared virtual clock.
    pub arrival_cycles: u64,
}

/// Completion record: latency = queueing delay + simulated service time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub model: ModelId,
    /// Instance that served the request.
    pub instance: usize,
    pub arrival_cycles: u64,
    pub start_cycles: u64,
    pub finish_cycles: u64,
}

impl Completion {
    /// End-to-end latency on the virtual clock.
    pub fn latency_cycles(&self) -> u64 {
        self.finish_cycles - self.arrival_cycles
    }

    /// Time spent waiting in the admission queue.
    pub fn queue_cycles(&self) -> u64 {
        self.start_cycles - self.arrival_cycles
    }

    /// Simulated on-device service time.
    pub fn service_cycles(&self) -> u64 {
        self.finish_cycles - self.start_cycles
    }
}

/// Deterministic synthetic request trace: the model of each request is
/// drawn uniformly from `models`, inter-arrival gaps uniformly from
/// `[0, 2·mean_gap_cycles]` (mean `mean_gap_cycles`). Same inputs →
/// identical trace; arrivals are non-decreasing and ids are `0..requests`.
pub fn synthetic_trace(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
) -> Vec<Request> {
    assert!(!models.is_empty(), "trace needs at least one model");
    let gap_hi = mean_gap_cycles.saturating_mul(2).min(i64::MAX as u64) as i64;
    let mut rng = Rng::new(seed);
    let mut clock = 0u64;
    (0..requests as u64)
        .map(|id| {
            let model = *rng.choose(models);
            clock += rng.int(0, gap_hi) as u64;
            Request { id, model, arrival_cycles: clock }
        })
        .collect()
}

/// One virtual NPU instance: a re-entrant executor plus its position on
/// the shared clock.
pub struct NpuInstance {
    pub id: usize,
    executor: Executor,
    /// Clock cycle at which this instance next goes idle.
    pub busy_until_cycles: u64,
}

impl NpuInstance {
    /// Aggregate metrics of this instance's executor.
    pub fn metrics(&self) -> &Metrics {
        &self.executor.metrics
    }

    /// Total cycles spent serving (utilization numerator).
    pub fn busy_cycles(&self) -> u64 {
        self.executor.metrics.total_sim_cycles
    }

    /// Requests served.
    pub fn served(&self) -> u64 {
        self.executor.metrics.requests
    }
}

/// FIFO admission queue + earliest-idle-instance dispatch.
///
/// Determinism: dispatch order is admission order; ties between equally
/// idle instances break toward the lowest instance id; all timing derives
/// from the simulated program, never the host clock. With a fixed trace,
/// adding instances can only move every start time earlier — makespan is
/// monotone non-increasing in the instance count (the serve property suite
/// checks this).
pub struct Scheduler {
    instances: Vec<NpuInstance>,
    pending: VecDeque<Request>,
}

impl Scheduler {
    pub fn new(cfg: &NeutronConfig, instances: usize) -> Self {
        assert!(instances >= 1, "need at least one NPU instance");
        Self {
            instances: (0..instances)
                .map(|id| NpuInstance {
                    id,
                    executor: Executor::with_config(cfg.clone()),
                    busy_until_cycles: 0,
                })
                .collect(),
            pending: VecDeque::new(),
        }
    }

    /// Admit a request into the FIFO queue.
    pub fn admit(&mut self, request: Request) {
        self.pending.push_back(request);
    }

    /// Model of the request at the head of the admission queue, so the
    /// caller can resolve its compiled program before dispatching.
    pub fn next_model(&self) -> Option<ModelId> {
        self.pending.front().map(|r| r.model)
    }

    /// Requests still waiting in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    /// Dispatch the head request onto the earliest-idle instance. Returns
    /// `None` when the queue is empty.
    pub fn dispatch_next(&mut self, program: &JobProgram) -> Option<Completion> {
        let request = self.pending.pop_front()?;
        let instance = self
            .instances
            .iter_mut()
            .min_by_key(|i| (i.busy_until_cycles, i.id))
            .expect("at least one instance");
        let result = instance
            .executor
            .run_program(program, None)
            .expect("sim-only request cannot fail");
        let start = request.arrival_cycles.max(instance.busy_until_cycles);
        let finish = start + result.sim_cycles;
        instance.busy_until_cycles = finish;
        Some(Completion {
            id: request.id,
            model: request.model,
            instance: instance.id,
            arrival_cycles: request.arrival_cycles,
            start_cycles: start,
            finish_cycles: finish,
        })
    }

    /// Clock cycle when the last instance goes idle (0 if nothing ran).
    pub fn makespan_cycles(&self) -> u64 {
        self.instances
            .iter()
            .map(|i| i.busy_until_cycles)
            .max()
            .unwrap_or(0)
    }

    pub fn instances(&self) -> &[NpuInstance] {
        &self.instances
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Format;
    use crate::compiler::TileId;
    use crate::coordinator::Job;
    use crate::ir::OpId;

    fn toy_program(cycles: u64) -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: Vec::new(),
                    param_tile: None,
                    format: Format::Depth,
                    cycles,
                },
                Job::Barrier,
            ],
            model: "toy".to_string(),
        }
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let models = [ModelId::MobileNetV1, ModelId::MobileNetV2];
        let a = synthetic_trace(&models, 50, 1_000, 42);
        let b = synthetic_trace(&models, 50, 1_000, 42);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_cycles <= w[1].arrival_cycles));
        assert!(a.windows(2).all(|w| w[0].id + 1 == w[1].id));
        let c = synthetic_trace(&models, 50, 1_000, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn fifo_earliest_idle_dispatch() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, 2);
        let p = toy_program(1_000);
        for id in 0..4 {
            s.admit(Request { id, model: ModelId::MobileNetV1, arrival_cycles: 0 });
        }
        assert_eq!(s.queue_len(), 4);
        let mut done = Vec::new();
        while s.next_model().is_some() {
            done.push(s.dispatch_next(&p).unwrap());
        }
        // 4 × 1000-cycle requests over 2 instances: two waves.
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].instance, 0, "tie breaks toward the lowest id");
        assert_eq!(done[1].instance, 1);
        assert_eq!(done[0].finish_cycles, 1_000);
        assert_eq!(done[2].start_cycles, 1_000);
        assert_eq!(s.makespan_cycles(), 2_000);
        assert_eq!(done.iter().map(|c| c.latency_cycles()).max().unwrap(), 2_000);
        assert_eq!(s.instances()[0].served() + s.instances()[1].served(), 4);
        assert_eq!(s.instances()[0].metrics().requests, 2);
        assert_eq!(s.instances()[0].busy_cycles(), 2_000);
    }

    #[test]
    fn latency_is_queue_plus_service() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, 1);
        let p = toy_program(500);
        s.admit(Request { id: 0, model: ModelId::MobileNetV1, arrival_cycles: 100 });
        s.admit(Request { id: 1, model: ModelId::MobileNetV1, arrival_cycles: 150 });
        let a = s.dispatch_next(&p).unwrap();
        let b = s.dispatch_next(&p).unwrap();
        // The idle instance waits for the arrival; nothing starts early.
        assert_eq!(a.start_cycles, 100);
        assert_eq!(a.finish_cycles, 600);
        assert_eq!(a.queue_cycles(), 0);
        assert_eq!(b.start_cycles, 600);
        assert_eq!(b.queue_cycles(), 450);
        assert_eq!(b.latency_cycles(), b.queue_cycles() + b.service_cycles());
        assert_eq!(s.makespan_cycles(), 1_100);
    }

    #[test]
    fn empty_scheduler_reports_zero_makespan() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, 3);
        assert_eq!(s.makespan_cycles(), 0);
        assert!(s.next_model().is_none());
        assert!(s.dispatch_next(&toy_program(1)).is_none());
    }
}
