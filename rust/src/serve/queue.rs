//! Overload-aware admission queue + priority scheduler over N virtual NPU
//! instances.
//!
//! Event-driven simulation on the shared virtual clock (see the module doc
//! in `serve/mod.rs` for the determinism contract). Three mechanisms on
//! top of the earliest-idle dispatch core:
//!
//! * **Bounded admission** — the queue holds at most
//!   [`SchedulerOptions::queue_capacity`] requests; overflow is shed per
//!   [`AdmissionPolicy`] (reject the newest arrival, or drop the oldest
//!   queued request to make room). Shed requests never run and are
//!   reported separately, so sustained overload bounds queueing delay
//!   instead of growing it without limit.
//! * **Priority classes** — each [`Request`] carries a [`Priority`];
//!   dispatch picks the pending request with the best
//!   `(effective class, admission order)` key. An optional aging rule
//!   ([`SchedulerOptions::age_after_cycles`]) promotes a waiting request
//!   one class per aging period so low classes cannot starve.
//! * **Same-model batching** — when the head-of-queue request's model and
//!   class match other queued requests, up to
//!   [`SchedulerOptions::max_batch`] of them coalesce onto one instance.
//!   The batch leader pays the full service time; each follower pays only
//!   [`marginal_service_cycles`] (weights already resident, parameter
//!   fetches skipped), so batching raises throughput under backlog at a
//!   bounded latency cost. With [`SchedulerOptions::dynamic_batch`] the
//!   effective ceiling scales with queue depth (static `max_batch` stays
//!   the hard cap), so light load batches little and deep backlog batches
//!   fully.
//! * **Intra-instance pipelining + TCM weight residency** — with
//!   [`SchedulerOptions::pipeline`], a dispatch's head prefetch ticks
//!   overlap the same instance's previous request's fetch-free tail
//!   window (the DAE generalization of cross-request latency hiding);
//!   with [`SchedulerOptions::weight_residency`], each instance keeps hot
//!   models' parameter tiles resident in TCM ([`TcmResidency`]) under a
//!   cost-model-driven eviction policy and elides their fetches entirely
//!   (the batching "followers skip parameter DMA" trick, generalized
//!   across requests); [`SchedulerOptions::warm_routing`] then routes
//!   each request to the instance with the lowest predicted finish under
//!   warm/cold pricing instead of blind earliest-idle placement.
//!
//! Dispatch-order determinism: the selection key is a pure function of
//! the pending set and the decision time, ties break toward the earliest
//! admission, and equally idle instances break toward the lowest id — no
//! host-clock value ever enters a decision. Residency decisions, overlap
//! windows and warm routing all derive from the same deterministic state,
//! so the extended scheduler still replays bit-identically.
//!
//! **Energy accounting** ([`SchedulerOptions::energy`]) prices every
//! dispatch's ticks into femtojoules with the same DMA filters the
//! timing path uses — a pure observation layered beside the executor,
//! never inside it, so switching the meter on cannot move a single
//! timing field. [`SchedulerOptions::energy_mode`] and
//! [`SchedulerOptions::energy_budget_fj`] then make joules an objective:
//! stretch-mode batching coalesces same-model work even when instances
//! idle (eliding follower parameter-fetch DMA at a makespan cost), and a
//! fleet joule budget sheds Batch arrivals at ¾ spend and Standard
//! arrivals at exhaustion, Realtime never.

use std::collections::{HashMap, HashSet};

use crate::arch::{NeutronConfig, TcmResidency};
use crate::compiler::TileId;
use crate::coordinator::{Executor, Job, JobProgram, Metrics};
use crate::energy::{EnergyMode, EnergyModel, TickEnergy};
use crate::util::prop::Rng;
use crate::zoo::ModelId;

/// Priority class carried on every request. Lower [`Priority::rank`]
/// values dispatch first; within a class, admission order wins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Interactive traffic: always dispatched before other classes.
    Realtime,
    /// Default class for ordinary requests.
    Standard,
    /// Best-effort background work: yields to everything (until aging
    /// promotes it).
    Batch,
}

impl Priority {
    /// All classes, highest priority first.
    pub fn all() -> [Priority; 3] {
        [Priority::Realtime, Priority::Standard, Priority::Batch]
    }

    /// Dispatch rank: 0 is served first. Aging lowers the effective rank
    /// of a waiting request, never past 0.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Realtime => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    /// Human-readable class name (also the trace-format spelling).
    pub fn display_name(self) -> &'static str {
        match self {
            Priority::Realtime => "realtime",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Parse the [`Priority::display_name`] spelling back.
    pub fn parse(s: &str) -> Option<Priority> {
        let lower = s.to_ascii_lowercase();
        Priority::all().into_iter().find(|p| p.display_name() == lower)
    }
}

/// Relative class weights for synthetic trace generation: each request's
/// class is drawn with probability `weight / total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PriorityMix {
    /// Weight of [`Priority::Realtime`].
    pub realtime: u32,
    /// Weight of [`Priority::Standard`].
    pub standard: u32,
    /// Weight of [`Priority::Batch`].
    pub batch: u32,
}

impl Default for PriorityMix {
    /// The serving default: 1 realtime : 2 standard : 1 batch.
    fn default() -> Self {
        Self { realtime: 1, standard: 2, batch: 1 }
    }
}

impl PriorityMix {
    /// Every request is [`Priority::Standard`] — the mix that degenerates
    /// to plain FIFO scheduling (no aging, no class reordering).
    pub fn standard_only() -> Self {
        Self { realtime: 0, standard: 1, batch: 0 }
    }

    /// Draw one class; consumes exactly one PRNG value, so traces stay
    /// reproducible. Panics when all weights are zero. Weights sum in
    /// u64, so extreme u32 weights cannot overflow into a wrong
    /// distribution.
    pub fn pick(&self, rng: &mut Rng) -> Priority {
        let (realtime, standard) = (self.realtime as u64, self.standard as u64);
        let total = realtime + standard + self.batch as u64;
        assert!(total > 0, "priority mix needs at least one non-zero weight");
        let draw = rng.int(0, total as i64 - 1) as u64;
        if draw < realtime {
            Priority::Realtime
        } else if draw < realtime + standard {
            Priority::Standard
        } else {
            Priority::Batch
        }
    }
}

/// What to do with an arrival when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Shed the arriving request itself (the queue keeps its backlog).
    RejectNewest,
    /// Shed the oldest queued request — regardless of class — and admit
    /// the arrival (bounded-staleness semantics: the longest-queued work
    /// is the least likely to still be wanted).
    DropOldest,
}

impl AdmissionPolicy {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "reject-newest" | "reject" => Some(AdmissionPolicy::RejectNewest),
            "drop-oldest" | "drop" => Some(AdmissionPolicy::DropOldest),
            _ => None,
        }
    }

    /// Human-readable policy name (the CLI spelling).
    pub fn display_name(self) -> &'static str {
        match self {
            AdmissionPolicy::RejectNewest => "reject-newest",
            AdmissionPolicy::DropOldest => "drop-oldest",
        }
    }
}

/// Outcome of one [`Scheduler::admit`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// The request entered the queue.
    Accepted,
    /// The queue was full: the contained request was shed — the arrival
    /// itself under [`AdmissionPolicy::RejectNewest`], the oldest queued
    /// request under [`AdmissionPolicy::DropOldest`].
    Shed(Request),
}

/// Scheduling knobs, grouped so every entry point (CLI, benches, tests)
/// names them once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchedulerOptions {
    /// Virtual NPU instances sharing the admission queue (≥ 1).
    pub instances: usize,
    /// Maximum queued (admitted, not yet dispatched) requests. `None`
    /// means unbounded — the PR-1 behavior, where sustained overload
    /// grows latency without limit.
    pub queue_capacity: Option<usize>,
    /// Load-shedding policy applied when the queue is full.
    pub policy: AdmissionPolicy,
    /// Largest same-model, same-class batch one dispatch may coalesce;
    /// `1` disables batching.
    pub max_batch: usize,
    /// Scale the effective batch ceiling with queue depth: a dispatch may
    /// coalesce at most `ceil(backlog / instances)` requests (backlog
    /// includes the dispatch head), capped by the static `max_batch`
    /// ceiling. Light backlog then batches little (latency-friendly) while
    /// deep backlog batches up to the full ceiling (throughput-friendly).
    /// `false` keeps the static `max_batch` for every dispatch.
    pub dynamic_batch: bool,
    /// Starvation-avoidance aging: a waiting request is promoted one
    /// class per this many cycles waited (`None` disables aging and makes
    /// class order strict).
    pub age_after_cycles: Option<u64>,
    /// Intra-instance pipelining: overlap a dispatch's head prefetch
    /// ticks with the same instance's previous request's fetch-free tail
    /// window. Off reproduces strict back-to-back service bit for bit.
    pub pipeline: bool,
    /// TCM weight residency: each instance keeps hot models' parameter
    /// tiles resident across requests (capacity-accounted, deterministic
    /// cost-model-driven eviction — see [`TcmResidency`]) and elides the
    /// fetches of resident tiles. Off reproduces cold dispatch bit for
    /// bit.
    pub weight_residency: bool,
    /// Route each request to the instance with the lowest predicted
    /// finish under warm/cold pricing (instead of blind earliest-idle
    /// placement). Requires `weight_residency`.
    pub warm_routing: bool,
    /// Override the TCM capacity (bytes) accounted for weight residency;
    /// `None` charges against the config's full TCM size. Requires
    /// `weight_residency`.
    pub residency_capacity_bytes: Option<u64>,
    /// Per-tenant (per-owner) residency quota in bytes: no single model's
    /// weights — or single sequence's KV cache — may hold more than this
    /// much TCM, with over-quota installs evicting the owner's own
    /// lowest-value tiles first ([`TcmResidency::with_quota`]). `None`
    /// lets any owner fill the whole capacity. Requires
    /// `weight_residency`.
    pub residency_quota_bytes: Option<u64>,
    /// Continuous batching for decode requests: sequences join their
    /// instance at prefill end and advance one token per round, with the
    /// model's decode-step weights pinned on-chip for as long as it has
    /// active sequences there — the first step of a model on an instance
    /// pays its parameter streaming, every later step (same sequence or a
    /// same-model follower) elides it (the batching marginal-cost rule
    /// applied at token granularity). Off, a decode request occupies its
    /// instance from prefill through last token and replays the bucket
    /// program cold — re-paying parameter streaming — every step
    /// (request-boundary scheduling).
    pub continuous_batch: bool,
    /// Energy accounting: price every dispatch's ticks into femtojoules
    /// with the [`crate::energy::EnergyModel`] derived from the config,
    /// carried on each [`Completion`] (compute / DMA / idle channels,
    /// exactly conserved). Off, every completion carries zero energy and
    /// nothing else changes — timing, reports and traces are bit-
    /// identical to a build without energy accounting.
    pub energy: bool,
    /// Energy objective ([`EnergyMode`]): `RaceToIdle` (default) leaves
    /// scheduling untouched; `Stretch` coalesces same-model batches even
    /// when idle instances are available, trading makespan for the
    /// parameter-fetch DMA energy the followers elide. Stretch requires
    /// `energy` — there is no point stretching without the meter on.
    pub energy_mode: EnergyMode,
    /// Fleet-wide joule budget in femtojoules: once ¾ of it is spent,
    /// arriving [`Priority::Batch`] requests are shed; once it is
    /// exhausted, [`Priority::Standard`] arrivals are shed too.
    /// [`Priority::Realtime`] is always admitted (budgets degrade
    /// best-effort work first, never interactive traffic). Requires
    /// `energy` — the budget is enforced against metered spend.
    pub energy_budget_fj: Option<u64>,
}

impl Default for SchedulerOptions {
    /// Two instances, unbounded FIFO-per-class queue, no batching, no
    /// aging, no pipelining, no residency — the exact PR-1 scheduler when
    /// every request is [`Priority::Standard`].
    fn default() -> Self {
        Self {
            instances: 2,
            queue_capacity: None,
            policy: AdmissionPolicy::RejectNewest,
            max_batch: 1,
            dynamic_batch: false,
            age_after_cycles: None,
            pipeline: false,
            weight_residency: false,
            warm_routing: false,
            residency_capacity_bytes: None,
            residency_quota_bytes: None,
            continuous_batch: false,
            energy: false,
            energy_mode: EnergyMode::RaceToIdle,
            energy_budget_fj: None,
        }
    }
}

impl SchedulerOptions {
    fn validate(&self) {
        assert!(self.instances >= 1, "need at least one NPU instance");
        assert!(self.max_batch >= 1, "max_batch must be at least 1 (1 = batching off)");
        if let Some(cap) = self.queue_capacity {
            assert!(cap >= 1, "queue capacity must be at least 1 (use None for unbounded)");
        }
        if let Some(age) = self.age_after_cycles {
            assert!(age >= 1, "age_after_cycles must be at least 1 (use None to disable)");
        }
        assert!(
            !self.warm_routing || self.weight_residency,
            "warm_routing requires weight_residency (there is no warm state to route to)"
        );
        if let Some(cap) = self.residency_capacity_bytes {
            assert!(
                self.weight_residency,
                "residency_capacity_bytes requires weight_residency"
            );
            assert!(cap >= 1, "residency capacity must be at least 1 byte (use None for the config TCM size)");
        }
        if let Some(quota) = self.residency_quota_bytes {
            assert!(
                self.weight_residency,
                "residency_quota_bytes requires weight_residency (there is no residency to cap)"
            );
            assert!(quota >= 1, "residency quota must be at least 1 byte (use None for no per-owner cap)");
            if let Some(cap) = self.residency_capacity_bytes {
                assert!(
                    quota <= cap,
                    "residency quota ({quota} bytes) exceeds the residency capacity ({cap} bytes)"
                );
            }
        }
        assert!(
            self.energy || self.energy_mode == EnergyMode::RaceToIdle,
            "energy_mode stretch requires energy accounting (there is no meter to optimize)"
        );
        if let Some(budget) = self.energy_budget_fj {
            assert!(
                self.energy,
                "energy_budget_fj requires energy accounting (there is no spend to budget)"
            );
            assert!(budget >= 1, "energy budget must be at least 1 fJ (use None for no budget)");
        }
    }
}

/// One inference request on the virtual clock. A request with
/// `decode_tokens > 0` is an autoregressive GenAI request: it runs its
/// model's prefill over `prompt_tokens` prompt rows (producing the first
/// token) and then `decode_tokens - 1` single-token decode steps over the
/// growing KV cache. `decode_tokens == 0` is an ordinary single-shot
/// inference — the PR-1 request, bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Caller-assigned id; [`synthetic_trace`] uses the trace index.
    pub id: u64,
    /// Which zoo model to run.
    pub model: ModelId,
    /// Priority class (see [`Priority`]).
    pub priority: Priority,
    /// Arrival time in NPU core cycles on the shared virtual clock.
    pub arrival_cycles: u64,
    /// Prompt length in tokens (decode requests only; 0 for single-shot
    /// inference).
    pub prompt_tokens: u32,
    /// Total tokens to generate, counting the first token the prefill
    /// produces. 0 marks a single-shot (non-decode) request.
    pub decode_tokens: u32,
}

impl Request {
    /// An ordinary single-shot inference request (no decode phase).
    pub fn inference(id: u64, model: ModelId, priority: Priority, arrival_cycles: u64) -> Self {
        Self { id, model, priority, arrival_cycles, prompt_tokens: 0, decode_tokens: 0 }
    }

    /// An autoregressive decode request: prefill `prompt_tokens` rows,
    /// generate `decode_tokens` tokens total. Panics on zero counts — a
    /// decode request needs a prompt and at least its first token.
    pub fn decode(
        id: u64,
        model: ModelId,
        priority: Priority,
        arrival_cycles: u64,
        prompt_tokens: u32,
        decode_tokens: u32,
    ) -> Self {
        assert!(prompt_tokens >= 1, "a decode request needs at least one prompt token");
        assert!(decode_tokens >= 1, "a decode request generates at least its first token");
        Self { id, model, priority, arrival_cycles, prompt_tokens, decode_tokens }
    }

    /// Is this an autoregressive decode request?
    pub fn is_decode(&self) -> bool {
        self.decode_tokens > 0
    }
}

/// Completion record: latency = queueing delay + service time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// Id of the completed request.
    pub id: u64,
    /// Model the request ran.
    pub model: ModelId,
    /// Priority class the request carried.
    pub priority: Priority,
    /// Instance that served the request.
    pub instance: usize,
    /// Position inside the dispatched batch: 0 is the leader (or a solo
    /// request), followers count up from 1.
    pub batch_index: u32,
    /// When the request arrived.
    pub arrival_cycles: u64,
    /// When its batch was dispatched onto the instance.
    pub start_cycles: u64,
    /// When this request's result became available (followers finish
    /// staggered, one marginal service time apart).
    pub finish_cycles: u64,
    /// Head-prefetch cycles that ran inside the predecessor's fetch-free
    /// tail window ([`SchedulerOptions::pipeline`]); 0 with pipelining
    /// off and for batch followers.
    pub overlap_cycles: u64,
    /// Datamover cycles elided because this request's parameter tiles
    /// were already resident in TCM
    /// ([`SchedulerOptions::weight_residency`]); 0 with residency off and
    /// for batch followers (whose marginal pricing already skips them).
    /// For decode requests this also counts the KV-cache fetch cycles
    /// elided by KV residency.
    pub residency_hit_cycles: u64,
    /// When the request's first token became available. For a decode
    /// request this is the prefill's finish (the TTFT anchor); for a
    /// single-shot request it equals `finish_cycles`.
    pub first_token_cycles: u64,
    /// Tokens this completion produced: `decode_tokens` for a decode
    /// request, 1 for a single-shot inference.
    pub tokens: u32,
    /// KV-cache fetch cycles a decode request re-paid because its cache
    /// was evicted from TCM between steps (preemption refetch); 0 for
    /// single-shot requests and with residency off (where every step
    /// streams the cache and nothing counts as a *re*-fetch).
    pub kv_refetch_cycles: u64,
    /// Compute-channel energy this request's service consumed, in
    /// femtojoules ([`SchedulerOptions::energy`]); 0 with energy
    /// accounting off.
    pub energy_compute_fj: u64,
    /// DMA-channel energy, femtojoules; 0 with energy accounting off.
    pub energy_dma_fj: u64,
    /// Idle-channel energy (idle floors + leakage inside the request's
    /// service ticks), femtojoules; 0 with energy accounting off.
    pub energy_idle_fj: u64,
}

impl Completion {
    /// End-to-end latency on the virtual clock.
    pub fn latency_cycles(&self) -> u64 {
        self.finish_cycles - self.arrival_cycles
    }

    /// Time spent waiting in the admission queue.
    pub fn queue_cycles(&self) -> u64 {
        self.start_cycles - self.arrival_cycles
    }

    /// Time from dispatch to this request's finish. For a batch follower
    /// this includes the shared pipeline time ahead of it, so the
    /// decomposition `latency = queue + service` always holds.
    pub fn service_cycles(&self) -> u64 {
        self.finish_cycles - self.start_cycles
    }

    /// Did this request ride a batch as a follower?
    pub fn batched(&self) -> bool {
        self.batch_index > 0
    }

    /// Time to first token: arrival → first token available. Equals
    /// `latency_cycles` for single-shot requests, so `TTFT ≤ latency`
    /// holds universally.
    pub fn ttft_cycles(&self) -> u64 {
        self.first_token_cycles - self.arrival_cycles
    }

    /// Cycles spent in the decode phase (first token → finish); 0 for
    /// single-shot requests.
    pub fn decode_phase_cycles(&self) -> u64 {
        self.finish_cycles - self.first_token_cycles
    }

    /// Mean time per output token over the decode phase, `None` for
    /// completions that produced a single token (TPOT is undefined — no
    /// inter-token gaps exist). By construction
    /// `ttft + tpot·(tokens−1) = latency` exactly.
    pub fn tpot_cycles(&self) -> Option<f64> {
        if self.tokens <= 1 {
            None
        } else {
            Some(self.decode_phase_cycles() as f64 / (self.tokens - 1) as f64)
        }
    }

    /// Total energy this request's service consumed, femtojoules. Equals
    /// the exact sum of the three channel fields (the conservation
    /// invariant is enforced where the channels are priced); 0 with
    /// energy accounting off.
    pub fn energy_total_fj(&self) -> u64 {
        self.energy_compute_fj + self.energy_dma_fj + self.energy_idle_fj
    }
}

/// Largest admissible `mean_gap_cycles` for [`synthetic_trace`]: gaps are
/// drawn uniformly from `[0, 2·mean]`, and `2·mean` must fit the PRNG's
/// signed-integer range. ≈ 4.6e18 cycles — around 146 years at 1 GHz, so
/// the bound never binds for realistic traces; it exists to make the
/// overflow case loud instead of silently clamping the distribution.
pub const MAX_MEAN_GAP_CYCLES: u64 = (i64::MAX / 2) as u64;

/// Deterministic synthetic request trace with every request
/// [`Priority::Standard`]: the model of each request is drawn uniformly
/// from `models`, inter-arrival gaps uniformly from
/// `[0, 2·mean_gap_cycles]` (mean `mean_gap_cycles`). Same inputs →
/// identical trace; arrivals are non-decreasing and ids are `0..requests`.
///
/// Panics when `mean_gap_cycles` exceeds [`MAX_MEAN_GAP_CYCLES`].
pub fn synthetic_trace(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
) -> Vec<Request> {
    synthetic_trace_with_mix(models, requests, mean_gap_cycles, seed, &PriorityMix::standard_only())
}

/// [`synthetic_trace`] with the priority class of each request drawn from
/// `mix`. Per request the PRNG is consumed in a fixed order — model,
/// class, gap — so traces are reproducible across runs and machines.
pub fn synthetic_trace_with_mix(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
    mix: &PriorityMix,
) -> Vec<Request> {
    assert!(!models.is_empty(), "trace needs at least one model");
    assert!(
        mean_gap_cycles <= MAX_MEAN_GAP_CYCLES,
        "mean_gap_cycles {mean_gap_cycles} exceeds MAX_MEAN_GAP_CYCLES {MAX_MEAN_GAP_CYCLES}"
    );
    let gap_hi = (mean_gap_cycles * 2) as i64;
    let mut rng = Rng::new(seed);
    let mut clock = 0u64;
    (0..requests as u64)
        .map(|id| {
            let model = *rng.choose(models);
            let priority = mix.pick(&mut rng);
            clock = clock.saturating_add(rng.int(0, gap_hi) as u64);
            Request::inference(id, model, priority, clock)
        })
        .collect()
}

/// Deterministic synthetic *decode* trace: like [`synthetic_trace`], but
/// every request is an autoregressive decode request with the given
/// prompt and generation lengths (class [`Priority::Standard`]). The PRNG
/// is consumed in the same fixed per-request order — model, gap — so the
/// arrival skeleton is reproducible across runs and machines.
pub fn synthetic_decode_trace(
    models: &[ModelId],
    requests: usize,
    mean_gap_cycles: u64,
    seed: u64,
    prompt_tokens: u32,
    decode_tokens: u32,
) -> Vec<Request> {
    assert!(!models.is_empty(), "trace needs at least one model");
    assert!(
        mean_gap_cycles <= MAX_MEAN_GAP_CYCLES,
        "mean_gap_cycles {mean_gap_cycles} exceeds MAX_MEAN_GAP_CYCLES {MAX_MEAN_GAP_CYCLES}"
    );
    let gap_hi = (mean_gap_cycles * 2) as i64;
    let mut rng = Rng::new(seed);
    let mut clock = 0u64;
    (0..requests as u64)
        .map(|id| {
            let model = *rng.choose(models);
            clock = clock.saturating_add(rng.int(0, gap_hi) as u64);
            Request::decode(id, model, Priority::Standard, clock, prompt_tokens, decode_tokens)
        })
        .collect()
}

/// Service time of a batch follower: the program's tick timing
/// ([`JobProgram::service_cycles_where`], the same helper the executor
/// uses for full service times) with every parameter-tile DMA job
/// skipped — the leader already fetched the weights, and they stay
/// resident for the batch — while all compute and all activation traffic
/// is still paid. Dropping DMA cycles can only shrink a tick's
/// `max(compute, dm)`, so the result is always ≤ the full service time.
pub fn marginal_service_cycles(program: &JobProgram) -> u64 {
    let param_tiles = program.param_tiles();
    program.service_cycles_where(|job| match job {
        Job::Dma { tile, .. } => !param_tiles.contains(tile),
        _ => true,
    })
}

/// The overlap window a successor arriving at `arrival` gets against a
/// predecessor finishing at `prev_finish` whose fetch-free tail spans
/// `tail_window` cycles: the part of the tail the successor was already
/// queued for. 0 when the successor arrived after the predecessor
/// finished (the instance went idle — nothing to hide behind).
fn overlap_window(prev_finish: u64, tail_window: u64, arrival: u64) -> u64 {
    if arrival >= prev_finish {
        0
    } else {
        (prev_finish - arrival).min(tail_window)
    }
}

/// Stable residency owner id of a zoo model: its position in
/// [`ModelId::all`] (the enum itself stays encoding-free).
fn model_owner(model: ModelId) -> u64 {
    ModelId::all()
        .iter()
        .position(|&m| m == model)
        .expect("every ModelId appears in ModelId::all()") as u64
}

/// Residency owner ids at or above this value are per-sequence KV caches;
/// below it they are per-model weight sets ([`model_owner`]). Keeping
/// both in one [`TcmResidency`] makes weights and KV caches compete for
/// the same TCM bytes under one deterministic eviction order — the
/// capacity pressure Sec. VI describes.
pub const KV_OWNER_BASE: u64 = 1 << 32;

/// Residency owner id of a decode sequence's KV cache. Request ids at or
/// above `KV_OWNER_BASE` would collide with other sequences' owners, so
/// they are rejected loudly.
fn kv_owner(request_id: u64) -> u64 {
    assert!(
        request_id < KV_OWNER_BASE,
        "decode request id {request_id} too large for a KV residency owner"
    );
    KV_OWNER_BASE + request_id
}

/// Per-parameter-tile DMA footprint of a program, in first-appearance
/// order: the capacity a residency install must charge (largest single
/// transfer of the tile) and the datamover cycles a hit saves (all of
/// the tile's transfers).
fn param_tile_stats(program: &JobProgram) -> Vec<(TileId, u64, u64)> {
    let param_tiles = program.param_tiles();
    let mut stats: Vec<(TileId, u64, u64)> = Vec::new();
    for job in &program.jobs {
        if let Job::Dma { tile, bytes, cycles, .. } = job {
            if param_tiles.contains(tile) {
                match stats.iter_mut().find(|(t, _, _)| t == tile) {
                    Some((_, b, c)) => {
                        *b = (*b).max(*bytes);
                        *c += cycles;
                    }
                    None => stats.push((*tile, *bytes, *cycles)),
                }
            }
        }
    }
    stats
}

/// One decode sequence resident on an instance under continuous
/// batching: it joined at its prefill's end and advances one token per
/// decode round until `tokens_done == decode_tokens`.
struct ActiveSeq {
    request: Request,
    /// Tokens generated so far (≥ 1 once joined — the prefill produced
    /// the first token).
    tokens_done: u32,
    first_token_cycles: u64,
    start_cycles: u64,
    /// Elided fetch cycles (weights at prefill + KV hits) accumulated
    /// over the sequence's life; emitted on its completion record.
    residency_hit_cycles: u64,
    /// KV fetch cycles re-paid after an eviction (preemption refetch).
    kv_refetch_cycles: u64,
    /// Has this sequence's KV cache ever been installed in TCM? A miss
    /// after a successful install is a preemption refetch, not a cold
    /// start.
    kv_installed: bool,
    /// Energy accumulated over the sequence's life (prefill + every
    /// decode step so far); emitted on its completion record.
    /// [`TickEnergy::ZERO`] throughout with energy accounting off.
    energy: TickEnergy,
}

/// One virtual NPU instance: a re-entrant executor plus its position on
/// the shared clock and (when enabled) its TCM weight-residency state.
pub struct NpuInstance {
    /// Stable instance id (also the dispatch tie-breaker).
    pub id: usize,
    executor: Executor,
    /// Clock cycle at which this instance next goes idle.
    pub busy_until_cycles: u64,
    occupied_cycles: u64,
    served: u64,
    /// Parameter tiles resident in this instance's TCM
    /// (`Some` iff [`SchedulerOptions::weight_residency`]).
    residency: Option<TcmResidency>,
    /// Fetch-free tail window of the last solo dispatch (0 after a batch
    /// — the staggered follower replays make the window unreliable).
    last_tail_window_cycles: u64,
    /// Decode sequences continuously batched on this instance, in join
    /// order (empty unless [`SchedulerOptions::continuous_batch`]).
    active: Vec<ActiveSeq>,
    /// Models whose decode-step weights are currently pinned on this
    /// instance: a model joins when its first continuous decode step pays
    /// the parameter streaming and leaves when its last active sequence
    /// completes. Every step while pinned elides the parameter fetches —
    /// the mechanism by which continuous batching beats request-boundary
    /// scheduling on both makespan and TPOT.
    decode_warm: HashSet<ModelId>,
}

impl NpuInstance {
    /// Aggregate executor metrics (one executor run per dispatched batch;
    /// batch followers replay the leader's program, so they do not run the
    /// executor again).
    pub fn metrics(&self) -> &Metrics {
        &self.executor.metrics
    }

    /// Total cycles this instance was occupied serving dispatches,
    /// including the marginal tail of every batch (utilization
    /// numerator). Head cycles a pipelined dispatch overlapped into the
    /// predecessor's window are counted once — inside the predecessor's
    /// interval — so per-instance occupancy never exceeds the clock.
    pub fn busy_cycles(&self) -> u64 {
        self.occupied_cycles
    }

    /// Requests served, counting every batch member.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// This instance's TCM residency state (`None` when
    /// [`SchedulerOptions::weight_residency`] is off).
    pub fn residency(&self) -> Option<&TcmResidency> {
        self.residency.as_ref()
    }

    /// Decode sequences currently continuously batched on this instance.
    pub fn active_decode(&self) -> usize {
        self.active.len()
    }
}

/// Internal queue entry: the request plus its admission sequence number.
/// `pending` stays sorted by `seq` (entries are only appended and
/// removed), which makes "oldest" and FIFO-within-class O(1) to define.
struct QueuedRequest {
    request: Request,
    seq: u64,
}

/// A planned dispatch: which pending entry, onto which instance, when.
struct Plan {
    pending_idx: usize,
    instance_idx: usize,
    start_cycles: u64,
}

/// Overload-aware scheduler: bounded admission queue + priority dispatch
/// with aging + same-model batching over N virtual NPU instances.
///
/// Dispatch order is deterministic: among requests that have arrived by
/// the decision time, the lowest `(effective class rank, admission order)`
/// key wins; equally idle instances break toward the lowest id; all
/// timing derives from the simulated program, never the host clock. With
/// the default options and a single-class trace this degenerates to the
/// FIFO earliest-idle scheduler, for which adding instances can only move
/// every completion earlier (the serve property suite checks this).
///
/// The caller resolves the compiled program for the model named by
/// [`Scheduler::next_model`] (usually through the compile cache) and
/// passes it to [`Scheduler::dispatch_next`]; nothing may be admitted
/// between the two calls, or the plan they agree on would change.
///
/// ```
/// use eiq_neutron::arch::NeutronConfig;
/// use eiq_neutron::serve::{CompileCache, Priority, Request, Scheduler, SchedulerOptions};
/// use eiq_neutron::zoo::ModelId;
///
/// let cfg = NeutronConfig::flagship_2tops();
/// let mut cache = CompileCache::for_serving(cfg.clone());
/// let opts = SchedulerOptions { instances: 1, ..SchedulerOptions::default() };
/// let mut scheduler = Scheduler::new(&cfg, &opts);
/// for id in 0..3 {
///     scheduler.admit(Request::inference(id, ModelId::MobileNetV3Min, Priority::Standard, 0));
/// }
/// let mut completions = Vec::new();
/// while let Some(model) = scheduler.next_model() {
///     let entry = cache.get(model);
///     completions.extend(scheduler.dispatch_next(model, &entry.program));
/// }
/// assert_eq!(completions.len(), 3);
/// assert!(completions.windows(2).all(|w| w[0].finish_cycles <= w[1].finish_cycles));
/// ```
pub struct Scheduler {
    opts: SchedulerOptions,
    instances: Vec<NpuInstance>,
    pending: Vec<QueuedRequest>,
    shed: Vec<Request>,
    next_seq: u64,
    /// Per-model program skeletons seen by [`Scheduler::dispatch_next`],
    /// used by warm routing to price "warm on a busy instance" against
    /// "cold on an idle one" before the caller resolves the program.
    skeletons: HashMap<ModelId, JobProgram>,
    warm_dispatches: u64,
    overlap_cycles_total: u64,
    /// Decode artifacts by model, registered by the caller
    /// ([`Scheduler::register_decode_job`]) before the first decode
    /// request of that model dispatches.
    decode_jobs: HashMap<ModelId, std::sync::Arc<crate::coordinator::DecodeJob>>,
    /// KV-cache residency entries evicted from TCM (by weight installs or
    /// other sequences' caches) — each one forces a preemption refetch.
    kv_evictions: u64,
    /// Tokens generated across all completed decode requests (single-shot
    /// completions count 1 each).
    tokens_generated: u64,
    /// Energy pricer, `Some` iff [`SchedulerOptions::energy`]. Pricing is
    /// a pure observation of dispatch shapes — it never feeds back into
    /// timing (except through the explicitly opt-in budget/stretch
    /// knobs).
    energy_model: Option<EnergyModel>,
    /// Total femtojoules metered so far across all dispatches (the
    /// budget-enforcement accumulator); 0 with energy accounting off.
    energy_spent_fj: u64,
}

impl Scheduler {
    /// Build a scheduler with `opts.instances` fresh executor instances.
    /// Panics when the options are inconsistent (see [`SchedulerOptions`]).
    pub fn new(cfg: &NeutronConfig, opts: &SchedulerOptions) -> Self {
        opts.validate();
        Self {
            opts: opts.clone(),
            instances: (0..opts.instances)
                .map(|id| NpuInstance {
                    id,
                    executor: Executor::with_config(cfg.clone()),
                    busy_until_cycles: 0,
                    occupied_cycles: 0,
                    served: 0,
                    residency: opts.weight_residency.then(|| {
                        let capacity =
                            opts.residency_capacity_bytes.unwrap_or(cfg.tcm_bytes as u64);
                        match opts.residency_quota_bytes {
                            Some(quota) => {
                                assert!(
                                    quota <= capacity,
                                    "residency quota ({quota} bytes) exceeds the TCM \
                                     residency capacity ({capacity} bytes)"
                                );
                                TcmResidency::with_quota(capacity, quota)
                            }
                            None => TcmResidency::new(capacity),
                        }
                    }),
                    last_tail_window_cycles: 0,
                    active: Vec::new(),
                    decode_warm: HashSet::new(),
                })
                .collect(),
            pending: Vec::new(),
            shed: Vec::new(),
            next_seq: 0,
            skeletons: HashMap::new(),
            warm_dispatches: 0,
            overlap_cycles_total: 0,
            decode_jobs: HashMap::new(),
            kv_evictions: 0,
            tokens_generated: 0,
            energy_model: opts.energy.then(|| EnergyModel::for_config(cfg)),
            energy_spent_fj: 0,
        }
    }

    /// Register a model's decode artifact. Must be called (once per
    /// model) before the first decode request of that model dispatches;
    /// repeated registration replaces the artifact.
    pub fn register_decode_job(
        &mut self,
        model: ModelId,
        job: std::sync::Arc<crate::coordinator::DecodeJob>,
    ) {
        self.decode_jobs.insert(model, job);
    }

    /// Offer a request to the admission queue. When the queue is at
    /// capacity the configured [`AdmissionPolicy`] decides who is shed;
    /// the victim is recorded in [`Scheduler::shed`] and returned.
    pub fn admit(&mut self, request: Request) -> Admission {
        // Energy-budget shedding runs before capacity: once ¾ of the
        // fleet joule budget is metered, Batch arrivals are shed; once it
        // is exhausted, Standard arrivals too. Realtime always passes —
        // budgets degrade best-effort work first, never interactive
        // traffic. (u128 keeps `spent·4` overflow-proof for any budget.)
        if let Some(budget) = self.opts.energy_budget_fj {
            let spent = self.energy_spent_fj as u128;
            let shed_now = match request.priority {
                Priority::Realtime => false,
                Priority::Standard => spent >= budget as u128,
                Priority::Batch => spent * 4 >= budget as u128 * 3,
            };
            if shed_now {
                self.shed.push(request);
                return Admission::Shed(request);
            }
        }
        if let Some(cap) = self.opts.queue_capacity {
            if self.pending.len() >= cap {
                match self.opts.policy {
                    AdmissionPolicy::RejectNewest => {
                        self.shed.push(request);
                        return Admission::Shed(request);
                    }
                    AdmissionPolicy::DropOldest => {
                        // `pending` is seq-sorted, so index 0 is oldest.
                        let victim = self.pending.remove(0).request;
                        self.shed.push(victim);
                        self.push_pending(request);
                        return Admission::Shed(victim);
                    }
                }
            }
        }
        self.push_pending(request);
        Admission::Accepted
    }

    fn push_pending(&mut self, request: Request) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(QueuedRequest { request, seq });
    }

    /// Requests still waiting in the admission queue.
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    /// Every request shed so far, in shedding order.
    pub fn shed(&self) -> &[Request] {
        &self.shed
    }

    /// Effective dispatch rank of a request at `now`: the class rank,
    /// minus one promotion per full aging period waited, floored at the
    /// highest class.
    fn effective_rank(&self, request: &Request, now: u64) -> u8 {
        let base = request.priority.rank();
        match self.opts.age_after_cycles {
            Some(age) => {
                let waited = now.saturating_sub(request.arrival_cycles);
                base - (waited / age).min(base as u64) as u8
            }
            None => base,
        }
    }

    /// Batch ceiling for the dispatch being committed right now: the
    /// static `max_batch`, or — under [`SchedulerOptions::dynamic_batch`]
    /// — `ceil(backlog / instances)` capped by `max_batch`, where the
    /// backlog counts the queued requests plus the dispatch head (already
    /// popped when this runs). A pure function of queue depth, so dynamic
    /// sizing preserves the determinism contract.
    fn effective_max_batch(&self) -> usize {
        if !self.opts.dynamic_batch {
            return self.opts.max_batch;
        }
        let backlog = self.pending.len() + 1;
        let per_instance = (backlog + self.opts.instances - 1) / self.opts.instances;
        per_instance.clamp(1, self.opts.max_batch)
    }

    /// Plan the next dispatch without committing it. The decision time is
    /// `max(earliest instance idle, earliest pending arrival)` — the first
    /// moment an instance is free *and* some request exists — and only
    /// requests that have arrived by then are eligible (the scheduler
    /// cannot see the future). Under [`SchedulerOptions::warm_routing`]
    /// the request choice is unchanged, but the instance is re-picked to
    /// minimize its predicted finish time using each instance's residency
    /// state and the model's cached program skeleton, so a warm busy
    /// instance can beat a cold idle one.
    fn plan(&self) -> Option<Plan> {
        let min_arrival = self.pending.iter().map(|q| q.request.arrival_cycles).min()?;
        let instance_idx = self
            .instances
            .iter()
            .min_by_key(|i| (i.busy_until_cycles, i.id))
            .expect("at least one instance")
            .id;
        let decision = self.instances[instance_idx].busy_until_cycles.max(min_arrival);
        let pending_idx = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, q)| q.request.arrival_cycles <= decision)
            .min_by_key(|(_, q)| (self.effective_rank(&q.request, decision), q.seq))
            .map(|(i, _)| i)
            .expect("min_arrival guarantees at least one eligible request");
        // Decode dispatches always take the earliest-idle instance: their
        // cost structure (prefill + growing-context steps) is not the
        // skeleton warm routing prices with, so warm routing does not
        // apply to them.
        if !self.opts.warm_routing || self.pending[pending_idx].request.is_decode() {
            return Some(Plan { pending_idx, instance_idx, start_cycles: decision });
        }
        let request = &self.pending[pending_idx].request;
        let Some(skeleton) = self.skeletons.get(&request.model) else {
            // First dispatch of the model: no skeleton to price with.
            return Some(Plan { pending_idx, instance_idx, start_cycles: decision });
        };
        let owner = model_owner(request.model);
        let param_tiles = skeleton.param_tiles();
        let mut best: Option<(u64, usize, u64)> = None; // (finish, id, start)
        for inst in &self.instances {
            let warm: HashSet<TileId> = param_tiles
                .iter()
                .filter(|t| {
                    inst.residency
                        .as_ref()
                        .is_some_and(|r| r.is_resident(owner, t.0))
                })
                .copied()
                .collect();
            let count = |j: &Job| match j {
                Job::Dma { tile, .. } => !warm.contains(tile),
                _ => true,
            };
            let start = inst.busy_until_cycles.max(decision);
            let effective = skeleton.service_cycles_where(count);
            let overlap = if self.opts.pipeline {
                skeleton.pipeline_profile_where(count).head_cycles.min(overlap_window(
                    inst.busy_until_cycles,
                    inst.last_tail_window_cycles,
                    request.arrival_cycles,
                ))
            } else {
                0
            };
            let finish = start + effective - overlap;
            if best.is_none_or(|(f, id, _)| (finish, inst.id) < (f, id)) {
                best = Some((finish, inst.id, start));
            }
        }
        let (_, best_id, best_start) = best.expect("at least one instance");
        Some(Plan { pending_idx, instance_idx: best_id, start_cycles: best_start })
    }

    /// Model of the request the next [`Scheduler::dispatch_next`] will
    /// serve, so the caller can resolve its compiled program first.
    pub fn next_model(&self) -> Option<ModelId> {
        self.plan().map(|p| self.pending[p.pending_idx].request.model)
    }

    /// Like [`Scheduler::next_model`], but only when that dispatch would
    /// start at or before `horizon_cycles`. The event loop in
    /// `serve::run_trace` uses this to run every service event up to (and
    /// including) an arrival's timestamp before admitting the arrival —
    /// the "service precedes admission at equal times" convention of the
    /// determinism contract.
    pub fn next_model_before(&self, horizon_cycles: u64) -> Option<ModelId> {
        self.plan()
            .filter(|p| p.start_cycles <= horizon_cycles)
            .map(|p| self.pending[p.pending_idx].request.model)
    }

    /// Dispatch the planned request — plus, when batching is enabled and
    /// every other instance is busy past the start time, up to
    /// `max_batch − 1` already-arrived followers of the same model and
    /// class — onto the earliest-idle instance. `model` and `program` are
    /// the model the caller resolved via [`Scheduler::next_model`] and its
    /// compiled program; if the plan has changed since (something was
    /// admitted in between), the mismatch panics instead of silently
    /// replaying the wrong model's timing. Returns the batch's
    /// completions in batch order (empty when nothing is pending).
    pub fn dispatch_next(&mut self, model: ModelId, program: &JobProgram) -> Vec<Completion> {
        let Some(plan) = self.plan() else { return Vec::new() };
        assert_eq!(
            self.pending[plan.pending_idx].request.model, model,
            "dispatch_next model mismatch: the plan changed between next_model() and \
             dispatch_next() (never admit between the two calls)"
        );
        let head = self.pending.remove(plan.pending_idx).request;
        if head.is_decode() {
            // Decode requests run through their registered DecodeJob (the
            // passed `program` is the same prefill the job holds, resolved
            // through the shared compile-cache entry).
            return self.dispatch_decode(head, plan);
        }
        let start = plan.start_cycles;
        let idx = plan.instance_idx;

        // Batching is a backlog optimization: coalesce only when no other
        // instance is idle at the start time (a free instance would serve
        // a follower sooner than the batch's marginal tail). Decode
        // requests never ride as followers — their per-token service has
        // nothing in common with the leader's single-shot replay.
        let others_busy = self
            .instances
            .iter()
            .all(|i| i.id == idx || i.busy_until_cycles > start);
        // Stretch mode widens the coalescing condition: followers ride
        // even when another instance sits idle, because a follower's
        // marginal replay skips its parameter-fetch DMA — fewer bytes
        // moved, at the cost of serializing work the idle instance could
        // have raced (see `EnergyMode::Stretch`).
        let stretch = self.opts.energy_mode == EnergyMode::Stretch;
        let batch_cap = self.effective_max_batch();
        let mut followers: Vec<Request> = Vec::new();
        if batch_cap > 1 && (others_busy || stretch) {
            // `pending` is seq-sorted, so iteration order = admission order.
            let picked: Vec<usize> = self
                .pending
                .iter()
                .enumerate()
                .filter(|(_, q)| {
                    q.request.model == head.model
                        && q.request.priority == head.priority
                        && q.request.arrival_cycles <= start
                        && !q.request.is_decode()
                })
                .map(|(i, _)| i)
                .take(batch_cap - 1)
                .collect();
            for &i in picked.iter().rev() {
                followers.push(self.pending.remove(i).request);
            }
            followers.reverse();
        }

        let (skip_tiles, residency_hit_cycles) = self.weight_prepass(idx, model, program);
        let count_dma = |j: &Job| match j {
            Job::Dma { tile, .. } => !skip_tiles.contains(tile),
            _ => true,
        };

        let result = self.instances[idx]
            .executor
            .run_program_where(program, count_dma, None)
            .expect("sim-only dispatch cannot fail");
        let full = result.sim_cycles;

        // Intra-instance pipelining: this dispatch's head (leading
        // parameter fetches) can hide inside the predecessor's fetch-free
        // tail window, but only for the part of it the request was
        // actually queued through.
        let mut overlap = 0u64;
        let mut tail_window = 0u64;
        if self.opts.pipeline {
            let profile = program.pipeline_profile_where(count_dma);
            overlap = profile.head_cycles.min(overlap_window(
                self.instances[idx].busy_until_cycles,
                self.instances[idx].last_tail_window_cycles,
                head.arrival_cycles,
            ));
            tail_window = profile.tail_window_cycles;
        }
        self.overlap_cycles_total += overlap;

        // Energy: price the leader under the same DMA filter the executor
        // just timed with; each follower is priced as its marginal replay
        // (every parameter-tile fetch skipped — the exact filter of
        // [`marginal_service_cycles`]). `None` (energy off) prices
        // everything at zero, bit for bit.
        let leader_energy = match &self.energy_model {
            Some(m) => m.price_program_where(program, count_dma),
            None => TickEnergy::ZERO,
        };
        let follower_energy = match &self.energy_model {
            Some(m) if !followers.is_empty() => {
                let param_tiles = program.param_tiles();
                m.price_program_where(program, |job| match job {
                    Job::Dma { tile, .. } => !param_tiles.contains(tile),
                    _ => true,
                })
            }
            _ => TickEnergy::ZERO,
        };
        self.energy_spent_fj = self.energy_spent_fj.saturating_add(
            leader_energy
                .total_fj()
                .saturating_add(follower_energy.total_fj() * followers.len() as u64),
        );

        let mut finish = start + full - overlap;
        let mut completions = Vec::with_capacity(1 + followers.len());
        completions.push(Completion {
            id: head.id,
            model: head.model,
            priority: head.priority,
            instance: idx,
            batch_index: 0,
            arrival_cycles: head.arrival_cycles,
            start_cycles: start,
            finish_cycles: finish,
            overlap_cycles: overlap,
            residency_hit_cycles,
            first_token_cycles: finish,
            tokens: 1,
            kv_refetch_cycles: 0,
            energy_compute_fj: leader_energy.compute_fj(),
            energy_dma_fj: leader_energy.dma_fj(),
            energy_idle_fj: leader_energy.idle_fj(),
        });
        if !followers.is_empty() {
            // Followers replay the resident program: parameter fetches are
            // skipped, and a floor of one cycle keeps service times
            // positive for degenerate programs.
            let marginal = marginal_service_cycles(program).max(1);
            for (j, r) in followers.iter().enumerate() {
                finish += marginal;
                completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    priority: r.priority,
                    instance: idx,
                    batch_index: (j + 1) as u32,
                    arrival_cycles: r.arrival_cycles,
                    start_cycles: start,
                    finish_cycles: finish,
                    overlap_cycles: 0,
                    residency_hit_cycles: 0,
                    first_token_cycles: finish,
                    tokens: 1,
                    kv_refetch_cycles: 0,
                    energy_compute_fj: follower_energy.compute_fj(),
                    energy_dma_fj: follower_energy.dma_fj(),
                    energy_idle_fj: follower_energy.idle_fj(),
                });
            }
        }
        if self.opts.warm_routing {
            self.skeletons.entry(model).or_insert_with(|| program.clone());
        }
        let instance = &mut self.instances[idx];
        // Batches end in follower replays whose fetch-free tail is not
        // the leader program's, so only a solo dispatch leaves a window.
        instance.last_tail_window_cycles =
            if self.opts.pipeline && followers.is_empty() { tail_window } else { 0 };
        instance.busy_until_cycles = finish;
        // Overlapped head cycles live inside the predecessor's occupied
        // interval, so `finish - start` counts every busy cycle exactly
        // once and utilization stays ≤ 1.
        instance.occupied_cycles += finish - start;
        instance.served += completions.len() as u64;
        self.tokens_generated += completions.len() as u64;
        completions
    }

    /// Weight-residency pre-pass for one dispatch: touch every parameter
    /// tile of `program` in instance `idx`'s TCM residency. Hits elide
    /// the tile's DMA jobs from the run (same rule batching uses for
    /// followers); misses install the tile, bank-rounded, evicting
    /// lowest-value tiles — weight or KV — as needed. Returns the tiles
    /// the run skips and the datamover cycles those hits save; a no-op
    /// `(∅, 0)` with residency off.
    fn weight_prepass(
        &mut self,
        idx: usize,
        model: ModelId,
        program: &JobProgram,
    ) -> (HashSet<TileId>, u64) {
        let mut skip_tiles: HashSet<TileId> = HashSet::new();
        let mut hit_cycles = 0u64;
        if !self.opts.weight_residency {
            return (skip_tiles, hit_cycles);
        }
        let owner = model_owner(model);
        let stats = param_tile_stats(program);
        let mut kv_victims = 0u64;
        let instance = &mut self.instances[idx];
        let bank_bytes = instance.executor.config().bank_bytes() as u64;
        let residency = instance
            .residency
            .as_mut()
            .expect("weight_residency instances carry residency state");
        let mut misses_here = 0usize;
        for &(tile, bytes, cycles) in &stats {
            if residency.touch(owner, tile.0) {
                skip_tiles.insert(tile);
                hit_cycles += cycles;
            } else {
                misses_here += 1;
                let rounded = bytes.div_ceil(bank_bytes).max(1) * bank_bytes;
                if let Some(victims) = residency.install_evicting(owner, tile.0, rounded, cycles)
                {
                    kv_victims +=
                        victims.iter().filter(|v| v.owner >= KV_OWNER_BASE).count() as u64;
                }
            }
        }
        if !stats.is_empty() && misses_here == 0 {
            self.warm_dispatches += 1;
        }
        self.kv_evictions += kv_victims;
        (skip_tiles, hit_cycles)
    }

    /// The registered decode artifact of `model`; panics when the caller
    /// dispatched a decode request without registering one first.
    fn decode_job(&self, model: ModelId) -> std::sync::Arc<crate::coordinator::DecodeJob> {
        std::sync::Arc::clone(self.decode_jobs.get(&model).unwrap_or_else(|| {
            panic!(
                "no decode job registered for model {model:?} \
                 (call Scheduler::register_decode_job before dispatching decode requests)"
            )
        }))
    }

    /// Free a finished (or abandoned) sequence's KV-cache bytes. Frees
    /// are not evictions: the sequence is done with its cache.
    fn release_kv(&mut self, idx: usize, request_id: u64) {
        if let Some(residency) = self.instances[idx].residency.as_mut() {
            residency.release_owner(kv_owner(request_id));
        }
    }

    /// Price one decode step of `request` over `bucket` on instance
    /// `idx`. KV residency decides whether the step's KV-cache streaming
    /// is paid or elided; `pay_params` whether its parameter fetches are
    /// paid (the first sequence of a model per continuous round pays,
    /// same-model followers elide — request-boundary scheduling always
    /// pays). Returns `(step cycles, elided KV cycles, refetched KV
    /// cycles, step energy)` — the energy priced under exactly the DMA
    /// filter the step was timed with ([`TickEnergy::ZERO`] with energy
    /// accounting off) and already added to the fleet spend meter.
    fn decode_step_cost(
        &mut self,
        idx: usize,
        request: &Request,
        bucket: &crate::coordinator::DecodeBucket,
        pay_params: bool,
        kv_installed: &mut bool,
    ) -> (u64, u64, u64, TickEnergy) {
        let mut pay_kv = true;
        let mut hit_cycles = 0u64;
        let mut refetch_cycles = 0u64;
        let mut kv_victims = 0u64;
        if self.opts.weight_residency {
            let owner = kv_owner(request.id);
            let instance = &mut self.instances[idx];
            let bank_bytes = instance.executor.config().bank_bytes() as u64;
            let residency = instance
                .residency
                .as_mut()
                .expect("weight_residency instances carry residency state");
            let needed = bucket.kv_stream_bytes().div_ceil(bank_bytes).max(1) * bank_bytes;
            let resident = residency.touch(owner, 0);
            if resident && residency.owner_bytes(owner) >= needed {
                // The whole cache (at this bucket's footprint) is in TCM:
                // the step elides its KV streaming entirely.
                pay_kv = false;
                hit_cycles = bucket.kv_fetch_cycles();
            } else {
                // Cold, evicted between steps (preemption), or grown past
                // its resident footprint: stream the cache and (re)install
                // it at the bucket's size. A miss after a successful
                // install is the preemption-refetch price.
                if !resident && *kv_installed {
                    refetch_cycles = bucket.kv_fetch_cycles();
                }
                residency.release_owner(owner);
                if let Some(victims) =
                    residency.install_evicting(owner, 0, needed, bucket.kv_fetch_cycles())
                {
                    kv_victims +=
                        victims.iter().filter(|v| v.owner >= KV_OWNER_BASE).count() as u64;
                    *kv_installed = true;
                }
            }
        }
        self.kv_evictions += kv_victims;
        let param_tiles = bucket.program.param_tiles();
        let count_dma = |j: &Job| match j {
            Job::Dma { tile, .. } => {
                if bucket.kv_tiles.contains(tile) {
                    pay_kv
                } else if param_tiles.contains(tile) {
                    pay_params
                } else {
                    true
                }
            }
            _ => true,
        };
        let cost = bucket.program.service_cycles_where(count_dma);
        let energy = match &self.energy_model {
            Some(m) => m.price_program_where(&bucket.program, count_dma),
            None => TickEnergy::ZERO,
        };
        self.energy_spent_fj = self.energy_spent_fj.saturating_add(energy.total_fj());
        (cost.max(1), hit_cycles, refetch_cycles, energy)
    }

    /// Dispatch a decode request: run its prefill as a solo dispatch
    /// (weight residency applies; pipelining, warm routing and batching
    /// do not), then either run the whole decode phase immediately
    /// (request-boundary scheduling) or join the instance's active set to
    /// advance one token per round (continuous batching, see
    /// [`Scheduler::advance_decode`]).
    fn dispatch_decode(&mut self, head: Request, plan: Plan) -> Vec<Completion> {
        let job = self.decode_job(head.model);
        let idx = plan.instance_idx;
        let start = plan.start_cycles;
        let (skip_tiles, prefill_hit_cycles) = self.weight_prepass(idx, head.model, &job.prefill);
        let count_dma = |j: &Job| match j {
            Job::Dma { tile, .. } => !skip_tiles.contains(tile),
            _ => true,
        };
        let result = self.instances[idx]
            .executor
            .run_program_where(&job.prefill, count_dma, None)
            .expect("sim-only dispatch cannot fail");
        let first_token = start + result.sim_cycles;
        // Prefill energy under the same residency-elision filter the
        // executor timed with; decode-step energy accumulates on top as
        // the steps are priced.
        let prefill_energy = match &self.energy_model {
            Some(m) => m.price_program_where(&job.prefill, count_dma),
            None => TickEnergy::ZERO,
        };
        self.energy_spent_fj = self.energy_spent_fj.saturating_add(prefill_energy.total_fj());
        let complete = |finish: u64, hits: u64, refetch: u64, energy: TickEnergy| Completion {
            id: head.id,
            model: head.model,
            priority: head.priority,
            instance: idx,
            batch_index: 0,
            arrival_cycles: head.arrival_cycles,
            start_cycles: start,
            finish_cycles: finish,
            overlap_cycles: 0,
            residency_hit_cycles: hits,
            first_token_cycles: first_token,
            tokens: head.decode_tokens,
            kv_refetch_cycles: refetch,
            energy_compute_fj: energy.compute_fj(),
            energy_dma_fj: energy.dma_fj(),
            energy_idle_fj: energy.idle_fj(),
        };
        if !self.opts.continuous_batch {
            // Request-boundary scheduling: the sequence owns the instance
            // from prefill through its last token, and every step re-pays
            // the decode-step parameter streaming.
            let mut now = first_token;
            let mut hit_cycles = prefill_hit_cycles;
            let mut kv_refetch = 0u64;
            let mut kv_installed = false;
            let mut energy = prefill_energy;
            for step in 1..head.decode_tokens {
                let kv_ctx = head.prompt_tokens.saturating_add(step - 1).clamp(1, job.max_kv());
                let bucket = job.bucket_for(kv_ctx);
                let (cost, hit, refetch, step_energy) =
                    self.decode_step_cost(idx, &head, bucket, true, &mut kv_installed);
                now += cost;
                hit_cycles += hit;
                kv_refetch += refetch;
                energy.add(&step_energy);
            }
            self.release_kv(idx, head.id);
            let instance = &mut self.instances[idx];
            instance.last_tail_window_cycles = 0;
            instance.busy_until_cycles = now;
            instance.occupied_cycles += now - start;
            instance.served += 1;
            self.tokens_generated += head.decode_tokens as u64;
            return vec![complete(now, hit_cycles, kv_refetch, energy)];
        }
        // Continuous batching: the instance is only committed through the
        // prefill; the sequence joins the active set and advances with
        // the instance's next rounds.
        {
            let instance = &mut self.instances[idx];
            instance.last_tail_window_cycles = 0;
            instance.busy_until_cycles = first_token;
            instance.occupied_cycles += first_token - start;
        }
        if head.decode_tokens == 1 {
            // Prefill-only request: the first token is the last.
            self.release_kv(idx, head.id);
            self.instances[idx].served += 1;
            self.tokens_generated += 1;
            return vec![complete(first_token, prefill_hit_cycles, 0, prefill_energy)];
        }
        self.instances[idx].active.push(ActiveSeq {
            request: head,
            tokens_done: 1,
            first_token_cycles: first_token,
            start_cycles: start,
            residency_hit_cycles: prefill_hit_cycles,
            kv_refetch_cycles: 0,
            kv_installed: false,
            energy: prefill_energy,
        });
        Vec::new()
    }

    /// Advance every active sequence on instance `idx` by one token, in
    /// join order. The first step of a model on the instance pays its
    /// decode-step parameter streaming and pins the weights
    /// ([`NpuInstance::decode_warm`]); every later step of the model —
    /// same sequence or a same-model follower — elides it until the
    /// model's last active sequence completes. That amortization across
    /// steps *and* sequences is what request-boundary scheduling (a cold
    /// bucket-program replay per step) never gets. Steps run back to
    /// back, so finishes stagger deterministically.
    fn run_one_round(&mut self, idx: usize) -> Vec<Completion> {
        let round_start = self.instances[idx].busy_until_cycles;
        let mut now = round_start;
        let mut completions = Vec::new();
        for i in 0..self.instances[idx].active.len() {
            let (request, tokens_done, mut kv_installed) = {
                let s = &self.instances[idx].active[i];
                (s.request, s.tokens_done, s.kv_installed)
            };
            let job = self.decode_job(request.model);
            let kv_ctx =
                request.prompt_tokens.saturating_add(tokens_done - 1).clamp(1, job.max_kv());
            let bucket = job.bucket_for(kv_ctx);
            let pay_params = self.instances[idx].decode_warm.insert(request.model);
            let (cost, hit, refetch, step_energy) =
                self.decode_step_cost(idx, &request, bucket, pay_params, &mut kv_installed);
            now += cost;
            let s = &mut self.instances[idx].active[i];
            s.tokens_done += 1;
            s.kv_installed = kv_installed;
            s.residency_hit_cycles += hit;
            s.kv_refetch_cycles += refetch;
            s.energy.add(&step_energy);
            if s.tokens_done == s.request.decode_tokens {
                completions.push(Completion {
                    id: request.id,
                    model: request.model,
                    priority: request.priority,
                    instance: idx,
                    batch_index: 0,
                    arrival_cycles: request.arrival_cycles,
                    start_cycles: s.start_cycles,
                    finish_cycles: now,
                    overlap_cycles: 0,
                    residency_hit_cycles: s.residency_hit_cycles,
                    first_token_cycles: s.first_token_cycles,
                    tokens: request.decode_tokens,
                    kv_refetch_cycles: s.kv_refetch_cycles,
                    energy_compute_fj: s.energy.compute_fj(),
                    energy_dma_fj: s.energy.dma_fj(),
                    energy_idle_fj: s.energy.idle_fj(),
                });
            }
        }
        for c in &completions {
            self.release_kv(idx, c.id);
        }
        let instance = &mut self.instances[idx];
        instance.active.retain(|s| s.tokens_done < s.request.decode_tokens);
        // A model's weights stay pinned only while it has active
        // sequences; afterwards its TCM space is up for grabs again.
        let still_active: HashSet<ModelId> =
            instance.active.iter().map(|s| s.request.model).collect();
        instance.decode_warm.retain(|m| still_active.contains(m));
        instance.busy_until_cycles = now;
        instance.occupied_cycles += now - round_start;
        instance.served += completions.len() as u64;
        self.tokens_generated += completions.iter().map(|c| c.tokens as u64).sum::<u64>();
        completions
    }

    /// Does any instance still hold unfinished continuously-batched
    /// decode sequences?
    pub fn has_active_decode(&self) -> bool {
        self.instances.iter().any(|i| !i.active.is_empty())
    }

    /// Start time of the earliest pending decode round: the smallest
    /// `busy_until` among instances with active sequences.
    pub fn next_decode_round_start(&self) -> Option<u64> {
        self.instances
            .iter()
            .filter(|i| !i.active.is_empty())
            .map(|i| i.busy_until_cycles)
            .min()
    }

    /// Start time of the next planned dispatch, if any (the event loop
    /// orders decode rounds against dispatches with this).
    pub fn next_start_cycles(&self) -> Option<u64> {
        self.plan().map(|p| p.start_cycles)
    }

    /// Run the earliest due decode round — the instance with active
    /// sequences and the smallest `(busy_until, id)` — when it starts at
    /// or before `horizon_cycles`. `None` when no round is due; `Some`
    /// with the round's completions (possibly empty) otherwise.
    pub fn advance_decode(&mut self, horizon_cycles: u64) -> Option<Vec<Completion>> {
        let idx = self
            .instances
            .iter()
            .filter(|i| !i.active.is_empty() && i.busy_until_cycles <= horizon_cycles)
            .min_by_key(|i| (i.busy_until_cycles, i.id))
            .map(|i| i.id)?;
        Some(self.run_one_round(idx))
    }

    /// Run decode rounds to exhaustion (end-of-trace drain).
    pub fn drain_decode(&mut self) -> Vec<Completion> {
        let mut completions = Vec::new();
        while let Some(mut batch) = self.advance_decode(u64::MAX) {
            completions.append(&mut batch);
        }
        completions
    }

    /// Total cycles of dispatch head fetches hidden inside predecessors'
    /// tail windows by intra-instance pipelining.
    pub fn overlap_cycles(&self) -> u64 {
        self.overlap_cycles_total
    }

    /// Dispatches whose parameter tiles were all already TCM-resident
    /// (warm dispatches skip every parameter fetch).
    pub fn warm_dispatches(&self) -> u64 {
        self.warm_dispatches
    }

    /// Parameter-tile residency hits across all instances.
    pub fn residency_hits(&self) -> u64 {
        self.instances
            .iter()
            .filter_map(|i| i.residency.as_ref())
            .map(|r| r.hits())
            .sum()
    }

    /// Parameter-tile residency misses across all instances.
    pub fn residency_misses(&self) -> u64 {
        self.instances
            .iter()
            .filter_map(|i| i.residency.as_ref())
            .map(|r| r.misses())
            .sum()
    }

    /// Residency evictions across all instances.
    pub fn residency_evictions(&self) -> u64 {
        self.instances
            .iter()
            .filter_map(|i| i.residency.as_ref())
            .map(|r| r.evictions())
            .sum()
    }

    /// KV-cache residency entries evicted from TCM by competing installs
    /// (weights or other sequences' caches) — each forces the victim
    /// sequence to re-stream its context (preemption refetch).
    pub fn kv_evictions(&self) -> u64 {
        self.kv_evictions
    }

    /// Tokens generated across all completions: `decode_tokens` per
    /// decode request, 1 per single-shot inference.
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated
    }

    /// Total femtojoules metered across all dispatches so far (the
    /// accumulator [`SchedulerOptions::energy_budget_fj`] is enforced
    /// against); 0 with energy accounting off.
    pub fn energy_spent_fj(&self) -> u64 {
        self.energy_spent_fj
    }

    /// Clock cycle when the last instance goes idle (0 if nothing ran).
    pub fn makespan_cycles(&self) -> u64 {
        self.instances
            .iter()
            .map(|i| i.busy_until_cycles)
            .max()
            .unwrap_or(0)
    }

    /// The virtual NPU instances, indexed by id.
    pub fn instances(&self) -> &[NpuInstance] {
        &self.instances
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Format, TransferKind};
    use crate::coordinator::Job;
    use crate::ir::OpId;

    fn toy_program(cycles: u64) -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: Vec::new(),
                    param_tile: None,
                    format: Format::Depth,
                    cycles,
                },
                Job::Barrier,
            ],
            model: "toy".to_string(),
        }
    }

    /// Two-tick program with a 600-cycle parameter prologue fetch, a
    /// 1000-cycle compute and a 300-cycle activation fetch:
    /// full = 600 + max(1000, 300) = 1600, marginal = max(1000, 300) = 1000.
    fn weighted_program() -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Dma {
                    tile: TileId(9),
                    kind: TransferKind::Fetch,
                    bytes: 4_096,
                    cycles: 600,
                },
                Job::Barrier,
                Job::Dma {
                    tile: TileId(1),
                    kind: TransferKind::Fetch,
                    bytes: 1_024,
                    cycles: 300,
                },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![TileId(1)],
                    param_tile: Some(TileId(9)),
                    format: Format::Depth,
                    cycles: 1_000,
                },
                Job::Barrier,
            ],
            model: "weighted".to_string(),
        }
    }

    fn request(id: u64, priority: Priority, arrival: u64) -> Request {
        Request::inference(id, ModelId::MobileNetV1, priority, arrival)
    }

    fn fifo_opts(instances: usize) -> SchedulerOptions {
        SchedulerOptions { instances, ..SchedulerOptions::default() }
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let models = [ModelId::MobileNetV1, ModelId::MobileNetV2];
        let a = synthetic_trace(&models, 50, 1_000, 42);
        let b = synthetic_trace(&models, 50, 1_000, 42);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_cycles <= w[1].arrival_cycles));
        assert!(a.windows(2).all(|w| w[0].id + 1 == w[1].id));
        assert!(a.iter().all(|r| r.priority == Priority::Standard));
        let c = synthetic_trace(&models, 50, 1_000, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn mixed_trace_draws_all_classes() {
        let models = [ModelId::MobileNetV1];
        let mix = PriorityMix::default();
        let t = synthetic_trace_with_mix(&models, 200, 1_000, 5, &mix);
        for p in Priority::all() {
            assert!(
                t.iter().any(|r| r.priority == p),
                "class {p:?} missing from a 200-request default-mix trace"
            );
        }
        // Degenerate weights pin the class.
        let rt = PriorityMix { realtime: 1, standard: 0, batch: 0 };
        let t = synthetic_trace_with_mix(&models, 50, 1_000, 5, &rt);
        assert!(t.iter().all(|r| r.priority == Priority::Realtime));
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_MEAN_GAP_CYCLES")]
    fn oversized_mean_gap_is_rejected_loudly() {
        synthetic_trace(&[ModelId::MobileNetV1], 1, MAX_MEAN_GAP_CYCLES + 1, 0);
    }

    #[test]
    fn fifo_earliest_idle_dispatch() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(2));
        let p = toy_program(1_000);
        for id in 0..4 {
            assert_eq!(s.admit(request(id, Priority::Standard, 0)), Admission::Accepted);
        }
        assert_eq!(s.queue_len(), 4);
        let mut done = Vec::new();
        while s.next_model().is_some() {
            done.extend(s.dispatch_next(ModelId::MobileNetV1, &p));
        }
        // 4 × 1000-cycle requests over 2 instances: two waves.
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].instance, 0, "tie breaks toward the lowest id");
        assert_eq!(done[1].instance, 1);
        assert_eq!(done[0].finish_cycles, 1_000);
        assert_eq!(done[2].start_cycles, 1_000);
        assert_eq!(s.makespan_cycles(), 2_000);
        assert_eq!(done.iter().map(|c| c.latency_cycles()).max().unwrap(), 2_000);
        assert_eq!(s.instances()[0].served() + s.instances()[1].served(), 4);
        assert_eq!(s.instances()[0].metrics().requests, 2);
        assert_eq!(s.instances()[0].busy_cycles(), 2_000);
        assert!(s.shed().is_empty());
    }

    #[test]
    fn latency_is_queue_plus_service() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(500);
        s.admit(request(0, Priority::Standard, 100));
        s.admit(request(1, Priority::Standard, 150));
        let a = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        let b = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        // The idle instance waits for the arrival; nothing starts early.
        assert_eq!(a.start_cycles, 100);
        assert_eq!(a.finish_cycles, 600);
        assert_eq!(a.queue_cycles(), 0);
        assert_eq!(b.start_cycles, 600);
        assert_eq!(b.queue_cycles(), 450);
        assert_eq!(b.latency_cycles(), b.queue_cycles() + b.service_cycles());
        assert_eq!(s.makespan_cycles(), 1_100);
    }

    #[test]
    fn empty_scheduler_reports_zero_makespan() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(3));
        assert_eq!(s.makespan_cycles(), 0);
        assert!(s.next_model().is_none());
        assert!(s.next_model_before(u64::MAX).is_none());
        assert!(s.dispatch_next(ModelId::MobileNetV1, &toy_program(1)).is_empty());
    }

    #[test]
    fn classes_dispatch_in_rank_then_admission_order() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(100);
        s.admit(request(0, Priority::Batch, 0));
        s.admit(request(1, Priority::Realtime, 0));
        s.admit(request(2, Priority::Standard, 0));
        s.admit(request(3, Priority::Realtime, 0));
        let mut order = Vec::new();
        while s.next_model().is_some() {
            order.extend(s.dispatch_next(ModelId::MobileNetV1, &p).iter().map(|c| c.id));
        }
        assert_eq!(order, vec![1, 3, 2, 0], "class rank first, admission order within class");
    }

    #[test]
    fn scheduler_cannot_dispatch_requests_before_they_arrive() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        let p = toy_program(100);
        // A Realtime request that arrives at t=500 must not outrank a
        // Standard request already waiting at t=0: at the decision time
        // (t=0, instance idle) only the Standard request has arrived.
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Realtime, 500));
        let a = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        assert_eq!(a.id, 0);
        assert_eq!(a.start_cycles, 0);
        let b = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        assert_eq!(b.id, 1);
        assert_eq!(b.start_cycles, 500, "idle instance waits for the arrival");
    }

    #[test]
    fn aging_promotes_starved_batch_work() {
        let cfg = NeutronConfig::flagship_2tops();
        let p = toy_program(1_000);
        let run = |age: Option<u64>| {
            let opts = SchedulerOptions {
                instances: 1,
                age_after_cycles: age,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            // Occupy the instance until t=1000, with a Batch request queued
            // from t=0 and a Realtime request arriving just before the
            // instance frees up.
            s.admit(request(0, Priority::Standard, 0));
            s.dispatch_next(ModelId::MobileNetV1, &p);
            s.admit(request(1, Priority::Batch, 0));
            s.admit(request(2, Priority::Realtime, 999));
            s.dispatch_next(ModelId::MobileNetV1, &p)[0].id
        };
        // Strict classes: Realtime jumps the 1000-cycle-old Batch request.
        assert_eq!(run(None), 2);
        // Aging 100 cycles/class: by t=1000 the Batch request has been
        // promoted to effective Realtime and its earlier admission wins.
        assert_eq!(run(Some(100)), 1);
    }

    #[test]
    fn bounded_queue_reject_newest_sheds_the_arrival() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            queue_capacity: Some(2),
            policy: AdmissionPolicy::RejectNewest,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        assert_eq!(s.admit(request(0, Priority::Standard, 0)), Admission::Accepted);
        assert_eq!(s.admit(request(1, Priority::Standard, 0)), Admission::Accepted);
        let r2 = request(2, Priority::Standard, 10);
        assert_eq!(s.admit(r2), Admission::Shed(r2));
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.shed(), &[r2]);
        // The backlog is preserved: ids 0 and 1 still dispatch.
        let p = toy_program(100);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 0);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 1);
    }

    #[test]
    fn bounded_queue_drop_oldest_sheds_the_head() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            queue_capacity: Some(2),
            policy: AdmissionPolicy::DropOldest,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let r0 = request(0, Priority::Standard, 0);
        s.admit(r0);
        s.admit(request(1, Priority::Standard, 0));
        assert_eq!(s.admit(request(2, Priority::Standard, 10)), Admission::Shed(r0));
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.shed(), &[r0]);
        let p = toy_program(100);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 1);
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p)[0].id, 2);
    }

    #[test]
    fn marginal_cycles_skip_parameter_fetches_only() {
        assert_eq!(marginal_service_cycles(&toy_program(700)), 700);
        let p = weighted_program();
        assert_eq!(marginal_service_cycles(&p), 1_000);
        // Sanity: the executor's full service time is 600 + 1000.
        let cfg = NeutronConfig::flagship_2tops();
        let mut ex = Executor::with_config(cfg);
        let full = ex.run_program(&p, None).unwrap().sim_cycles;
        assert_eq!(full, 1_600);
    }

    #[test]
    fn batching_coalesces_same_model_requests_under_backlog() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 3,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        for id in 0..4 {
            s.admit(request(id, Priority::Standard, 0));
        }
        // First dispatch: a full batch of 3 (leader 1600, followers +1000).
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 3);
        assert_eq!(
            batch.iter().map(|c| (c.id, c.batch_index, c.finish_cycles)).collect::<Vec<_>>(),
            vec![(0, 0, 1_600), (1, 1, 2_600), (2, 2, 3_600)]
        );
        assert!(batch.iter().all(|c| c.start_cycles == 0));
        assert!(!batch[0].batched() && batch[1].batched());
        // Second dispatch: the leftover request rides solo.
        let solo = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(solo.len(), 1);
        assert_eq!((solo[0].id, solo[0].start_cycles, solo[0].finish_cycles), (3, 3_600, 5_200));
        // Batched makespan 5200 beats 4 solo services (4 × 1600 = 6400).
        assert_eq!(s.makespan_cycles(), 5_200);
        assert_eq!(s.instances()[0].served(), 4);
        assert_eq!(s.instances()[0].busy_cycles(), 5_200);
        // The executor ran once per batch, not once per request.
        assert_eq!(s.instances()[0].metrics().requests, 2);
    }

    #[test]
    fn batching_defers_to_an_idle_instance() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 2,
            max_batch: 4,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Standard, 0));
        // Instance 1 is idle at t=0, so the first dispatch must not absorb
        // request 1 as a follower — it runs in parallel instead.
        let first = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(first.len(), 1);
        let second = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].instance, 1);
        assert_eq!(s.makespan_cycles(), 1_600);
    }

    #[test]
    fn priority_parse_round_trips() {
        for p in Priority::all() {
            assert_eq!(Priority::parse(p.display_name()), Some(p));
        }
        assert_eq!(Priority::parse("REALTIME"), Some(Priority::Realtime));
        assert_eq!(Priority::parse("nope"), None);
    }

    #[test]
    fn dynamic_batch_scales_ceiling_with_backlog() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 4,
            dynamic_batch: true,
            ..SchedulerOptions::default()
        };
        let p = weighted_program();

        // Shallow backlog (2 queued): ceiling = ceil(2/1) = 2 < max_batch,
        // so only one follower coalesces even though 4 would fit.
        let mut s = Scheduler::new(&cfg, &opts);
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Standard, 0));
        assert_eq!(s.dispatch_next(ModelId::MobileNetV1, &p).len(), 2);

        // Deep backlog (8 queued): ceiling = min(8, max_batch) = 4.
        let mut s = Scheduler::new(&cfg, &opts);
        for id in 0..8 {
            s.admit(request(id, Priority::Standard, 0));
        }
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 4, "deep backlog reaches the static ceiling");
        assert_eq!(s.queue_len(), 4);

        // Static batching at the same depth behaves identically at the
        // ceiling (dynamic sizing never exceeds max_batch).
        let static_opts = SchedulerOptions { dynamic_batch: false, ..opts.clone() };
        let mut s2 = Scheduler::new(&cfg, &static_opts);
        for id in 0..8 {
            s2.admit(request(id, Priority::Standard, 0));
        }
        assert_eq!(s2.dispatch_next(ModelId::MobileNetV1, &p).len(), 4);
    }

    #[test]
    fn dynamic_batch_divides_backlog_across_instances() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 2,
            max_batch: 8,
            dynamic_batch: true,
            ..SchedulerOptions::default()
        };
        let p = weighted_program();
        let mut s = Scheduler::new(&cfg, &opts);
        // Occupy both instances with staggered finish times so the next
        // dispatch (on the earlier-idle instance) still sees the other one
        // busy — the condition batching is gated on.
        s.admit(request(100, Priority::Standard, 0));
        s.admit(request(101, Priority::Standard, 0));
        s.dispatch_next(ModelId::MobileNetV1, &toy_program(5_000));
        s.dispatch_next(ModelId::MobileNetV1, &toy_program(2_000));
        for id in 0..6 {
            s.admit(request(id, Priority::Standard, 0));
        }
        // Backlog 6 over 2 instances → ceiling ceil(6/2) = 3.
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        assert_eq!(batch.len(), 3, "backlog is split across the fleet, not hoarded");
        assert_eq!(batch[0].instance, 1, "earliest-idle instance serves the batch");
    }

    #[test]
    fn batching_respects_class_and_model_boundaries() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 8,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        s.admit(request(0, Priority::Standard, 0));
        s.admit(Request::inference(1, ModelId::MobileNetV2, Priority::Standard, 0));
        s.admit(request(2, Priority::Batch, 0));
        s.admit(request(3, Priority::Standard, 0));
        let batch = s.dispatch_next(ModelId::MobileNetV1, &p);
        // Only id 3 matches the leader's (model, class); the other-model
        // and other-class requests stay queued.
        assert_eq!(batch.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn overlap_window_is_bounded_by_tail_and_wait() {
        // Arrived after the predecessor finished: nothing to hide behind.
        assert_eq!(overlap_window(100, 50, 120), 0);
        assert_eq!(overlap_window(100, 50, 100), 0);
        // Arrived 20 cycles before the finish: only 20 cycles of the
        // 50-cycle tail were spent queued.
        assert_eq!(overlap_window(100, 50, 80), 20);
        // Queued through the whole tail: the full window.
        assert_eq!(overlap_window(100, 50, 0), 50);
    }

    /// Three-tick program shaped for pipelining: a 600-cycle fetch-only
    /// head, a 1000-cycle compute tick, and a 50-cycle writeback-only
    /// tail (no inbound fetch after the compute tick).
    /// full = 600 + max(1000, 300) + 50 = 1650, head = 600, tail = 50.
    fn pipelined_program() -> JobProgram {
        JobProgram {
            jobs: vec![
                Job::Dma {
                    tile: TileId(9),
                    kind: TransferKind::Fetch,
                    bytes: 4_096,
                    cycles: 600,
                },
                Job::Barrier,
                Job::Dma {
                    tile: TileId(1),
                    kind: TransferKind::Fetch,
                    bytes: 1_024,
                    cycles: 300,
                },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![TileId(1)],
                    param_tile: Some(TileId(9)),
                    format: Format::Depth,
                    cycles: 1_000,
                },
                Job::Barrier,
                Job::Dma {
                    tile: TileId(0),
                    kind: TransferKind::Push,
                    bytes: 512,
                    cycles: 50,
                },
                Job::Barrier,
            ],
            model: "pipelined".to_string(),
        }
    }

    #[test]
    fn pipelining_overlaps_successor_head_with_fetch_free_tail() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions { instances: 1, pipeline: true, ..SchedulerOptions::default() };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = pipelined_program();
        s.admit(request(0, Priority::Standard, 0));
        s.admit(request(1, Priority::Standard, 0));
        let a = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        let b = s.dispatch_next(ModelId::MobileNetV1, &p)[0];
        // The first dispatch has no predecessor: no window, full service.
        assert_eq!(a.overlap_cycles, 0);
        assert_eq!(a.finish_cycles, 1_650);
        // The second was queued through the predecessor's entire 50-cycle
        // writeback tail, so 50 of its 600 head-fetch cycles hide there.
        assert_eq!(b.start_cycles, 1_650);
        assert_eq!(b.overlap_cycles, 50);
        assert_eq!(b.finish_cycles, 1_650 + 1_650 - 50);
        assert_eq!(s.overlap_cycles(), 50);
        // Overlapped cycles are counted once: occupancy equals makespan.
        assert_eq!(s.makespan_cycles(), 3_250);
        assert_eq!(s.instances()[0].busy_cycles(), 3_250);
    }

    #[test]
    fn residency_warms_repeat_dispatches_of_one_model() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            weight_residency: true,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        let p = weighted_program();
        for id in 0..3 {
            s.admit(request(id, Priority::Standard, 0));
        }
        let mut done = Vec::new();
        while s.next_model().is_some() {
            done.extend(s.dispatch_next(ModelId::MobileNetV1, &p));
        }
        // Cold leader pays the full 1600; the parameter tile then stays
        // resident, so every repeat runs at the 1000-cycle marginal cost.
        assert_eq!(done[0].finish_cycles, 1_600);
        assert_eq!(done[1].finish_cycles, 2_600);
        assert_eq!(done[2].finish_cycles, 3_600);
        assert_eq!(
            done.iter().map(|c| c.residency_hit_cycles).collect::<Vec<_>>(),
            vec![0, 600, 600]
        );
        assert_eq!(s.residency_hits(), 2);
        assert_eq!(s.residency_misses(), 1);
        assert_eq!(s.residency_evictions(), 0);
        assert_eq!(s.warm_dispatches(), 2);
        // The 4096-byte tile is charged bank-rounded against TCM capacity.
        let res = s.instances()[0].residency().expect("residency enabled");
        assert_eq!(res.len(), 1);
        assert_eq!(res.resident_bytes(), cfg.bank_bytes() as u64);
    }

    #[test]
    fn warm_routing_prefers_busy_warm_instance_over_idle_cold_one() {
        let cfg = NeutronConfig::flagship_2tops();
        let route = |warm_routing: bool| {
            let opts = SchedulerOptions {
                instances: 2,
                weight_residency: true,
                warm_routing,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            let p = weighted_program();
            s.admit(request(0, Priority::Standard, 0));
            s.dispatch_next(ModelId::MobileNetV1, &p);
            // Instance 0 is busy until 1600 and holds the model's
            // parameter tile; instance 1 is idle but cold.
            s.admit(request(1, Priority::Standard, 2_000));
            s.dispatch_next(ModelId::MobileNetV1, &p)[0]
        };
        // Earliest-idle routing picks the cold idle instance: 2000 + 1600.
        let cold = route(false);
        assert_eq!(cold.instance, 1);
        assert_eq!(cold.finish_cycles, 3_600);
        // Warm routing prices both and picks the warm one: 2000 + 1000.
        let warm = route(true);
        assert_eq!(warm.instance, 0);
        assert_eq!(warm.finish_cycles, 3_000);
        assert_eq!(warm.residency_hit_cycles, 600);
    }

    #[test]
    fn residency_eviction_under_pressure_is_deterministic() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = || {
            let opts = SchedulerOptions {
                instances: 1,
                weight_residency: true,
                // One bank: the two models' parameter tiles cannot
                // coexist, so every dispatch evicts the other's.
                residency_capacity_bytes: Some(cfg.bank_bytes() as u64),
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            let p = weighted_program();
            for id in 0..4 {
                let model = if id % 2 == 0 { ModelId::MobileNetV1 } else { ModelId::MobileNetV2 };
                s.admit(Request::inference(id, model, Priority::Standard, 0));
            }
            while let Some(model) = s.next_model() {
                s.dispatch_next(model, &p);
            }
            let res = s.instances()[0].residency().unwrap().entries().to_vec();
            (s.residency_hits(), s.residency_misses(), s.residency_evictions(), res)
        };
        let (hits, misses, evictions, entries) = run();
        // Alternating owners thrash the single bank: no hits, an eviction
        // per reinstall after the first.
        assert_eq!(hits, 0);
        assert_eq!(misses, 4);
        assert_eq!(evictions, 3);
        assert_eq!(entries.len(), 1);
        assert_eq!(run(), (hits, misses, evictions, entries));
    }

    /// Toy decode bucket: a 600-cycle parameter prologue tick, then a
    /// compute tick where a 500-cycle step races `100·kv` cycles of KV
    /// streaming. Full step = `600 + max(500, 100·kv)`; params elided =
    /// `max(500, 100·kv)`; KV elided = `600 + 500`.
    fn decode_bucket(kv_len: u32) -> crate::coordinator::DecodeBucket {
        let kv_cycles = 100 * kv_len as u64;
        let program = JobProgram {
            jobs: vec![
                Job::Dma {
                    tile: TileId(9),
                    kind: TransferKind::Fetch,
                    bytes: 4_096,
                    cycles: 600,
                },
                Job::Barrier,
                Job::Dma {
                    tile: TileId(7),
                    kind: TransferKind::Fetch,
                    bytes: 64 * kv_len as u64,
                    cycles: kv_cycles,
                },
                Job::Compute {
                    op: OpId(0),
                    out_tile: TileId(0),
                    in_tiles: vec![TileId(7)],
                    param_tile: Some(TileId(9)),
                    format: Format::Depth,
                    cycles: 500,
                },
                Job::Barrier,
            ],
            model: "toy-decode".to_string(),
        };
        crate::coordinator::DecodeBucket {
            kv_len,
            program,
            kv_tiles: [TileId(7)].into_iter().collect(),
            predicted_cycles: 600 + 500u64.max(kv_cycles),
        }
    }

    /// Prefill = [`weighted_program`] (1600 cycles cold), buckets at KV
    /// 4 / 8 / 16.
    fn toy_decode_job() -> std::sync::Arc<crate::coordinator::DecodeJob> {
        std::sync::Arc::new(crate::coordinator::DecodeJob::new(
            "toy-decode".to_string(),
            weighted_program(),
            vec![decode_bucket(4), decode_bucket(8), decode_bucket(16)],
        ))
    }

    fn decode_request(id: u64, arrival: u64, prompt: u32, tokens: u32) -> Request {
        Request::decode(id, ModelId::MobileNetV1, Priority::Standard, arrival, prompt, tokens)
    }

    #[test]
    fn request_boundary_decode_prices_prefill_and_bucketed_steps() {
        let cfg = NeutronConfig::flagship_2tops();
        let mut s = Scheduler::new(&cfg, &fifo_opts(1));
        s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
        s.admit(decode_request(0, 0, 4, 3));
        assert_eq!(s.next_model(), Some(ModelId::MobileNetV1));
        let done = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        assert_eq!(done.len(), 1);
        let c = done[0];
        // Prefill 1600, then step 1 over kv=4 (bucket 4: 600+500) and
        // step 2 over kv=5 (bucket 8: 600+800) — every step pays params.
        assert_eq!(c.first_token_cycles, 1_600);
        assert_eq!(c.finish_cycles, 1_600 + 1_100 + 1_400);
        assert_eq!(c.tokens, 3);
        assert_eq!(c.ttft_cycles(), 1_600);
        assert_eq!(c.decode_phase_cycles(), 2_500);
        assert_eq!(c.tpot_cycles(), Some(1_250.0));
        // The TTFT/TPOT decomposition reconciles exactly with latency.
        assert_eq!(
            c.ttft_cycles() + (c.tpot_cycles().unwrap() * (c.tokens - 1) as f64) as u64,
            c.latency_cycles()
        );
        assert_eq!(s.makespan_cycles(), 4_100);
        assert_eq!(s.tokens_generated(), 3);
        assert!(!s.has_active_decode());
        assert_eq!(s.instances()[0].busy_cycles(), 4_100);
    }

    #[test]
    fn continuous_batching_amortizes_decode_weights_across_steps() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = |continuous: bool| {
            let opts = SchedulerOptions {
                instances: 1,
                continuous_batch: continuous,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
            s.admit(decode_request(0, 0, 4, 3));
            let mut done = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
            done.extend(s.drain_decode());
            (done, s.makespan_cycles())
        };
        let (rb, rb_makespan) = run(false);
        let (cont, cont_makespan) = run(true);
        assert_eq!(rb_makespan, 4_100);
        // Continuous: the first step pays the decode weights (1100) and
        // pins them; the second elides them (800 at bucket 8).
        assert_eq!(cont[0].first_token_cycles, 1_600);
        assert_eq!(cont_makespan, 1_600 + 1_100 + 800);
        assert!(cont_makespan < rb_makespan);
        // Same TTFT, strictly better TPOT.
        assert_eq!(cont[0].ttft_cycles(), rb[0].ttft_cycles());
        assert!(cont[0].tpot_cycles().unwrap() < rb[0].tpot_cycles().unwrap());
    }

    #[test]
    fn continuous_batching_shares_weights_across_sequences() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = |continuous: bool| {
            let opts = SchedulerOptions {
                instances: 1,
                continuous_batch: continuous,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
            s.admit(decode_request(0, 0, 4, 3));
            s.admit(decode_request(1, 0, 4, 3));
            let mut done = Vec::new();
            while let Some(model) = s.next_model() {
                done.extend(s.dispatch_next(model, &weighted_program()));
            }
            done.extend(s.drain_decode());
            (done, s.makespan_cycles())
        };
        let (_, rb_makespan) = run(false);
        let (cont, cont_makespan) = run(true);
        // Request-boundary serializes the two sequences: 2 × 4100.
        assert_eq!(rb_makespan, 8_200);
        // Continuous: prefills at 0–1600 and 1600–3200, then round 1
        // (leader pays 1100, follower elides to 500) and round 2 (both
        // elide: 800 + 800).
        assert_eq!(cont_makespan, 3_200 + 1_100 + 500 + 800 + 800);
        assert!(cont_makespan < rb_makespan);
        assert_eq!(cont.len(), 2);
        assert_eq!(cont[0].id, 0);
        assert_eq!(cont[0].finish_cycles, 3_200 + 1_100 + 500 + 800);
        assert_eq!(cont[1].finish_cycles, cont_makespan);
        // Sequence 1's first token came from its own prefill, not a round.
        assert_eq!(cont[1].first_token_cycles, 3_200);
        assert_eq!(s_tokens(&cont), 6);
    }

    fn s_tokens(completions: &[Completion]) -> u64 {
        completions.iter().map(|c| c.tokens as u64).sum()
    }

    #[test]
    fn kv_residency_elides_repeat_kv_streaming() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            weight_residency: true,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
        s.admit(decode_request(0, 0, 4, 4));
        let done = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        let c = done[0];
        // Step 1 (kv=4) streams and installs the cache; steps 2 and 3
        // (kv=5, 6 → bucket 8, same 1-bank footprint) hit and elide their
        // 800-cycle KV fetches, running at 600 + 500 instead of 600 + 800.
        assert_eq!(c.finish_cycles, 1_600 + 1_100 + 1_100 + 1_100);
        assert_eq!(c.residency_hit_cycles, 1_600);
        assert_eq!(c.kv_refetch_cycles, 0);
        assert_eq!(s.kv_evictions(), 0);
        // The sequence released its cache at completion; only the prefill
        // weight tile remains resident.
        let res = s.instances()[0].residency().unwrap();
        assert_eq!(res.len(), 1);
        assert!(res.is_resident(model_owner(ModelId::MobileNetV1), 9));
    }

    #[test]
    fn kv_preemption_under_capacity_pressure_is_paid_and_counted() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = || {
            let opts = SchedulerOptions {
                instances: 1,
                weight_residency: true,
                continuous_batch: true,
                // One bank: the two sequences' KV caches (and the weight
                // tile) cannot coexist, so every step evicts something.
                residency_capacity_bytes: Some(cfg.bank_bytes() as u64),
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
            s.admit(decode_request(0, 0, 4, 3));
            s.admit(decode_request(1, 0, 4, 3));
            let mut done = Vec::new();
            while let Some(model) = s.next_model() {
                done.extend(s.dispatch_next(model, &weighted_program()));
            }
            done.extend(s.drain_decode());
            (done, s.kv_evictions())
        };
        let (done, kv_evictions) = run();
        // Round 1: sequence 0 installs its cache (evicting the weight
        // tile — not a KV eviction), then sequence 1's install evicts it.
        // Round 2: each sequence's install evicts the other's cache and
        // re-pays its 800-cycle KV stream as a preemption refetch.
        assert_eq!(kv_evictions, 3);
        assert_eq!(done[0].kv_refetch_cycles, 800);
        assert_eq!(done[1].kv_refetch_cycles, 800);
        // Deterministic replay: same trace, same counters, same records.
        assert_eq!(run(), (done, kv_evictions));
    }

    #[test]
    fn decode_requests_do_not_ride_single_shot_batches() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            max_batch: 8,
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
        s.admit(request(0, Priority::Standard, 0));
        s.admit(decode_request(1, 0, 4, 2));
        s.admit(request(2, Priority::Standard, 0));
        let batch = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        // The decode request must not be absorbed as a follower of the
        // single-shot batch.
        assert_eq!(batch.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 2]);
        let decode = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        assert_eq!(decode[0].id, 1);
        assert_eq!(decode[0].tokens, 2);
    }

    #[test]
    fn pipelining_and_residency_off_reproduce_baseline_scheduler() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = synthetic_trace(&[ModelId::MobileNetV1], 20, 800, 7);
        let run = |opts: &SchedulerOptions| {
            let mut s = Scheduler::new(&cfg, opts);
            for r in &trace {
                s.admit(*r);
            }
            let mut done = Vec::new();
            while s.next_model().is_some() {
                done.extend(s.dispatch_next(ModelId::MobileNetV1, &weighted_program()));
            }
            (done, s.makespan_cycles())
        };
        let base = run(&fifo_opts(2));
        let off = run(&SchedulerOptions {
            instances: 2,
            pipeline: false,
            weight_residency: false,
            ..SchedulerOptions::default()
        });
        assert_eq!(base, off);
        assert!(base.0.iter().all(|c| c.overlap_cycles == 0 && c.residency_hit_cycles == 0));
    }

    #[test]
    fn energy_accounting_observes_without_touching_timing() {
        let cfg = NeutronConfig::flagship_2tops();
        let trace = synthetic_trace(&[ModelId::MobileNetV1], 20, 800, 7);
        let run = |energy: bool| {
            let opts = SchedulerOptions { instances: 2, energy, ..SchedulerOptions::default() };
            let mut s = Scheduler::new(&cfg, &opts);
            for r in &trace {
                s.admit(*r);
            }
            let mut done = Vec::new();
            while s.next_model().is_some() {
                done.extend(s.dispatch_next(ModelId::MobileNetV1, &weighted_program()));
            }
            (done, s.makespan_cycles(), s.energy_spent_fj())
        };
        let (off, off_makespan, off_spent) = run(false);
        let (on, on_makespan, on_spent) = run(true);
        assert_eq!(off_makespan, on_makespan, "the meter must never move the clock");
        assert_eq!(off_spent, 0);
        assert!(on_spent > 0);
        assert_eq!(off.len(), on.len());
        for (a, b) in off.iter().zip(&on) {
            // Every timing field bit-identical; only the energy differs.
            assert_eq!(
                (a.id, a.start_cycles, a.finish_cycles, a.first_token_cycles, a.instance),
                (b.id, b.start_cycles, b.finish_cycles, b.first_token_cycles, b.instance)
            );
            assert_eq!(a.energy_total_fj(), 0);
            assert!(b.energy_total_fj() > 0, "leakage floors every priced request above 0");
            assert_eq!(
                b.energy_compute_fj + b.energy_dma_fj + b.energy_idle_fj,
                b.energy_total_fj()
            );
        }
        // The fleet meter is exactly the sum of the per-request meters
        // (no idle-gap energy at the scheduler level — the report layer
        // adds that from the makespan).
        assert_eq!(on.iter().map(|c| c.energy_total_fj()).sum::<u64>(), on_spent);
    }

    #[test]
    fn stretch_trades_makespan_for_follower_dma_energy() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = |mode: EnergyMode| {
            // One instance per request: race-to-idle always finds an idle
            // peer (or an empty queue on the last dispatch), so it never
            // forms followers — every coalescing decision below is
            // attributable to stretch alone.
            let opts = SchedulerOptions {
                instances: 4,
                max_batch: 4,
                energy: true,
                energy_mode: mode,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            for id in 0..4 {
                s.admit(request(id, Priority::Standard, 0));
            }
            let mut done = Vec::new();
            while s.next_model().is_some() {
                done.extend(s.dispatch_next(ModelId::MobileNetV1, &weighted_program()));
            }
            (done, s.makespan_cycles(), s.energy_spent_fj())
        };
        let (race, race_makespan, race_spent) = run(EnergyMode::RaceToIdle);
        let (stretch, stretch_makespan, stretch_spent) = run(EnergyMode::Stretch);
        // Race-to-idle spreads the four requests over the four instances
        // (idle capacity wins); stretch coalesces them into one batch
        // whose followers skip the 600-cycle parameter fetch.
        assert!(race.iter().all(|c| c.batch_index == 0));
        assert!(stretch.iter().any(|c| c.batch_index > 0));
        assert!(
            stretch_makespan > race_makespan,
            "stretch serializes work: {stretch_makespan} vs {race_makespan}"
        );
        assert!(
            stretch_spent < race_spent,
            "stretch elides follower DMA: {stretch_spent} vs {race_spent}"
        );
        let dma = |cs: &[Completion]| cs.iter().map(|c| c.energy_dma_fj).sum::<u64>();
        assert!(dma(&stretch) < dma(&race), "the savings come from the DMA channel");
    }

    #[test]
    fn energy_budget_sheds_batch_then_standard_never_realtime() {
        let cfg = NeutronConfig::flagship_2tops();
        let opts = SchedulerOptions {
            instances: 1,
            energy: true,
            energy_budget_fj: Some(1), // exhausted by the very first dispatch
            ..SchedulerOptions::default()
        };
        let mut s = Scheduler::new(&cfg, &opts);
        // Before anything is metered, every class is admitted.
        assert_eq!(s.admit(request(0, Priority::Batch, 0)), Admission::Accepted);
        s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        assert!(s.energy_spent_fj() >= 1, "the budget is now exhausted");
        // Past exhaustion: Batch and Standard shed, Realtime still lands.
        let batch = request(1, Priority::Batch, 2_000);
        assert_eq!(s.admit(batch), Admission::Shed(batch));
        let standard = request(2, Priority::Standard, 2_000);
        assert_eq!(s.admit(standard), Admission::Shed(standard));
        assert_eq!(s.admit(request(3, Priority::Realtime, 2_000)), Admission::Accepted);
        assert_eq!(s.shed().len(), 2);
        let done = s.dispatch_next(ModelId::MobileNetV1, &weighted_program());
        assert_eq!(done[0].id, 3, "realtime work still runs under an exhausted budget");
    }

    #[test]
    fn continuous_decode_spends_less_energy_than_request_boundary() {
        let cfg = NeutronConfig::flagship_2tops();
        let run = |continuous_batch: bool| {
            let opts = SchedulerOptions {
                instances: 1,
                continuous_batch,
                energy: true,
                ..SchedulerOptions::default()
            };
            let mut s = Scheduler::new(&cfg, &opts);
            s.register_decode_job(ModelId::MobileNetV1, toy_decode_job());
            s.admit(decode_request(0, 0, 4, 4));
            let mut done = Vec::new();
            while let Some(model) = s.next_model() {
                done.extend(s.dispatch_next(model, &weighted_program()));
            }
            done.extend(s.drain_decode());
            (done, s.energy_spent_fj())
        };
        let (boundary, boundary_spent) = run(false);
        let (continuous, continuous_spent) = run(true);
        assert_eq!(boundary[0].tokens, 4);
        assert_eq!(continuous[0].tokens, 4);
        for c in boundary.iter().chain(&continuous) {
            assert!(c.energy_total_fj() > 0);
            assert_eq!(
                c.energy_compute_fj + c.energy_dma_fj + c.energy_idle_fj,
                c.energy_total_fj()
            );
        }
        // Pinned decode weights elide per-step parameter streaming, so
        // continuous batching also wins on joules, not just makespan.
        assert!(
            continuous_spent < boundary_spent,
            "continuous {continuous_spent} fJ vs boundary {boundary_spent} fJ"
        );
        assert_eq!(continuous[0].energy_total_fj(), continuous_spent);
    }
}
